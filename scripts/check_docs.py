#!/usr/bin/env python
"""Keep the paper↔code documentation honest as the registries grow.

Fails (non-zero exit / raised AssertionError from pytest) when:

* a registered aggregator, attack, or schedule is missing from
  docs/PAPER_MAP.md (every registry name must appear as `name`);
* a registry entry has an empty description (the registry IS the
  documentation surface — see aggregators.describe());
* the README aggregator table is missing a registered aggregator;
* the checked-in benchmarks/BENCH_round_kernel.json is absent, unparsable,
  or its recorded headline claim (fused beats unfused at the paper-scale
  configuration on the recorded backend) does not hold;
* a registered pod-sweep scenario or production mesh (repro.sim.sweep) is
  missing from the checked-in benchmarks/BENCH_pod_sweeps.json, or a
  sweep-matrix axis value (attack/schedule/aggregator/mesh) is missing
  from the docs/BENCHMARKS.md sweep tables;
* a repro.verify rule (RV1xx/RV2xx/RV3xx) is missing from the
  docs/STATIC_ANALYSIS.md catalog, or the catalog documents a rule ID
  that is no longer registered (stale docs fail too);
* a Layer-C taint surface is undocumented: a declared (or declarable)
  sanitization kind or an adversary source tag missing from the
  docs/STATIC_ANALYSIS.md tables, or a PAPER_MAP that no longer anchors
  the --taint gate to the paper's S1.3 dependency argument;
* a registered arrival schedule (repro.core.staleness) is missing from
  the docs/ASYNC.md schedule table or the PAPER_MAP synchrony rows;
* a prose doc references a repo file path that does not exist, or points
  into the build container's /root/related staging area.

Run directly::

    PYTHONPATH=src python scripts/check_docs.py

or via tier-1 (tests/test_docs_map.py).
"""

from __future__ import annotations

import json
import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _read(relpath: str) -> str:
    with open(os.path.join(REPO, relpath)) as f:
        return f.read()


def collect_problems() -> list[str]:
    sys.path.insert(0, os.path.join(REPO, "src"))
    from repro.core import aggregators, byzantine

    problems: list[str] = []
    paper_map = _read(os.path.join("docs", "PAPER_MAP.md"))
    readme = _read("README.md")

    registries = {
        "aggregator": aggregators.describe(),
        "attack": byzantine.describe(),
        "schedule": byzantine.describe_schedules(),
    }
    for kind, rows in registries.items():
        for name, description in rows:
            if f"`{name}`" not in paper_map:
                problems.append(
                    f"{kind} {name!r} is registered but missing from "
                    "docs/PAPER_MAP.md — add its row")
            if not description.strip():
                problems.append(
                    f"{kind} {name!r} has an empty registry description")

    # The README table must match the registry row for row — names AND
    # descriptions (regenerate with aggregators.describe_markdown()).
    for row in aggregators.describe_markdown().splitlines():
        if row not in readme:
            problems.append(
                "README aggregator table drifted from the registry; "
                f"missing row: {row!r} "
                "(regenerate with repro.core.aggregators.describe_markdown())")

    bench_path = os.path.join("benchmarks", "BENCH_round_kernel.json")
    if not os.path.exists(os.path.join(REPO, bench_path)):
        problems.append(f"{bench_path} is not checked in "
                        "(run python -m benchmarks.run --only kernel_bench)")
    else:
        try:
            rec = json.loads(_read(bench_path))
        except json.JSONDecodeError as e:
            problems.append(f"{bench_path} does not parse: {e}")
        else:
            for field in ("backend", "paper_scale", "summary"):
                if field not in rec:
                    problems.append(f"{bench_path} missing field {field!r}")
            summary = rec.get("summary", {})
            if not summary.get("fused_beats_unfused_at_paper_scale", False):
                problems.append(
                    f"{bench_path}: recorded summary does not claim the "
                    "paper-scale fused win — re-measure or re-record")
            for row in rec.get("paper_scale", []):
                if row.get("speedup", 0.0) <= 1.0:
                    problems.append(
                        f"{bench_path}: paper_scale row {row} has "
                        "speedup <= 1")

    problems += _pod_sweep_problems(paper_map)
    problems += _codec_problems(paper_map)
    problems += _verify_rules_problems(paper_map)
    problems += _taint_doc_problems(paper_map)
    problems += _arrival_problems(paper_map)
    problems += _dead_path_problems()
    return problems


def _arrival_problems(paper_map: str) -> list[str]:
    """The asynchrony contract: every registered arrival schedule must be
    documented where its semantics live — the docs/ASYNC.md schedule table
    AND the PAPER_MAP synchrony rows — with a non-empty registry
    description (the registry IS the documentation surface, same
    discipline as the aggregator / attack / codec registries)."""
    from repro.core import staleness

    problems: list[str] = []
    async_md = _read(os.path.join("docs", "ASYNC.md"))
    for name, description in staleness.describe():
        if f"`{name}`" not in async_md:
            problems.append(
                f"arrival schedule {name!r} is registered but missing from "
                "docs/ASYNC.md — add its row to the schedule table")
        if f"`{name}`" not in paper_map:
            problems.append(
                f"arrival schedule {name!r} is registered but missing from "
                "docs/PAPER_MAP.md — add it to the §2 synchrony-assumption "
                "rows")
        if not description.strip():
            problems.append(
                f"arrival schedule {name!r} has an empty registry "
                "description")
    return problems


# Backtick-quoted repo paths in the prose docs (`a/b.py`, `docs/X.md`, …).
# Requires a `/` so module dotted-paths don't match; skips glob/template
# candidates (`*`, `<`, `{`) and the documented-as-uncommitted scratch
# outputs under benchmarks/results/.
_DOC_PATH_RE = re.compile(
    r"`([A-Za-z0-9_.\-/]+/[A-Za-z0-9_.\-/]+"
    r"\.(?:py|md|json|yml|yaml|sh))`")
_DEAD_PATH_DOCS = ("README.md", "ROADMAP.md", "docs/ASYNC.md",
                   "docs/BENCHMARKS.md", "docs/PAPER_MAP.md",
                   "docs/STATIC_ANALYSIS.md", "docs/DESIGN.md")


def _dead_path_problems(doc_texts: dict[str, str] | None = None) -> list[str]:
    """No dead pointers in the prose docs: every backtick-quoted file path
    must exist in the repo (tried verbatim, under src/, and under
    src/repro/ — the docs use all three conventions), and no doc may
    reference the build container's /root/related staging area, which does
    not exist for readers of the published repo (the ROADMAP once pointed
    there — PR 9 replaced those with upstream URLs).

    ``doc_texts`` overrides the on-disk docs for the negative-path test in
    tests/test_docs_map.py."""
    problems: list[str] = []
    if doc_texts is None:
        doc_texts = {rel: _read(rel) for rel in _DEAD_PATH_DOCS
                     if os.path.exists(os.path.join(REPO, rel))}
    for rel, text in doc_texts.items():
        for path in sorted(set(_DOC_PATH_RE.findall(text))):
            if path.startswith("benchmarks/results/"):
                continue
            candidates = (path, os.path.join("src", path),
                          os.path.join("src", "repro", path))
            if not any(os.path.exists(os.path.join(REPO, c))
                       for c in candidates):
                problems.append(
                    f"{rel} references `{path}` but no such file exists "
                    "(tried verbatim, src/, src/repro/) — fix or drop the "
                    "dead pointer")
        for i, line in enumerate(text.splitlines(), start=1):
            if "/root/related" in line:
                problems.append(
                    f"{rel}:{i} references the /root/related staging area, "
                    "which does not exist for repo readers — cite the "
                    "upstream URL instead")
    return problems


def _codec_problems(paper_map: str) -> list[str]:
    """The wire-codec contract: every registered compression codec must be
    documented where the §1.4 cost claims live — the PAPER_MAP comm-cost
    rows AND the docs/BENCHMARKS.md wire-traffic section — with a
    non-empty registry description (same discipline as the aggregator /
    attack registries: the registry IS the documentation surface)."""
    from repro.core import compression

    problems: list[str] = []
    benchmarks_md = _read(os.path.join("docs", "BENCHMARKS.md"))
    for name, description in compression.describe():
        if f"`{name}`" not in paper_map:
            problems.append(
                f"compression codec {name!r} is registered but missing "
                "from docs/PAPER_MAP.md — add it to the §1.4 "
                "communication-cost rows")
        if f"`{name}`" not in benchmarks_md:
            problems.append(
                f"compression codec {name!r} is registered but missing "
                "from the docs/BENCHMARKS.md wire-traffic section")
        if not description.strip():
            problems.append(
                f"compression codec {name!r} has an empty registry "
                "description")
    return problems


def _verify_rules_problems(paper_map: str) -> list[str]:
    """The invariant-checker contract: rule registry ⟺ the
    docs/STATIC_ANALYSIS.md catalog, both directions."""
    import re

    from repro.verify.rules import RULES

    problems: list[str] = []
    doc = _read(os.path.join("docs", "STATIC_ANALYSIS.md"))

    for rid in RULES:
        if f"`{rid}`" not in doc:
            problems.append(
                f"verify rule {rid!r} is registered but undocumented in "
                "docs/STATIC_ANALYSIS.md — add its catalog row")
    for rid in set(re.findall(r"`(RV\d{3})`", doc)):
        if rid not in RULES:
            problems.append(
                f"docs/STATIC_ANALYSIS.md documents {rid!r} but no such "
                "rule is registered in repro.verify.rules — remove the "
                "stale row or restore the rule")
    if "repro.verify" not in paper_map:
        problems.append(
            "docs/PAPER_MAP.md does not anchor `repro.verify` "
            "(§Thm 3 collective-shape rows)")
    return problems


def _taint_doc_problems(paper_map: str) -> list[str]:
    """The Layer-C contract: every sanitization kind the influence engine
    can discover (= every value ``register(sanitization_point=...)``
    accepts) and every adversary source tag must be documented in the
    docs/STATIC_ANALYSIS.md tables, every *declared* point must be one of
    them, and PAPER_MAP must anchor the taint gate to the paper's S1.3
    arbitrary-dependency argument."""
    from repro.core import aggregators
    from repro.verify.influence import SANITIZER_KINDS

    problems: list[str] = []
    doc = _read(os.path.join("docs", "STATIC_ANALYSIS.md"))

    for kind in SANITIZER_KINDS:
        if f"`{kind}`" not in doc:
            problems.append(
                f"sanitization kind {kind!r} is recognized by the Layer-C "
                "influence engine but missing from the "
                "docs/STATIC_ANALYSIS.md bounded-op table")
    for source in ("report", "age", "attack_state"):
        if f"`{source}`" not in doc:
            problems.append(
                f"adversary source tag {source!r} is missing from the "
                "docs/STATIC_ANALYSIS.md taint-sources table")
    for name in aggregators.available():
        point = aggregators.get_aggregator(name).sanitization_point
        if point is not None and point not in SANITIZER_KINDS:
            problems.append(
                f"aggregator {name!r} declares sanitization_point "
                f"{point!r}, which the influence engine cannot discover "
                "(not in SANITIZER_KINDS)")
    if "--taint" not in paper_map:
        problems.append(
            "docs/PAPER_MAP.md does not anchor the Layer-C taint gate "
            "(`python -m repro.verify --strict --taint`) to the S1.3 "
            "arbitrary-dependency rows")
    return problems


def _pod_sweep_problems(paper_map: str) -> list[str]:
    """The pod-sweep contract: registry ⊆ checked-in record ∧ docs tables."""
    from repro.sim import sweep

    problems: list[str] = []
    benchmarks_md = _read(os.path.join("docs", "BENCHMARKS.md"))

    # every matrix axis value must be documented in the BENCHMARKS.md sweep
    # section, and the sweep module must be anchored in the paper map.
    for kind, values in (("attack", sweep.POD_ATTACKS),
                         ("schedule", sweep.POD_SCHEDULES),
                         ("aggregator", sweep.POD_AGGREGATORS),
                         ("mesh", sweep.POD_MESHES)):
        for v in values:
            if f"`{v}`" not in benchmarks_md:
                problems.append(
                    f"pod-sweep {kind} {v!r} is in the sweep matrix but "
                    "missing from the docs/BENCHMARKS.md sweep tables")
    if "repro.sim.sweep" not in paper_map:
        problems.append(
            "docs/PAPER_MAP.md does not anchor `repro.sim.sweep` "
            "(§5 communication-cost rows)")

    sweep_path = os.path.join("benchmarks", "BENCH_pod_sweeps.json")
    if not os.path.exists(os.path.join(REPO, sweep_path)):
        problems.append(
            f"{sweep_path} is not checked in "
            "(run python -m repro.sim.sweep --all)")
        return problems
    try:
        rec = json.loads(_read(sweep_path))
    except json.JSONDecodeError as e:
        problems.append(f"{sweep_path} does not parse: {e}")
        return problems
    scenarios = rec.get("scenarios", {})
    for name in sweep.available():
        if name not in scenarios:
            problems.append(
                f"pod scenario {name!r} is registered but missing from "
                f"{sweep_path} — re-record with "
                "`python -m repro.sim.sweep --all`")
    recorded_meshes = {e.get("mesh") for e in scenarios.values()}
    for mesh in sweep.POD_MESHES:
        if mesh not in recorded_meshes:
            problems.append(
                f"production mesh {mesh!r} has no recorded scenario in "
                f"{sweep_path}")

    # big-model (sharded-aggregation) cells: documented + recorded with the
    # grad_mode they claim to measure.
    if f"`{sweep.BIG_MODEL_ARCH}`" not in benchmarks_md:
        problems.append(
            f"big-model arch {sweep.BIG_MODEL_ARCH!r} is in the sweep "
            "registry but missing from the docs/BENCHMARKS.md big-model "
            "section")
    for name in sweep.BIG_MODEL_SCENARIOS:
        entry = scenarios.get(name)
        if entry is None:
            continue  # absence already reported above
        want = "gathered" if name.endswith("/gathered") else "sharded"
        if entry.get("grad_mode") != want:
            problems.append(
                f"big-model scenario {name!r} recorded "
                f"grad_mode={entry.get('grad_mode')!r}, expected {want!r} — "
                "the O(d/shards) comparison needs both modes recorded as "
                "labelled")
    return problems


def main() -> int:
    problems = collect_problems()
    for p in problems:
        print(f"check_docs: {p}")
    if problems:
        print(f"check_docs: FAILED ({len(problems)} problem(s))")
        return 1
    print("check_docs: ok — registries, PAPER_MAP, README table, "
          "BENCH_round_kernel.json, the pod-sweep record/docs, the "
          "repro.verify rule catalog, the Layer-C taint tables, the "
          "ASYNC.md arrival table, and every doc-referenced file path "
          "are consistent")
    return 0


if __name__ == "__main__":
    sys.exit(main())

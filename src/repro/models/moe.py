"""Mixture-of-Experts layer (top-k router, capacity-bounded dispatch).

TPU adaptation notes (DESIGN.md §3): expert dispatch uses sorted scatter into
per-expert capacity buffers rather than the (tokens × experts × capacity)
one-hot einsum of GShard — the one-hot dispatch tensor is infeasible at
kimi-k2 scale (1M tokens × 384 experts).  Scatter/gather lower to
all-to-all-style collectives when the expert axis is sharded over ``model``
(expert parallelism), which is exactly the collective the roofline tracks.

Tokens beyond an expert's capacity are dropped (standard; capacity_factor
controls the slack).  The router adds the usual load-balance auxiliary loss
(Switch/GShard form) and optional router z-loss.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers, meshctx


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_ff: int                  # per-expert hidden size
    num_experts: int
    experts_per_token: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-4


def init(key, spec: MoESpec, *, dtype):
    k_router, k_gate, k_up, k_down = jax.random.split(key, 4)
    E, D, F = spec.num_experts, spec.d_model, spec.d_ff

    def expert_init(k, d_in, d_out):
        return layers.truncated_normal_init(
            k, (E, d_in, d_out), d_in ** -0.5, dtype)

    return {
        "router": layers.dense_init(k_router, D, E, dtype=jnp.float32),
        "w_gate": expert_init(k_gate, D, F),
        "w_up": expert_init(k_up, D, F),
        "w_down": expert_init(k_down, F, D),
    }


def _capacity(spec: MoESpec, num_tokens: int) -> int:
    cap = int(spec.capacity_factor * num_tokens
              * spec.experts_per_token / spec.num_experts)
    return max(cap, spec.experts_per_token)


def route(params, spec: MoESpec, x_flat):
    """Router: logits, top-k ids/weights and aux losses.  x_flat: (N, D)."""
    logits = jnp.einsum("nd,de->ne", x_flat.astype(jnp.float32),
                        params["router"])                      # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_ids = jax.lax.top_k(probs, spec.experts_per_token)
    top_w = top_w / jnp.maximum(
        jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)          # renormalize

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    f = jnp.zeros((spec.num_experts,), jnp.float32).at[
        top_ids.reshape(-1)].add(1.0) / top_ids.size
    p = jnp.mean(probs, axis=0)
    aux = spec.num_experts * jnp.sum(f * p)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    return top_ids, top_w, aux, z


def _ambient_mesh():
    return meshctx.current_mesh()


def _ep_applicable(spec: MoESpec, x, mesh) -> bool:
    if mesh is None or "model" not in mesh.axis_names:
        return False
    model_n = mesh.shape["model"]
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not data_axes:
        return False
    data_n = 1
    for a in data_axes:
        data_n *= mesh.shape[a]
    B = x.shape[0]
    return (spec.num_experts % model_n == 0 and B % data_n == 0
            and spec.num_experts >= model_n)


def apply(params, spec: MoESpec, x):
    """x: (B, T, D) -> (out (B, T, D), aux_loss scalar).

    Under an ambient mesh with a ``model`` axis (jax.set_mesh), dispatch runs
    **expert-parallel under shard_map**: each (data, model) device routes its
    local tokens to its local E/|model| experts in a per-device capacity
    buffer and the expert outputs are summed with one psum over ``model`` —
    the token→expert data movement is absorbed into the existing
    tensor-parallel all-reduce, and no global (E, C, D) buffer or
    GSPMD-replicated scatter ever exists (that naive lowering cost ~1 TB/chip
    of all-reduce on granite-moe; see EXPERIMENTS.md §Perf).

    Without a mesh (CPU tests / single device) the dense scatter path runs.
    """
    mesh = _ambient_mesh()
    if _ep_applicable(spec, x, mesh):
        return _apply_expert_parallel(params, spec, x, mesh)
    return _apply_dense(params, spec, x)


def _expert_ffn(w_gate, w_up, w_down, h):
    g = jnp.einsum("cd,df->cf", h, w_gate)
    u = jnp.einsum("cd,df->cf", h, w_up)
    act = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
    return jnp.einsum("cf,fd->cd", act, w_down)


def _dispatch_local(spec: MoESpec, x_flat, top_ids, top_w, *,
                    expert_lo: int, num_local: int, capacity: int):
    """Capacity-bounded dispatch of local tokens to local experts.
    Returns (expert_in (E_loc, C, D), combine info)."""
    N, D = x_flat.shape
    K = spec.experts_per_token
    flat_ids = top_ids.reshape(-1)
    local = (flat_ids >= expert_lo) & (flat_ids < expert_lo + num_local)
    le = jnp.where(local, flat_ids - expert_lo, num_local)  # sentinel bucket
    order = jnp.argsort(le, stable=True)
    sorted_le = le[order]
    first = jnp.searchsorted(sorted_le, sorted_le, side="left")
    rank_sorted = jnp.arange(N * K) - first
    slots = jnp.zeros((N * K,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))
    keep = local & (slots < capacity)
    token_idx = jnp.repeat(jnp.arange(N), K)
    safe_e = jnp.where(keep, le, 0)
    safe_s = jnp.where(keep, slots, capacity - 1)
    contrib = jnp.where(keep[:, None], x_flat[token_idx], 0.0)
    expert_in = jnp.zeros((num_local, capacity, D), x_flat.dtype) \
        .at[safe_e, safe_s].add(contrib)
    w = jnp.where(keep, top_w.reshape(-1), 0.0)
    return expert_in, (token_idx, safe_e, safe_s, w)


def _apply_expert_parallel(params, spec: MoESpec, x, mesh):
    """Expert parallelism under shard_map.

    The residual stream arrives **T-sharded over model** (sequence
    parallelism); the body all-gathers x over ``model`` (bf16, B·T·D/|data|),
    routes its tokens to its E/|model| local experts, and returns the partial
    outputs with one ``psum_scatter`` back to T-sharded layout.  Explicitly
    managing the SP↔EP boundary this way replaced a GSPMD reshard that
    all-reduced the *unsharded* group activations per MoE layer (3.8 GB ×
    244 occurrences on kimi-k2 train_4k — EXPERIMENTS §Perf iteration 2)."""
    from jax.sharding import PartitionSpec as P
    B, T, D = x.shape
    E = spec.num_experts
    model_n = mesh.shape["model"]
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    d_ax = data_axes if len(data_axes) > 1 else data_axes[0]
    data_n = 1
    for a in data_axes:
        data_n *= mesh.shape[a]
    e_loc = E // model_n
    n_loc = (B // data_n) * T
    cap = max(int(spec.capacity_factor * n_loc
                  * spec.experts_per_token / E), spec.experts_per_token)
    t_sharded = (T % model_n == 0 and T >= model_n)

    # FSDP dim of the expert weights (mirrors launch/sharding.py's rule:
    # largest dim after E).  Gathering it EXPLICITLY inside the region makes
    # the gather's transpose a reduce-scatter into the optimizer layout —
    # the implicit jit-boundary reshard was hoisted out of the layer scan
    # (~129 GB resident weights) and its transpose lowered as a 4.2 GB × 244
    # in-loop all-reduce on kimi-k2 (EXPERIMENTS §Perf iteration 3).
    D_, F_ = params["w_gate"].shape[-2:]
    gate_fsdp_axis = 1 if D_ >= F_ else 2          # (E, D, F)
    down_fsdp_axis = 2 if D_ >= F_ else 1          # (E, F, D)
    fsdp_ok = (max(D_, F_) % data_n == 0 and max(D_, F_) >= data_n)

    def _wspec(ax):
        if not fsdp_ok:
            return P("model", None, None)
        spec_ = [None, None, None]
        spec_[0] = "model"
        spec_[ax] = d_ax
        return P(*spec_)

    def body(router_w, w_gate, w_up, w_down, x_blk):
        # x_blk: (B_loc, T/|model|, D) T-sharded (or (B_loc, T, D) if not)
        if fsdp_ok:
            w_gate = jax.lax.all_gather(w_gate, d_ax, axis=gate_fsdp_axis,
                                        tiled=True)
            w_up = jax.lax.all_gather(w_up, d_ax, axis=gate_fsdp_axis,
                                      tiled=True)
            w_down = jax.lax.all_gather(w_down, d_ax, axis=down_fsdp_axis,
                                        tiled=True)
        if t_sharded:
            x_blk = jax.lax.all_gather(x_blk, "model", axis=1, tiled=True)
        b_loc = x_blk.shape[0]
        x_flat = x_blk.reshape(b_loc * T, D)
        logits = jnp.einsum("nd,de->ne", x_flat.astype(jnp.float32),
                            router_w)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_ids = jax.lax.top_k(probs, spec.experts_per_token)
        top_w = top_w / jnp.maximum(
            jnp.sum(top_w, axis=-1, keepdims=True), 1e-9)

        midx = jax.lax.axis_index("model")
        expert_lo = midx * e_loc
        expert_in, (token_idx, safe_e, safe_s, w) = _dispatch_local(
            spec, x_flat, top_ids, top_w,
            expert_lo=expert_lo, num_local=e_loc, capacity=cap)
        expert_out = jax.vmap(_expert_ffn)(w_gate, w_up, w_down, expert_in)
        gathered = expert_out[safe_e, safe_s]
        out_flat = jnp.zeros((b_loc * T, D), jnp.float32).at[token_idx].add(
            gathered.astype(jnp.float32) * w[:, None])
        # sum expert contributions across the model axis; scatter back to
        # the T-sharded layout when the stream is sequence-parallel
        if not t_sharded:
            out_flat = jax.lax.psum(out_flat, axis_name="model")
        if t_sharded:
            out_seq = out_flat.reshape(b_loc, T, D)
            out_seq = jax.lax.psum_scatter(out_seq, "model",
                                           scatter_dimension=1, tiled=True)
            out_flat = out_seq.reshape(b_loc * (T // model_n), D)

        # global router stats for the aux losses
        # stats are identical across model ranks only after the t_sharded
        # gather (then vma still marks them varying -> psum+divide); without
        # the gather they are invarying over model and must not be psum'd.
        stat_axes = data_axes + (("model",) if t_sharded else ())
        stat_norm = model_n if t_sharded else 1
        counts = jnp.zeros((E,), jnp.float32).at[top_ids.reshape(-1)].add(1.0)
        counts = jax.lax.psum(counts, axis_name=stat_axes) / stat_norm
        p_sum = jax.lax.psum(jnp.sum(probs, axis=0),
                             axis_name=stat_axes) / stat_norm
        n_tot = b_loc * T * data_n
        f = counts / (n_tot * spec.experts_per_token)
        p = p_sum / n_tot
        aux = E * jnp.sum(f * p)
        z = jax.lax.psum(
            jnp.sum(jnp.square(jax.nn.logsumexp(logits, axis=-1))),
            axis_name=stat_axes) / stat_norm / n_tot
        t_out = T // model_n if t_sharded else T
        return (out_flat.astype(x_blk.dtype).reshape(b_loc, t_out, D),
                spec.router_aux_weight * aux + spec.router_z_weight * z)

    x_spec = P(d_ax, "model", None) if t_sharded else P(d_ax, None, None)
    shmap = meshctx.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), _wspec(gate_fsdp_axis), _wspec(gate_fsdp_axis),
                  _wspec(down_fsdp_axis), x_spec),
        out_specs=(x_spec, P()),
    )
    return shmap(params["router"], params["w_gate"], params["w_up"],
                 params["w_down"], x)


def _apply_dense(params, spec: MoESpec, x):
    """x: (B, T, D) -> (out (B, T, D), aux_loss scalar)."""
    B, T, D = x.shape
    N = B * T
    K = spec.experts_per_token
    E = spec.num_experts
    C = _capacity(spec, N)
    x_flat = x.reshape(N, D)

    top_ids, top_w, aux, z = route(params, spec, x_flat)       # (N,K)

    # --- dispatch: rank each (token, k) assignment within its expert -------
    flat_ids = top_ids.reshape(-1)                             # (N*K,)
    order = jnp.argsort(flat_ids, stable=True)                 # sort by expert
    sorted_ids = flat_ids[order]
    # rank within equal-id segment = position - first index of that id
    first = jnp.searchsorted(sorted_ids, sorted_ids, side="left")
    rank_sorted = jnp.arange(N * K) - first
    slots = jnp.zeros((N * K,), jnp.int32).at[order].set(
        rank_sorted.astype(jnp.int32))                         # (N*K,)
    keep = slots < C

    token_idx = jnp.repeat(jnp.arange(N), K)                   # (N*K,)
    safe_e = jnp.where(keep, flat_ids, 0)
    safe_s = jnp.where(keep, slots, C - 1)

    buf = jnp.zeros((E, C, D), x.dtype)
    contrib = jnp.where(keep[:, None], x_flat[token_idx], 0.0)
    expert_in = buf.at[safe_e, safe_s].add(contrib)            # (E, C, D)

    # --- expert FFN (vmapped over E; experts sharded over `model`) ---------
    def ffn(w_gate, w_up, w_down, h):
        g = jnp.einsum("cd,df->cf", h, w_gate)
        u = jnp.einsum("cd,df->cf", h, w_up)
        act = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * u
        return jnp.einsum("cf,fd->cd", act, w_down)

    expert_out = jax.vmap(ffn)(params["w_gate"], params["w_up"],
                               params["w_down"], expert_in)    # (E, C, D)

    # --- combine: gather each assignment's output, weight, and sum over K --
    gathered = expert_out[safe_e, safe_s]                      # (N*K, D)
    w = jnp.where(keep, top_w.reshape(-1), 0.0)                # dropped => 0
    out_flat = jnp.zeros((N, D), jnp.float32).at[token_idx].add(
        gathered.astype(jnp.float32) * w[:, None])
    out = out_flat.astype(x.dtype).reshape(B, T, D)

    aux_total = spec.router_aux_weight * aux + spec.router_z_weight * z
    return out, aux_total

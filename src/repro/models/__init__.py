from repro.models import (  # noqa: F401
    attention,
    blocks,
    layers,
    mamba,
    model,
    moe,
    rwkv,
)

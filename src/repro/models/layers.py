"""Shared neural-net layers (pure functional, params = nested dicts).

Conventions:
* ``init_*`` returns a params pytree; ``apply`` style functions are pure.
* Params are stored in ``param_dtype`` (default f32 at small scale, bf16 at
  production scale via configs); matmuls run in the activation dtype.
* Layer stacks are *scanned*: per-layer params carry a leading L axis
  (initialized with vmap) and the block is applied under ``jax.lax.scan`` —
  this keeps the HLO size O(1) in depth, which the 512-device dry-run
  compiles depend on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def truncated_normal_init(key, shape, scale: float, dtype):
    """He/LeCun-style scaled truncated normal."""
    x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
    return (x * scale).astype(dtype)


def dense_init(key, d_in: int, d_out, *, dtype, scale: float | None = None):
    """Weight matrix (d_in, *d_out) with fan-in scaling."""
    if isinstance(d_out, int):
        d_out = (d_out,)
    scale = scale if scale is not None else d_in ** -0.5
    return truncated_normal_init(key, (d_in, *d_out), scale, dtype)


def embed_init(key, vocab: int, d_model: int, *, dtype):
    return truncated_normal_init(key, (vocab, d_model), 1.0, dtype)


# ---------------------------------------------------------------------------
# norms

def rmsnorm_init(dim: int, *, dtype):
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params, x, *, eps: float = 1e-5):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(dim: int, *, dtype):
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params, x, *, eps: float = 1e-5):
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    normed = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = normed * params["scale"].astype(jnp.float32) \
        + params["bias"].astype(jnp.float32)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings

def rope_frequencies(head_dim: int, *, theta: float = 1e4):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, *, theta: float = 1e4):
    """x: (..., T, H, head_dim); positions: broadcastable to (..., T)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta=theta)         # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., None, :]                     # (..., T, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs

def swiglu_init(key, d_model: int, d_ff: int, *, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype=dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype=dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype=dtype),
    }


def _swiglu_local(w_gate, w_up, w_down, x):
    gate = jnp.einsum("...d,df->...f", x, w_gate)
    up = jnp.einsum("...d,df->...f", x, w_up)
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("...f,fd->...d", hidden, w_down)


import functools


@functools.lru_cache(maxsize=None)
def _make_swiglu_sp_region(data_axes: tuple):
    """Megatron SP+TP SwiGLU per-device body (runs inside shard_map), with a
    hand-written VJP (EXPERIMENTS §Perf/qwen2 iteration 3): the autodiff'd
    version moved f32 tangents through the gathers and lowered the
    all-gather transpose as a full-size ``psum_invariant`` all-reduce
    (604 MB × 320 occurrences on qwen2-72b).  Here every collective carries
    the residual dtype (bf16), the gather transpose is an explicit
    reduce-scatter, the gathered activations are re-gathered in the backward
    instead of saved, and the weight-grad data reduction is an explicit psum
    over ``data_axes``."""

    @jax.custom_vjp
    def region(w_gate, w_up, w_down, x_blk):
        g = jax.lax.all_gather(x_blk, "model", axis=1, tiled=True)
        out = _swiglu_local(w_gate, w_up, w_down, g)
        return jax.lax.psum_scatter(out.astype(x_blk.dtype), "model",
                                    scatter_dimension=1, tiled=True)

    def fwd(w_gate, w_up, w_down, x_blk):
        return region(w_gate, w_up, w_down, x_blk), \
            (w_gate, w_up, w_down, x_blk)

    def bwd(res, grad_out):
        w_gate, w_up, w_down, x_blk = res
        g = jax.lax.all_gather(x_blk, "model", axis=1, tiled=True)
        go = jax.lax.all_gather(grad_out, "model", axis=1, tiled=True)
        gate = jnp.einsum("...d,df->...f", g, w_gate)
        up = jnp.einsum("...d,df->...f", g, w_up)
        gate32 = gate.astype(jnp.float32)
        sg = jax.nn.silu(gate32)
        h = sg.astype(g.dtype) * up

        grad_h = jnp.einsum("...d,fd->...f", go, w_down)
        grad_wd = jnp.einsum("...f,...d->fd", h, go)
        grad_up = grad_h * sg.astype(grad_h.dtype)
        sig = jax.nn.sigmoid(gate32)
        dsilu = sig * (1 + gate32 * (1 - sig))
        grad_gate = (grad_h.astype(jnp.float32) * up.astype(jnp.float32)
                     * dsilu).astype(g.dtype)
        grad_g = jnp.einsum("...f,df->...d", grad_gate, w_gate) \
            + jnp.einsum("...f,df->...d", grad_up, w_up)
        grad_x = jax.lax.psum_scatter(grad_g.astype(x_blk.dtype), "model",
                                      scatter_dimension=1, tiled=True)
        grad_wg = jnp.einsum("...d,...f->df", g, grad_gate)
        grad_wu = jnp.einsum("...d,...f->df", g, grad_up)
        # explicit data-parallel weight-grad reduction (vma correctness)
        grad_wg, grad_wu, grad_wd = jax.lax.psum(
            (grad_wg, grad_wu, grad_wd), axis_name=data_axes)
        return grad_wg, grad_wu, grad_wd, grad_x

    region.defvjp(fwd, bwd)
    return region


def swiglu(params, x):
    """SwiGLU MLP.  Under an ambient mesh with sequence-parallel activations
    this runs the Megatron SP+TP schedule in shard_map: all-gather the
    T-sharded residual over ``model``, compute against the F-sharded expert
    of d_ff, reduce-scatter the partial output back to T-sharded — activation
    traffic 2·B·T·D per layer instead of gathering the (much larger) 3·D·F
    weights per use (measured 2.3 TB/device/step of ZeRO-3 weight gathers on
    qwen2-72b; see EXPERIMENTS.md §Perf iteration 2)."""
    from repro.models import meshctx
    mesh = meshctx.current_mesh()
    if x.ndim == 3 and mesh is not None:
        B, T, D = x.shape
        F = params["w_gate"].shape[-1]
        mp = meshctx.model_size(mesh)
        if (meshctx.sp_applicable(mesh, B, T) and F % mp == 0):
            from jax.sharding import PartitionSpec as P
            dd = meshctx.dspec(mesh)
            region = _make_swiglu_sp_region(meshctx.data_axes(mesh))
            return meshctx.shard_map(
                region, mesh=mesh,
                in_specs=(P(None, "model"), P(None, "model"),
                          P("model", None), P(dd, "model", None)),
                out_specs=P(dd, "model", None),
            )(params["w_gate"], params["w_up"], params["w_down"], x)
    return _swiglu_local(params["w_gate"], params["w_up"],
                         params["w_down"], x)


def gelu_mlp_init(key, d_model: int, d_ff: int, *, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, d_model, d_ff, dtype=dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": dense_init(k2, d_ff, d_model, dtype=dtype),
        "b_out": jnp.zeros((d_model,), dtype),
    }


def gelu_mlp(params, x):
    h = jnp.einsum("...d,df->...f", x, params["w_in"]) + params["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_out"]) + params["b_out"]


# ---------------------------------------------------------------------------
# losses

def cross_entropy_loss(logits_fn, hidden, labels, *, vocab_chunk: int = 0,
                       ignore_index: int = -1):
    """Memory-frugal LM cross entropy.

    ``logits_fn(h_chunk) -> (..., V)`` is applied to sequence chunks under a
    scan so the full (B, T, V) logits tensor never materializes (critical for
    the 150k-vocab configs at 32k context).

    hidden: (B, T, D); labels: (B, T) int32 with ``ignore_index`` masked out.
    Returns mean loss over unmasked positions.
    """
    B, T = labels.shape
    chunk = vocab_chunk if vocab_chunk > 0 else min(T, 512)
    n_chunks = T // chunk if T % chunk == 0 else 1
    if T % chunk != 0:
        chunk = T

    h = hidden.reshape(B, n_chunks, chunk, hidden.shape[-1]) \
        .transpose(1, 0, 2, 3)
    y = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        total, count = carry
        hc, yc = xs
        logits = logits_fn(hc).astype(jnp.float32)          # (B, c, V)
        lse = jax.nn.logsumexp(logits, axis=-1)
        mask = (yc != ignore_index)
        safe_y = jnp.where(mask, yc, 0)
        picked = jnp.take_along_axis(
            logits, safe_y[..., None], axis=-1)[..., 0]
        nll = jnp.where(mask, lse - picked, 0.0)
        return (total + jnp.sum(nll),
                count + jnp.sum(mask.astype(jnp.float32))), None

    (total, count), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                            jnp.zeros((), jnp.float32)),
                                     (h, y))
    return total / jnp.maximum(count, 1.0)

"""Per-family transformer blocks and their scanned-stack drivers.

Every stack uses ``jax.lax.scan`` over the layer axis (params carry a leading
L dim, initialized with vmap) so the lowered HLO is O(1) in depth — the
512-device dry-run of the 80-layer configs depends on this.  ``cfg.remat``
wraps the block body in ``jax.checkpoint`` (activation rematerialization).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers, mamba, moe, rwkv


# ---------------------------------------------------------------------------
# spec builders

def attn_spec(cfg: ModelConfig, *, causal=True, cross=False,
              sliding_window="cfg") -> attention.AttentionSpec:
    return attention.AttentionSpec(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim,
        qkv_bias=cfg.qkv_bias,
        qk_norm=cfg.qk_norm,
        causal=causal,
        sliding_window=(cfg.sliding_window if sliding_window == "cfg"
                        else sliding_window),
        rope_theta=cfg.rope_theta,
        cross=cross,
    )


def moe_spec(cfg: ModelConfig) -> moe.MoESpec:
    return moe.MoESpec(
        d_model=cfg.d_model, d_ff=cfg.d_ff,
        num_experts=cfg.num_experts,
        experts_per_token=cfg.experts_per_token,
        capacity_factor=cfg.moe_capacity_factor)


def rwkv_spec(cfg: ModelConfig) -> rwkv.RWKVSpec:
    return rwkv.RWKVSpec(d_model=cfg.d_model, d_ff=cfg.d_ff,
                         head_dim=cfg.ssm_head_dim)


def mamba_spec(cfg: ModelConfig) -> mamba.MambaSpec:
    return mamba.MambaSpec(d_model=cfg.d_model, d_state=cfg.ssm_state,
                           head_dim=cfg.ssm_head_dim, chunk=cfg.ssm_chunk)


# ---------------------------------------------------------------------------
# decoder block (dense / moe / vlm) — pre-norm GQA + (SwiGLU | MoE)

def init_decoder_block(key, cfg: ModelConfig, *, cross: bool = False):
    ks = jax.random.split(key, 4)
    p = {
        "ln_attn": layers.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype),
        "attn": attention.init(ks[0], attn_spec(cfg), dtype=cfg.param_dtype),
        "ln_mlp": layers.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype),
    }
    if cfg.family == "moe":
        p["moe"] = moe.init(ks[1], moe_spec(cfg), dtype=cfg.param_dtype)
    else:
        p["mlp"] = layers.swiglu_init(ks[1], cfg.d_model, cfg.d_ff,
                                      dtype=cfg.param_dtype)
    if cross:
        p["ln_cross"] = layers.rmsnorm_init(cfg.d_model,
                                            dtype=cfg.param_dtype)
        p["cross"] = attention.init(
            ks[2], attn_spec(cfg, cross=True), dtype=cfg.param_dtype)
    return p


def decoder_block(p, cfg: ModelConfig, x, *, memory=None, positions=None):
    """(x, aux) -> (x, aux).  Full-sequence (train/prefill)."""
    h = attention.apply(p["attn"], attn_spec(cfg),
                        layers.rmsnorm(p["ln_attn"], x, eps=cfg.norm_eps),
                        positions=positions)
    x = x + h
    if "cross" in p:
        h = attention.apply(p["cross"], attn_spec(cfg, cross=True),
                            layers.rmsnorm(p["ln_cross"], x,
                                           eps=cfg.norm_eps),
                            memory=memory, positions=positions)
        x = x + h
    normed = layers.rmsnorm(p["ln_mlp"], x, eps=cfg.norm_eps)
    if cfg.family == "moe":
        h, aux = moe.apply(p["moe"], moe_spec(cfg), normed)
    else:
        h, aux = layers.swiglu(p["mlp"], normed), jnp.zeros((), jnp.float32)
    return x + h, aux


def decoder_block_decode(p, cfg: ModelConfig, x, cache, position, *,
                         memory=None):
    """One-token decode through a decoder block. cache: attention cache dict
    (plus nothing else — MoE/MLP are stateless)."""
    h, new_cache = attention.decode_step(
        p["attn"], attn_spec(cfg),
        layers.rmsnorm(p["ln_attn"], x, eps=cfg.norm_eps),
        cache["self"], position)
    x = x + h
    if "cross" in p:
        h, _ = attention.decode_step(
            p["cross"], attn_spec(cfg, cross=True),
            layers.rmsnorm(p["ln_cross"], x, eps=cfg.norm_eps),
            None, position, memory=memory)
        x = x + h
    normed = layers.rmsnorm(p["ln_mlp"], x, eps=cfg.norm_eps)
    if cfg.family == "moe":
        h, _ = moe.apply(p["moe"], moe_spec(cfg), normed)
    else:
        h = layers.swiglu(p["mlp"], normed)
    return x + h, {"self": new_cache}


# encoder block (audio family): bidirectional self-attn + GELU MLP

def init_encoder_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln_attn": layers.layernorm_init(cfg.d_model, dtype=cfg.param_dtype),
        "attn": attention.init(
            ks[0], attn_spec(cfg, causal=False, sliding_window=None),
            dtype=cfg.param_dtype),
        "ln_mlp": layers.layernorm_init(cfg.d_model, dtype=cfg.param_dtype),
        "mlp": layers.gelu_mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                    dtype=cfg.param_dtype),
    }


def encoder_block(p, cfg: ModelConfig, x):
    spec = attn_spec(cfg, causal=False, sliding_window=None)
    x = x + attention.apply(
        p["attn"], spec, layers.layernorm(p["ln_attn"], x, eps=cfg.norm_eps))
    x = x + layers.gelu_mlp(
        p["mlp"], layers.layernorm(p["ln_mlp"], x, eps=cfg.norm_eps))
    return x


# rwkv block

def init_rwkv_block(key, cfg: ModelConfig):
    p = rwkv.init(key, rwkv_spec(cfg), dtype=cfg.param_dtype)
    p["ln_tm"] = layers.layernorm_init(cfg.d_model, dtype=cfg.param_dtype)
    p["ln_cm"] = layers.layernorm_init(cfg.d_model, dtype=cfg.param_dtype)
    return p


def rwkv_block(p, cfg: ModelConfig, x, *, state=None):
    """state = (prev_tm, wkv, prev_cm) or None (train)."""
    spec = rwkv_spec(cfg)
    prev_tm = wkv_state = prev_cm = None
    if state is not None:
        prev_tm, wkv_state, prev_cm = state
    h, (new_prev_tm, new_wkv) = rwkv.time_mix(
        p["time_mix"], spec, layers.layernorm(p["ln_tm"], x,
                                              eps=cfg.norm_eps),
        prev_token=prev_tm, wkv_state=wkv_state)
    x = x + h
    h, new_prev_cm = rwkv.channel_mix(
        p["channel_mix"], spec, layers.layernorm(p["ln_cm"], x,
                                                 eps=cfg.norm_eps),
        prev_token=prev_cm)
    x = x + h
    return x, (new_prev_tm, new_wkv, new_prev_cm)


# mamba block (zamba2)

def init_mamba_block(key, cfg: ModelConfig):
    p = mamba.init(key, mamba_spec(cfg), dtype=cfg.param_dtype)
    p["ln"] = layers.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype)
    return p


def mamba_block(p, cfg: ModelConfig, x, *, state=None):
    conv_state = ssm_state = None
    if state is not None:
        conv_state, ssm_state = state
    h, new_state = mamba.apply(
        p, mamba_spec(cfg), layers.rmsnorm(p["ln"], x, eps=cfg.norm_eps),
        conv_state=conv_state, ssm_state=ssm_state)
    return x + h, new_state


def mamba_block_decode(p, cfg: ModelConfig, x, state):
    conv_state, ssm_state = state
    h, new_state = mamba.decode_step(
        p, mamba_spec(cfg), layers.rmsnorm(p["ln"], x, eps=cfg.norm_eps),
        conv_state, ssm_state)
    return x + h, new_state


# ---------------------------------------------------------------------------
# stack helpers

def init_stacked(init_fn, key, num: int):
    """vmap an init over ``num`` split keys -> params with leading L dim."""
    keys = jax.random.split(key, num)
    return jax.vmap(init_fn)(keys)


def maybe_remat(fn, cfg: ModelConfig):
    return jax.checkpoint(fn) if cfg.remat else fn

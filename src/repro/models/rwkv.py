"""RWKV6 "Finch" blocks — attention-free linear recurrence with
data-dependent decay (arXiv:2404.05892).

Faithful to the defining Finch mechanics:

* token-shift mixing of the current and previous token,
* **data-dependent per-channel decay** ``w_t = exp(-exp(w0 + LoRA(x_t)))``
  (the paper's headline change over RWKV5's static decay),
* the ``u`` "bonus" for the current token,
* per-head WKV state ``S ∈ R^{head_dim × head_dim}``:
      y_t = r_t · (S_{t-1} + diag(u) k_t v_t^T),
      S_t = diag(w_t) S_{t-1} + k_t v_t^T
* squared-ReLU channel mix.

Deliberate simplification (noted per DESIGN.md §10): the official Finch uses
a 5-way LoRA tower to make *all* the token-shift mixes data-dependent; we use
static learned mixes for r/k/v/g and reserve the LoRA for the decay ``w`` —
the component the paper's name refers to.  The recurrence itself is exact.

The time scan is ``jax.lax.scan`` over T (compact HLO for the 512-device
dry-run; a chunked-parallel form is a §Perf candidate).  Decode carries
(S, prev_token) per layer — O(1) in context length, which is why rwkv6 runs
the long_500k shape natively.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class RWKVSpec:
    d_model: int
    d_ff: int
    head_dim: int = 64
    decay_lora_rank: int = 64

    @property
    def num_heads(self) -> int:
        return self.d_model // self.head_dim


def init(key, spec: RWKVSpec, *, dtype):
    D, F, H, hd = spec.d_model, spec.d_ff, spec.num_heads, spec.head_dim
    ks = jax.random.split(key, 12)
    return {
        "time_mix": {
            # token-shift mix coefficients (static; see docstring)
            "mix_r": jnp.full((D,), 0.5, dtype),
            "mix_k": jnp.full((D,), 0.5, dtype),
            "mix_v": jnp.full((D,), 0.5, dtype),
            "mix_g": jnp.full((D,), 0.5, dtype),
            "mix_w": jnp.full((D,), 0.5, dtype),
            "wr": layers.dense_init(ks[0], D, (H, hd), dtype=dtype),
            "wk": layers.dense_init(ks[1], D, (H, hd), dtype=dtype),
            "wv": layers.dense_init(ks[2], D, (H, hd), dtype=dtype),
            "wg": layers.dense_init(ks[3], D, (H, hd), dtype=dtype),
            "wo": layers.dense_init(ks[4], H * hd, D, dtype=dtype),
            # data-dependent decay: w_t = exp(-exp(w0 + tanh(x A) B))
            "w0": jnp.full((H, hd), -0.6, dtype),     # ~ decay 0.58
            "w_lora_a": layers.dense_init(ks[5], D, spec.decay_lora_rank,
                                          dtype=dtype),
            "w_lora_b": layers.truncated_normal_init(
                ks[6], (spec.decay_lora_rank, H, hd), 0.01, dtype),
            "u": layers.truncated_normal_init(ks[7], (H, hd), 0.1, dtype),
            "ln_x": layers.layernorm_init(H * hd, dtype=dtype),  # group norm
        },
        "channel_mix": {
            "mix_k": jnp.full((D,), 0.5, dtype),
            "mix_r": jnp.full((D,), 0.5, dtype),
            "wk": layers.dense_init(ks[8], D, F, dtype=dtype),
            "wv": layers.dense_init(ks[9], F, D, dtype=dtype),
            "wr": layers.dense_init(ks[10], D, D, dtype=dtype),
        },
    }


def _token_shift(x, prev):
    """shift right by one: position t sees token t-1; position 0 sees
    ``prev`` (zeros for training start, carried state for decode)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(x, shifted, coeff):
    return x + (shifted - x) * coeff


def wkv_scan(r, k, v, w, u, state):
    """The WKV linear recurrence.

    r,k,v,w: (B, T, H, hd);  u: (H, hd);  state: (B, H, hd, hd).
    Returns (y (B,T,H,hd), final state).  f32 state for stability.
    """
    rf, kf, vf, wf = (t.astype(jnp.float32) for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inputs):
        r_t, k_t, v_t, w_t = inputs                     # (B, H, hd)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)      # (B, H, hd, hd)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S + uf[None, :, :, None] * kv)
        S_new = w_t[..., None] * S + kv
        return S_new, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf))
    final, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1), final


def wkv_chunked(r, k, v, w, u, state, *, chunk: int = 64):
    """Chunked WKV — hillclimb iteration for the T-step scan (EXPERIMENTS
    §Perf/rwkv6): the per-token ``lax.scan`` costs 4096 sequential iterations
    at train_4k whose loop-carried copies dominated the memory roofline
    (measured 1.06e5 s).  This form processes ``chunk`` tokens per step with
    dense intra-chunk einsums (T/chunk steps).

    Numerics: all decay exponents appear as differences A_i - A_j with
    i >= j, so every exp() argument is <= 0 — no overflow for arbitrarily
    strong data-dependent decay (the factored r~ = r*exp(A) / k~ = k*exp(-A)
    matmul trick overflows for exactly that reason and is NOT used).

    Shapes as wkv_scan.  Exact (tests assert allclose vs wkv_scan).
    """
    B, T, H, hd = r.shape
    L = min(chunk, T)
    if T % L != 0:
        return wkv_scan(r, k, v, w, u, state)
    nC = T // L
    rf, kf, vf, wf = (t.astype(jnp.float32).reshape(B, nC, L, H, hd)
                      for t in (r, k, v, w))
    uf = u.astype(jnp.float32)

    strict = jnp.tril(jnp.ones((L, L), bool), k=-1)

    def chunk_step(S, inputs):
        r_c, k_c, v_c, w_c = inputs                  # (B, L, H, hd)
        log_w = jnp.log(jnp.maximum(w_c, 1e-30))
        A = jnp.cumsum(log_w, axis=1)                # inclusive
        A_prev = A - log_w                           # exclusive
        # intra-chunk pair decays D[i,j] = exp(A_{i-1} - A_j), j < i
        D = jnp.exp(A_prev[:, :, None] - A[:, None, :, :])  # (B,L,L,H,hd)
        D = jnp.where(strict[None, :, :, None, None], D, 0.0)
        scores = jnp.einsum("blhd,bmhd,blmhd->blmh", r_c, k_c, D)
        diag = jnp.einsum("blhd,hd,blhd->blh", r_c, uf, k_c)
        y_c = jnp.einsum("blmh,bmhd->blhd", scores, v_c) \
            + diag[..., None] * v_c
        # entering-state contribution + state update
        y_c = y_c + jnp.einsum("blhd,bhdv->blhv",
                               r_c * jnp.exp(A_prev), S)
        decay_end = jnp.exp(A[:, -1:, :] - A)
        kv_inj = jnp.einsum("blhd,blhv->bhdv", k_c * decay_end, v_c)
        S_new = jnp.exp(A[:, -1, :, :])[..., None] * S + kv_inj
        return S_new, y_c

    final, ys = jax.lax.scan(
        chunk_step, state.astype(jnp.float32),
        tuple(jnp.moveaxis(t, 1, 0) for t in (rf, kf, vf, wf)))
    y = jnp.moveaxis(ys, 0, 1)                       # (B, nC, L, H, hd)
    return y.reshape(B, T, H, hd), final


def _wkv_dispatch(r, k, v, w, u, state):
    """Route the WKV chunked compute through shard_map when a mesh is
    ambient: batch -> data, heads -> model, zero internal collectives.

    Measured motivation (EXPERIMENTS §Perf/rwkv6 iteration 2): under plain
    GSPMD the (B, L, L, H, hd) intra-chunk decay tensor came out fully
    replicated (17.2 GB × 8192 scan iterations of phantom traffic) — the
    partitioner cannot infer sharding through the three-operand decay einsum.
    Inside shard_map every operand is already local, so the tensor is
    (B/16, L, L, H/16, hd) per device by construction."""
    from repro.models import meshctx
    from jax.sharding import PartitionSpec as P
    mesh = meshctx.current_mesh()
    B, T, H, hd = r.shape
    if mesh is not None and "model" in mesh.axis_names:
        dd = meshctx.dspec(mesh)
        dn = meshctx.data_size(mesh)
        mp = meshctx.model_size(mesh)
        if B % dn == 0 and H % mp == 0 and dd is not None:
            spec4 = P(dd, None, "model", None)
            # check_rep=False: jax 0.4.x's replication checker mis-infers
            # the carry types when this region sits inside an outer
            # lax.scan (the layer stack / microbatch loops).
            return meshctx.shard_map(
                lambda *a: wkv_chunked(*a),
                mesh=mesh,
                in_specs=(spec4, spec4, spec4, spec4, P("model", None),
                          P(dd, "model", None, None)),
                out_specs=(spec4, P(dd, "model", None, None)),
                check_rep=False,
            )(r, k, v, w, u, state)
    return wkv_chunked(r, k, v, w, u, state)


def time_mix(params, spec: RWKVSpec, x, *, prev_token=None, wkv_state=None):
    """RWKV6 attention replacement.  x: (B,T,D).
    Returns (out, (new_prev_token, new_wkv_state))."""
    p = params
    B, T, D = x.shape
    H, hd = spec.num_heads, spec.head_dim
    if prev_token is None:
        prev_token = jnp.zeros((B, D), x.dtype)
    if wkv_state is None:
        wkv_state = jnp.zeros((B, H, hd, hd), jnp.float32)

    shifted = _token_shift(x, prev_token)
    xr = _mix(x, shifted, p["mix_r"])
    xk = _mix(x, shifted, p["mix_k"])
    xv = _mix(x, shifted, p["mix_v"])
    xg = _mix(x, shifted, p["mix_g"])
    xw = _mix(x, shifted, p["mix_w"])

    r = jnp.einsum("btd,dhk->bthk", xr, p["wr"])
    k = jnp.einsum("btd,dhk->bthk", xk, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", xv, p["wv"])
    g = jax.nn.silu(jnp.einsum("btd,dhk->bthk", xg, p["wg"])
                    .astype(jnp.float32)).astype(x.dtype)

    # data-dependent decay (the Finch contribution)
    lora = jnp.einsum("btr,rhk->bthk",
                      jnp.tanh(jnp.einsum("btd,dr->btr", xw, p["w_lora_a"])
                               .astype(jnp.float32)).astype(x.dtype),
                      p["w_lora_b"])
    w = jnp.exp(-jnp.exp((p["w0"][None, None] + lora).astype(jnp.float32)))

    if T > 1:
        y, new_state = _wkv_dispatch(r, k, v, w, p["u"], wkv_state)
    else:
        y, new_state = wkv_scan(r, k, v, w, p["u"], wkv_state)
    y = y.reshape(B, T, H * hd).astype(x.dtype)
    y = layers.layernorm(p["ln_x"], y)       # Finch's per-head group norm
    y = y * g.reshape(B, T, H * hd)
    out = jnp.einsum("btf,fd->btd", y, p["wo"])
    return out, (x[:, -1, :], new_state)


def channel_mix(params, spec: RWKVSpec, x, *, prev_token=None):
    """Squared-ReLU channel mixing.  Returns (out, new_prev_token)."""
    p = params
    B, T, D = x.shape
    if prev_token is None:
        prev_token = jnp.zeros((B, D), x.dtype)
    shifted = _token_shift(x, prev_token)
    xk = _mix(x, shifted, p["mix_k"])
    xr = _mix(x, shifted, p["mix_r"])
    k = jnp.einsum("btd,df->btf", xk, p["wk"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(jnp.einsum("btd,dd->btd", xr, p["wr"])
                       .astype(jnp.float32)).astype(x.dtype)
    return r * jnp.einsum("btf,fd->btd", k, p["wv"]), x[:, -1, :]

"""Ambient-mesh helpers for model code.

Model functions stay mesh-agnostic on CPU (tests) and pick up the production
sharding strategy automatically under ``jax.set_mesh`` — the same pattern as
models/moe.py's expert-parallel path.
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = True):
    """``jax.shard_map`` on new jax; the experimental module on 0.4.x.

    ``check_rep=False`` disables the replication checker (needed around
    ``lax.while_loop`` bodies on 0.4.x); newer jax dropped the kwarg, where
    we simply ignore it.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=check_rep)
        except TypeError:
            return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_rep)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    ``jax.set_mesh`` on jax >= 0.6; on 0.4.x a ``Mesh`` is itself a context
    manager that sets the thread-local resource env, so we return it as-is.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def current_mesh():
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        try:
            mesh = getter()
        except Exception:  # noqa: BLE001
            return None
        if mesh is None or not mesh.axis_names:
            return None
        return mesh
    # jax 0.4.x: the ambient mesh lives in the thread-local resource env.
    try:
        from jax._src import mesh as _mesh_src
        mesh = _mesh_src.thread_resources.env.physical_mesh
    except Exception:  # noqa: BLE001
        return None
    if mesh is None or mesh.empty or not mesh.axis_names:
        return None
    return mesh


def data_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_size(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1) if "model" in mesh.axis_names else 1


def dspec(mesh):
    ax = data_axes(mesh)
    return ax if len(ax) > 1 else (ax[0] if ax else None)


def sp_applicable(mesh, batch: int, seq: int) -> bool:
    """Sequence-parallel attention needs batch % data == 0 and
    seq % model == 0."""
    if mesh is None or "model" not in mesh.axis_names or not data_axes(mesh):
        return False
    return batch % data_size(mesh) == 0 and seq % model_size(mesh) == 0 \
        and seq >= model_size(mesh) * 16


def constrain(x, spec_tuple):
    """with_sharding_constraint under the ambient mesh (no-op without)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.NamedSharding(mesh, P(*spec_tuple)))

"""Grouped-query attention with the knobs the assigned archs need.

Features: GQA (num_kv_heads <= num_heads), optional QKV bias (Qwen2), optional
q/k RMSNorm (Qwen3), RoPE, causal masking, sliding-window attention (H2O
Danube3; and the long_500k variant for the other dense archs), bidirectional
mode (encoders), cross-attention (Seamless enc-dec), and a single-token decode
path against a KV cache.

The core score/softmax/value computation is factored into ``attention_core``
so the Pallas flash kernel (kernels/attention) can replace it 1:1 on TPU;
the jnp path here is also the kernel's oracle (kernels/attention/ref.py
re-exports it).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class AttentionSpec:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    causal: bool = True
    sliding_window: int | None = None
    rope_theta: float = 1e4
    cross: bool = False        # cross-attention: kv from encoder memory

    @property
    def group_size(self) -> int:
        return self.num_heads // self.num_kv_heads


def init(key, spec: AttentionSpec, *, dtype):
    ks = jax.random.split(key, 5)
    H, KV, hd, D = (spec.num_heads, spec.num_kv_heads, spec.head_dim,
                    spec.d_model)
    p = {
        "wq": layers.dense_init(ks[0], D, (H, hd), dtype=dtype),
        "wk": layers.dense_init(ks[1], D, (KV, hd), dtype=dtype),
        "wv": layers.dense_init(ks[2], D, (KV, hd), dtype=dtype),
        "wo": layers.dense_init(ks[3], H * hd, D, dtype=dtype,
                                scale=(H * hd) ** -0.5),
    }
    if spec.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KV, hd), dtype)
        p["bv"] = jnp.zeros((KV, hd), dtype)
    if spec.qk_norm:
        p["q_norm"] = layers.rmsnorm_init(hd, dtype=dtype)
        p["k_norm"] = layers.rmsnorm_init(hd, dtype=dtype)
    return p


def _project_q(params, spec: AttentionSpec, x, positions):
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"])
    if spec.qkv_bias:
        q = q + params["bq"]
    if spec.qk_norm:
        q = layers.rmsnorm(params["q_norm"], q)
    if not spec.cross:
        q = layers.apply_rope(q, positions, theta=spec.rope_theta)
    return q


def _project_kv(params, spec: AttentionSpec, x, positions):
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"])
    if spec.qkv_bias:
        k = k + params["bk"]
        v = v + params["bv"]
    if spec.qk_norm:
        k = layers.rmsnorm(params["k_norm"], k)
    if not spec.cross:
        k = layers.apply_rope(k, positions, theta=spec.rope_theta)
    return k, v


def attention_core(q, k, v, *, causal: bool, sliding_window: int | None,
                   q_positions=None, kv_positions=None,
                   kv_valid_len=None):
    """Scores/softmax/values for GQA.

    q: (B, Tq, H, hd);  k, v: (B, Tk, KV, hd).  Head grouping is done by
    reshaping q to (B, Tq, KV, G, hd) — no repeat/materialization of kv.

    ``q_positions``/``kv_positions`` (B, T) default to arange (prefill);
    decode passes explicit positions.  ``kv_valid_len`` (B,) masks cache tail.
    """
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Tq, KV, G, hd)
    scale = hd ** -0.5

    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores * scale                                  # (B,KV,G,Tq,Tk)

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(Tq)[None], (B, Tq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(Tk)[None], (B, Tk))
    qp = q_positions[:, None, None, :, None]                 # (B,1,1,Tq,1)
    kp = kv_positions[:, None, None, None, :]                # (B,1,1,1,Tk)

    mask = jnp.ones((B, 1, 1, Tq, Tk), bool)
    if causal:
        mask = mask & (kp <= qp)
    if sliding_window is not None:
        mask = mask & (kp > qp - sliding_window)
    if kv_valid_len is not None:
        valid = kv_positions < kv_valid_len[:, None]
        mask = mask & valid[:, None, None, None, :]

    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(B, Tq, H, hd)


def attention_core_blocked(q, k, v, *, causal: bool,
                           sliding_window: int | None,
                           q_block: int = 512):
    """Memory-bounded attention: Python-unrolled loop over q blocks, each
    attending only to its *statically sliced* causal/window kv prefix.

    This is the XLA-side realization of the Pallas flash kernel's blocking
    (kernels/attention): the (Tq, Tk) score matrix never materializes — peak
    intermediate is (q_block, kv_slice) per head — and, because the loop is
    unrolled with static slices, the lowered HLO contains exactly the useful
    dot ops (no masked-out wasted compute beyond block granularity), which
    keeps the dry-run roofline honest.  Gradients flow through normally.

    Requires default positions (prefill layout, q_pos == kv_pos == arange).
    """
    B, Tq, H, hd = q.shape
    Tk = k.shape[1]
    assert Tq == Tk, "blocked path assumes self-attention prefill layout"
    q_block = min(q_block, Tq)
    n_blocks = (Tq + q_block - 1) // q_block
    outs = []
    for i in range(n_blocks):
        qs, qe = i * q_block, min((i + 1) * q_block, Tq)
        ks = 0
        ke = qe if causal else Tk
        if sliding_window is not None:
            ks = max(0, qs - sliding_window + 1)
        q_blk = q[:, qs:qe]
        k_blk = k[:, ks:ke]
        v_blk = v[:, ks:ke]
        qpos = jnp.broadcast_to(jnp.arange(qs, qe)[None], (B, qe - qs))
        kpos = jnp.broadcast_to(jnp.arange(ks, ke)[None], (B, ke - ks))
        outs.append(attention_core(
            q_blk, k_blk, v_blk, causal=causal,
            sliding_window=sliding_window,
            q_positions=qpos, kv_positions=kpos))
    return jnp.concatenate(outs, axis=1)


# blocked path kicks in above this many query positions (train/prefill)
BLOCKED_ATTENTION_THRESHOLD = 2048


def _online_softmax_attention(q, k, v, *, causal, window, q_pos, kv_block,
                              kv_len):
    """Flash-style online softmax over kv blocks (pure jnp, static loop).

    q: (B, Tq, H, hd) — a query block; k/v: (B, Tk, KV, hd) full;
    q_pos: (B, Tq) absolute positions (traced OK).  Returns (B, Tq, H, hd).

    The static python loop over kv blocks keeps the peak intermediate at
    (Tq, kv_block) scores per head — the XLA analogue of the Pallas kernel's
    VMEM tiling, and exact-FLOP-visible to the dry-run roofline.
    """
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, Tq, KV, G, hd)
    scale = hd ** -0.5
    m = jnp.full((B, KV, G, Tq), -1e30, jnp.float32)
    l = jnp.zeros((B, KV, G, Tq), jnp.float32)
    acc = jnp.zeros((B, Tq, KV, G, hd), jnp.float32)
    n_blocks = (Tk + kv_block - 1) // kv_block
    for i in range(n_blocks):
        ks_, ke_ = i * kv_block, min((i + 1) * kv_block, Tk)
        kb = k[:, ks_:ke_]
        vb = v[:, ks_:ke_]
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kb).astype(jnp.float32)
        s = s * scale
        kv_pos = jnp.arange(ks_, ke_)
        mask = jnp.ones((B, 1, 1, Tq, ke_ - ks_), bool)
        qp = q_pos[:, None, None, :, None]
        kp = kv_pos[None, None, None, None, :]
        if causal:
            mask = mask & (kp <= qp)
        if window is not None:
            mask = mask & (kp > qp - window)
        if kv_len is not None:
            mask = mask & (kp < kv_len[:, None, None, None, None])
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), vb)
        acc = acc * jnp.moveaxis(alpha, 3, 1)[..., None] \
            + pv.astype(jnp.float32)
        m = m_new
    denom = jnp.maximum(jnp.moveaxis(l, 3, 1), 1e-30)[..., None]
    return (acc / denom).reshape(B, Tq, H, hd)


def apply_sequence_parallel(params, spec: AttentionSpec, x, *, memory=None,
                            q_block: int = 256, kv_block: int = 1024):
    """Sequence-parallel attention under shard_map (the production path).

    Motivation (measured — see EXPERIMENTS.md §Perf): naive GSPMD head
    sharding collapses for GQA (num_kv_heads < |model|) and non-divisible
    head counts (minitron 24H, qwen3 40H): the partitioner reshards the
    (B, KV, G, Tq, Tk) score tensors across the contracting dims, emitting
    ~7 GB all-reduces per layer (~14 TB/device/step on qwen2-72b).

    Design: the query positions are sharded over ``model`` (T/|model| per
    rank); k/v are projected locally from each rank's chunk and all-gathered
    over ``model`` (GQA makes kv 2·KV·hd/D ≈ 4-8× smaller than gathering x).
    All score/softmax/value compute is then rank-local with zero further
    collectives, for ANY head count.  Known baseline cost: causal masking is
    applied, not exploited — every rank scans the full kv (≈2× score FLOPs
    waste); recorded as a §Perf candidate (ragged kv bounds).
    """
    from repro.models import meshctx
    from jax.sharding import PartitionSpec as P
    mesh = meshctx.current_mesh()
    B, T, D = x.shape
    dd = meshctx.dspec(mesh)
    mp = meshctx.model_size(mesh)
    t_loc = T // mp
    causal = spec.causal and not spec.cross
    window = spec.sliding_window if not spec.cross else None

    def body(p, x_blk, mem_blk):
        b_loc = x_blk.shape[0]
        offset = jax.lax.axis_index("model") * t_loc
        q_pos_full = offset + jnp.arange(t_loc)
        q = _project_q(p, spec, x_blk,
                       jnp.broadcast_to(q_pos_full[None], (b_loc, t_loc)))
        if spec.cross:
            s_len = mem_blk.shape[1]
            k, v = _project_kv(p, spec, mem_blk, None)
        else:
            kv_pos = jnp.broadcast_to(q_pos_full[None], (b_loc, t_loc))
            k_loc, v_loc = _project_kv(p, spec, x_blk, kv_pos)
            k = jax.lax.all_gather(k_loc, "model", axis=1, tiled=True)
            v = jax.lax.all_gather(v_loc, "model", axis=1, tiled=True)
        outs = []
        n_q = (t_loc + q_block - 1) // q_block
        for i in range(n_q):
            qs_, qe_ = i * q_block, min((i + 1) * q_block, t_loc)
            outs.append(_online_softmax_attention(
                q[:, qs_:qe_], k, v, causal=causal, window=window,
                q_pos=jnp.broadcast_to(
                    (offset + jnp.arange(qs_, qe_))[None],
                    (b_loc, qe_ - qs_)),
                kv_block=kv_block, kv_len=None))
        out = jnp.concatenate(outs, axis=1).astype(x_blk.dtype)
        out = out.reshape(b_loc, t_loc, spec.num_heads * spec.head_dim)
        return jnp.einsum("btf,fd->btd", out, p["wo"])

    mem_spec = P(dd, None, None)
    if memory is None:
        memory = jnp.zeros((B, 1, 1), x.dtype)   # placeholder, unused
    shmap = meshctx.shard_map(
        body, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: P(), params),
                  P(dd, "model", None), mem_spec),
        out_specs=P(dd, "model", None))
    return shmap(params, x, memory)


def apply(params, spec: AttentionSpec, x, *, memory=None, positions=None,
          segment_mask=None):
    """Full-sequence attention (train / prefill).

    ``memory`` (B, S, D) supplies kv for cross-attention.  Returns (B, T, D).
    """
    B, T, _ = x.shape
    from repro.models import meshctx
    mesh = meshctx.current_mesh()
    if positions is None and meshctx.sp_applicable(mesh, B, T) \
            and (memory is None or
                 memory.shape[0] % meshctx.data_size(mesh) == 0):
        return apply_sequence_parallel(params, spec, x, memory=memory)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    kv_src = memory if spec.cross else x
    kv_pos = (jnp.broadcast_to(jnp.arange(kv_src.shape[1])[None],
                               (B, kv_src.shape[1]))
              if spec.cross else positions)
    q = _project_q(params, spec, x, positions)
    k, v = _project_kv(params, spec, kv_src, kv_pos)
    causal = spec.causal and not spec.cross
    window = spec.sliding_window if not spec.cross else None
    if (not spec.cross and T > BLOCKED_ATTENTION_THRESHOLD
            and k.shape[1] == T):
        out = attention_core_blocked(q, k, v, causal=causal,
                                     sliding_window=window)
    else:
        out = attention_core(
            q, k, v, causal=causal, sliding_window=window,
            q_positions=positions, kv_positions=kv_pos)
    out = out.reshape(B, T, spec.num_heads * spec.head_dim)
    return jnp.einsum("btf,fd->btd", out, params["wo"])


# ---------------------------------------------------------------------------
# decode path

def cache_shape(spec: AttentionSpec, batch: int, max_len: int):
    """Physical cache length: a sliding window needs only ``window`` slots
    (ring buffer) — this is what makes long_500k decode sub-quadratic AND
    sub-linear in memory for SWA archs."""
    phys = max_len if spec.sliding_window is None \
        else min(max_len, spec.sliding_window)
    return (batch, phys, spec.num_kv_heads, spec.head_dim)


def init_cache(spec: AttentionSpec, batch: int, max_len: int, *, dtype):
    shape = cache_shape(spec, batch, max_len)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params, spec: AttentionSpec, x, cache, position, *,
                memory=None):
    """One-token decode.  x: (B, 1, D); position: (B,) int32 — the absolute
    position of this token.  Returns (out (B,1,D), new_cache)."""
    B = x.shape[0]
    if spec.cross:
        # cross-attention: kv comes from fixed encoder memory; nothing cached
        # per-step (memory is precomputed outside).
        k, v = _project_kv(params, spec, memory, None)
        q = _project_q(params, spec, x, position[:, None])
        out = attention_core(q, k, v, causal=False, sliding_window=None,
                             q_positions=position[:, None])
        out = out.reshape(B, 1, spec.num_heads * spec.head_dim)
        return jnp.einsum("btf,fd->btd", out, params["wo"]), cache

    q = _project_q(params, spec, x, position[:, None])
    k_new, v_new = _project_kv(params, spec, x, position[:, None])

    phys = cache["k"].shape[1]
    slot = (position % phys)                                  # ring for SWA
    bidx = jnp.arange(B)
    k_cache = cache["k"].at[bidx, slot].set(k_new[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[bidx, slot].set(v_new[:, 0].astype(cache["v"].dtype))

    # absolute positions of every physical slot (ring-aware): slot s holds
    # the most recent token congruent to s mod phys that is <= position.
    slots = jnp.arange(phys)[None, :]                         # (1, phys)
    pos_col = position[:, None]
    kv_positions = pos_col - ((pos_col - slots) % phys)       # (B, phys)
    valid = kv_positions >= 0
    if spec.sliding_window is not None:
        valid = valid & (kv_positions > pos_col - spec.sliding_window)

    out = attention_core(
        q, k_cache, v_cache, causal=True,
        sliding_window=spec.sliding_window,
        q_positions=position[:, None],
        kv_positions=jnp.where(valid, kv_positions, jnp.int32(1) << 30))
    out = out.reshape(B, 1, spec.num_heads * spec.head_dim)
    return (jnp.einsum("btf,fd->btd", out, params["wo"]),
            {"k": k_cache, "v": v_cache})

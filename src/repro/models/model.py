"""Unified model: init / loss / forward / decode for all six families.

The model is selected by ``cfg.family``:

  dense, vlm   — scanned pre-norm GQA decoder (vlm prepends patch embeddings)
  moe          — same skeleton with MoE FFN + router aux loss
  ssm          — RWKV6 stack (token-shift states instead of KV cache)
  hybrid       — Zamba2: groups of Mamba2 blocks + one *shared* attn block
  audio        — Seamless-style encoder (stub frames) + cross-attn decoder

Batch formats (leaves may carry extra leading worker axes; these functions
see one worker's shard):

  train:   {"tokens": (B,T) i32, "labels": (B,T) i32}
           + vlm: {"patches": (B,P,D)}   + audio: {"frames": (B,Te,D)}
  decode:  tokens (B,1) i32, positions (B,) i32, state pytree
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, blocks, layers, mamba, rwkv


# ---------------------------------------------------------------------------
# init

def init(key, cfg: ModelConfig):
    k_embed, k_unembed, k_layers, k_extra = jax.random.split(key, 4)
    params = {
        "embed": layers.embed_init(k_embed, cfg.vocab_size, cfg.d_model,
                                   dtype=cfg.param_dtype),
        "ln_f": layers.rmsnorm_init(cfg.d_model, dtype=cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = layers.dense_init(
            k_unembed, cfg.d_model, cfg.vocab_size, dtype=cfg.param_dtype)

    if cfg.family in ("dense", "vlm", "moe"):
        params["layers"] = blocks.init_stacked(
            lambda k: blocks.init_decoder_block(k, cfg), k_layers,
            cfg.num_layers)
    elif cfg.family == "ssm":
        params["layers"] = blocks.init_stacked(
            lambda k: blocks.init_rwkv_block(k, cfg), k_layers,
            cfg.num_layers)
    elif cfg.family == "hybrid":
        groups, per = _hybrid_shape(cfg)
        keys = jax.random.split(k_layers, groups)
        params["mamba"] = jax.vmap(
            lambda k: blocks.init_stacked(
                lambda kk: blocks.init_mamba_block(kk, cfg), k, per))(keys)
        params["shared"] = blocks.init_decoder_block(k_extra, cfg)
    elif cfg.family == "audio":
        params["layers"] = blocks.init_stacked(
            lambda k: blocks.init_decoder_block(k, cfg, cross=True),
            k_layers, cfg.num_layers)
        k_enc, _ = jax.random.split(k_extra)
        params["encoder"] = blocks.init_stacked(
            lambda k: blocks.init_encoder_block(k, cfg), k_enc,
            cfg.encoder_layers)
        params["enc_ln"] = layers.layernorm_init(cfg.d_model,
                                                 dtype=cfg.param_dtype)
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return params


def _hybrid_shape(cfg: ModelConfig) -> tuple[int, int]:
    every = cfg.shared_attn_every or cfg.num_layers
    if cfg.num_layers % every != 0:
        raise ValueError("num_layers must be divisible by shared_attn_every")
    return cfg.num_layers // every, every


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)

def _embed(params, cfg: ModelConfig, tokens):
    return params["embed"][tokens].astype(cfg.dtype)


def _unembed_fn(params, cfg: ModelConfig):
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    return lambda h: jnp.einsum("...d,dv->...v", h, w)


def _run_encoder(params, cfg: ModelConfig, frames):
    x = frames.astype(cfg.dtype)
    block = blocks.maybe_remat(
        lambda p, h: blocks.encoder_block(p, cfg, h), cfg)

    def body(h, p):
        return block(p, h), None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return layers.layernorm(params["enc_ln"], x, eps=cfg.norm_eps)


def _run_decoder_stack(params_stack, cfg: ModelConfig, x, *, memory=None):
    """Scanned decoder (dense/moe/vlm/audio).  Returns (hidden, aux)."""
    block = blocks.maybe_remat(
        lambda p, h: blocks.decoder_block(p, cfg, h, memory=memory), cfg)

    def body(carry, p):
        h, aux = carry
        h, a = block(p, h)
        return (h, aux + a), None

    (x, aux), _ = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), params_stack)
    return x, aux


def _run_rwkv_stack(params_stack, cfg: ModelConfig, x, *, states=None):
    block = blocks.maybe_remat(
        lambda p, h, s: blocks.rwkv_block(p, cfg, h, state=s), cfg)
    if states is None:
        def body(h, p):
            h, _ = block(p, h, None)
            return h, None
        x, _ = jax.lax.scan(body, x, params_stack)
        return x, None

    def body(h, ps):
        p, s = ps
        h, new_s = block(p, h, s)
        return h, new_s
    x, new_states = jax.lax.scan(body, x, (params_stack, states))
    return x, new_states


def _run_hybrid_stack(params, cfg: ModelConfig, x, *, states=None):
    """Zamba2: [shared attn block, `every` mamba blocks] × groups."""
    mamba_fn = blocks.maybe_remat(
        lambda p, h, s: blocks.mamba_block(p, cfg, h, state=s), cfg)
    shared_fn = blocks.maybe_remat(
        lambda h: blocks.decoder_block(params["shared"], cfg, h)[0], cfg)

    def inner(h, ps):
        p, s = ps
        h, new_s = mamba_fn(p, h, s)
        return h, new_s

    if states is None:
        def group(h, p_group):
            h = shared_fn(h)
            B = h.shape[0]
            spec = blocks.mamba_spec(cfg)
            per = jax.tree.leaves(p_group)[0].shape[0]
            conv0, ssm0 = mamba.init_states(spec, B, dtype=h.dtype)
            init_s = jax.tree.map(
                lambda s: jnp.broadcast_to(s[None], (per,) + s.shape),
                (conv0, ssm0))
            h, _ = jax.lax.scan(inner, h, (p_group, init_s))
            return h, None
        x, _ = jax.lax.scan(group, x, params["mamba"])
        return x, None
    raise NotImplementedError("full-seq hybrid with states: use decode path")


def forward(params, cfg: ModelConfig, batch):
    """Full-sequence hidden states (B, T, D) + aux loss."""
    tokens = batch["tokens"]
    x = _embed(params, cfg, tokens)
    if cfg.family == "vlm":
        patches = batch["patches"].astype(cfg.dtype)
        x = jnp.concatenate([patches, x], axis=1)

    if cfg.family in ("dense", "vlm", "moe"):
        h, aux = _run_decoder_stack(params["layers"], cfg, x)
    elif cfg.family == "ssm":
        h, _ = _run_rwkv_stack(params["layers"], cfg, x)
        aux = jnp.zeros((), jnp.float32)
    elif cfg.family == "hybrid":
        h, _ = _run_hybrid_stack(params, cfg, x)
        aux = jnp.zeros((), jnp.float32)
    elif cfg.family == "audio":
        memory = _run_encoder(params, cfg, batch["frames"])
        h, aux = _run_decoder_stack(params["layers"], cfg, x, memory=memory)
    else:
        raise ValueError(cfg.family)

    h = layers.rmsnorm(params["ln_f"], h, eps=cfg.norm_eps)
    if cfg.family == "vlm":
        h = h[:, batch["patches"].shape[1]:, :]   # text positions only
    return h, aux


def loss_fn(params, batch, cfg: ModelConfig):
    """Mean next-token cross entropy (+ MoE aux)."""
    h, aux = forward(params, cfg, batch)
    ce = layers.cross_entropy_loss(
        _unembed_fn(params, cfg), h, batch["labels"],
        vocab_chunk=cfg.loss_chunk)
    return ce + aux


def logits(params, cfg: ModelConfig, batch):
    """Full logits (small-scale tests only — O(B·T·V) memory)."""
    h, _ = forward(params, cfg, batch)
    return _unembed_fn(params, cfg)(h)


# ---------------------------------------------------------------------------
# decode

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    """State pytree for single-token decoding against a ``max_len`` context.

    For attention families this is the KV cache the decode_32k / long_500k
    shapes size against; for SSM/hybrid it is O(1) recurrent state."""
    spec = blocks.attn_spec(cfg)
    if cfg.family in ("dense", "vlm", "moe"):
        cache = {"self": attention.init_cache(spec, batch, max_len,
                                              dtype=cfg.dtype)}
        cache = jax.tree.map(
            lambda c: jnp.broadcast_to(c[None], (cfg.num_layers,) + c.shape),
            cache)
        return {"cache": cache}
    if cfg.family == "ssm":
        rspec = blocks.rwkv_spec(cfg)
        L, D = cfg.num_layers, cfg.d_model
        H, hd = rspec.num_heads, rspec.head_dim
        return {"states": (
            jnp.zeros((L, batch, D), cfg.dtype),                # prev_tm
            jnp.zeros((L, batch, H, hd, hd), jnp.float32),      # wkv
            jnp.zeros((L, batch, D), cfg.dtype),                # prev_cm
        )}
    if cfg.family == "hybrid":
        groups, per = _hybrid_shape(cfg)
        mspec = blocks.mamba_spec(cfg)
        conv0, ssm0 = mamba.init_states(mspec, batch, dtype=cfg.dtype)
        conv = jax.tree.map(
            lambda s: jnp.broadcast_to(
                s[None, None], (groups, per) + s.shape).copy(), conv0)
        ssm = jnp.broadcast_to(
            ssm0[None, None], (groups, per) + ssm0.shape).copy()
        attn_cache = attention.init_cache(spec, batch, max_len,
                                          dtype=cfg.dtype)
        attn_cache = jax.tree.map(
            lambda c: jnp.broadcast_to(c[None], (groups,) + c.shape),
            attn_cache)
        return {"conv": conv, "ssm": ssm, "attn": attn_cache}
    if cfg.family == "audio":
        cache = {"self": attention.init_cache(spec, batch, max_len,
                                              dtype=cfg.dtype)}
        cache = jax.tree.map(
            lambda c: jnp.broadcast_to(c[None], (cfg.num_layers,) + c.shape),
            cache)
        enc_len = max(max_len // cfg.encoder_seq_divisor, 1)
        enc_len = min(enc_len, 8192)   # encoder memory is bounded (DESIGN §5)
        return {"cache": cache,
                "memory": jnp.zeros((batch, enc_len, cfg.d_model),
                                    cfg.dtype)}
    raise ValueError(cfg.family)


def decode_step(params, cfg: ModelConfig, state, tokens, positions):
    """One decode step.  tokens (B,1) i32, positions (B,) i32.
    Returns (logits (B,1,V), new_state)."""
    x = _embed(params, cfg, tokens)

    if cfg.family in ("dense", "vlm", "moe", "audio"):
        memory = state.get("memory")

        def body(h, ps):
            p, cache = ps
            h, new_cache = blocks.decoder_block_decode(
                p, cfg, h, cache, positions, memory=memory)
            return h, new_cache

        h, new_cache = jax.lax.scan(body, x,
                                    (params["layers"], state["cache"]))
        new_state = dict(state, cache=new_cache)

    elif cfg.family == "ssm":
        def body(h, ps):
            p, s = ps
            h, new_s = blocks.rwkv_block(p, cfg, h, state=s)
            return h, new_s
        h, new_states = jax.lax.scan(body, x,
                                     (params["layers"], state["states"]))
        new_state = {"states": new_states}

    elif cfg.family == "hybrid":
        def group(h, ps):
            p_group, conv_g, ssm_g, cache_g = ps
            h, new_cache = blocks.decoder_block_decode(
                params["shared"], cfg, h, {"self": cache_g}, positions)

            def inner(hh, qs):
                p, conv, ssm = qs
                hh, (new_conv, new_ssm) = blocks.mamba_block_decode(
                    p, cfg, hh, (conv, ssm))
                return hh, (new_conv, new_ssm)

            h, (new_conv_g, new_ssm_g) = jax.lax.scan(
                inner, h, (p_group, conv_g, ssm_g))
            return h, (new_conv_g, new_ssm_g, new_cache["self"])

        h, (new_conv, new_ssm, new_attn) = jax.lax.scan(
            group, x, (params["mamba"], state["conv"], state["ssm"],
                       state["attn"]))
        new_state = {"conv": new_conv, "ssm": new_ssm, "attn": new_attn}
    else:
        raise ValueError(cfg.family)

    h = layers.rmsnorm(params["ln_f"], h, eps=cfg.norm_eps)
    return _unembed_fn(params, cfg)(h), new_state


def prefill(params, cfg: ModelConfig, batch):
    """Score a full prompt and return the hidden states — the prefill_32k
    shape lowers this (labels-free forward)."""
    h, _ = forward(params, cfg, batch)
    return h

"""Mamba2 (SSD) block for the Zamba2 hybrid (arXiv:2411.15242 / Mamba2 SSD).

State-space recurrence with per-head scalar decay:

    h_t = a_t h_{t-1} + dt_t B_t x_t^T        a_t = exp(-dt_t A_h) in (0,1)
    y_t = C_t . h_t + D_h x_t

computed with the **chunked SSD algorithm** (the TPU-native form — see
DESIGN.md §3): the sequence is split into chunks of length ``chunk``; within
a chunk the contribution is a masked (L×L) "attention-like" matmul (MXU
friendly), across chunks a short ``lax.scan`` carries the (H, P, N) state.
This avoids both the T-step sequential scan (latency) and the
``associative_scan`` formulation (materializes T copies of the state —
~85 GB/device at zamba2 train_4k scale).

TP adaptation: the in-projection is stored as separate per-component
matrices (w_z, w_x, w_B, w_C, w_dt) rather than mamba's fused ``in_proj`` —
mathematically identical, but the z/x columns shard cleanly over ``model``
on head boundaries (Din/|model| = 5 heads/rank on zamba2) while the small
B/C/dt projections stay replicated.  The (B,nC,L,L,H) decay mask is then
H-sharded, so no head-blocking loop is needed.

Decode carries (conv states, ssm_state (B, H, P, N)) — O(1) in context,
which is why zamba2 runs long_500k natively.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk: int = 128

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim


def init(key, spec: MambaSpec, *, dtype):
    D, Din, N, H = spec.d_model, spec.d_inner, spec.d_state, spec.num_heads
    ks = jax.random.split(key, 7)
    return {
        "w_z": layers.dense_init(ks[0], D, Din, dtype=dtype),
        "w_x": layers.dense_init(ks[1], D, Din, dtype=dtype),
        "w_B": layers.dense_init(ks[2], D, N, dtype=dtype),
        "w_C": layers.dense_init(ks[3], D, N, dtype=dtype),
        "w_dt": layers.dense_init(ks[4], D, H, dtype=dtype),
        "conv_x": layers.truncated_normal_init(
            ks[5], (spec.conv_kernel, Din), 0.1, dtype),
        "conv_x_b": jnp.zeros((Din,), dtype),
        "conv_B": layers.truncated_normal_init(
            ks[6], (spec.conv_kernel, N), 0.1, dtype),
        "conv_B_b": jnp.zeros((N,), dtype),
        "conv_C": layers.truncated_normal_init(
            jax.random.fold_in(ks[6], 1), (spec.conv_kernel, N), 0.1, dtype),
        "conv_C_b": jnp.zeros((N,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),     # A = exp(A_log) >= 1
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": layers.rmsnorm_init(Din, dtype=dtype),
        "w_out": layers.dense_init(
            jax.random.fold_in(ks[5], 1), Din, D, dtype=dtype),
    }


def _causal_conv(x, w, b, *, state=None):
    """Depthwise causal conv over time.  x: (B, T, C); w: (K, C).
    ``state`` (B, K-1, C) prepends history (decode); returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)          # (B, T+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(K))
    y = y + b[None, None, :]
    return (jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype),
            xp[:, -(K - 1):, :])


def _ssd_chunked(x, dt, A, B_mat, C_mat, spec: MambaSpec, *,
                 init_state=None):
    """Chunked SSD.  Shapes:
        x (B, T, H, P), dt (B, T, H), A (H,), B_mat/C_mat (B, T, N).
    Returns (y (B, T, H, P), final_state (B, H, P, N)) in f32.
    """
    Bsz, T, H, P = x.shape
    N = B_mat.shape[-1]
    L = min(spec.chunk, T)
    assert T % L == 0, f"T={T} must be divisible by chunk={L}"
    nC = T // L

    xf = x.astype(jnp.float32).reshape(Bsz, nC, L, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nC, L, H)
    Bf = B_mat.astype(jnp.float32).reshape(Bsz, nC, L, N)
    Cf = C_mat.astype(jnp.float32).reshape(Bsz, nC, L, N)

    log_a = -dtf * A[None, None, None, :]             # (B, nC, L, H) <= 0
    acum = jnp.cumsum(log_a, axis=2)                  # inclusive
    dtx = dtf[..., None] * xf                         # (B, nC, L, H, P)

    scores = jnp.einsum("bcln,bcmn->bclm", Cf, Bf)    # (B, nC, L, L)
    causal = jnp.tril(jnp.ones((L, L), bool))

    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    # intra-chunk: masked decay "attention" (H-sharded over `model` under TP)
    decay = jnp.exp(acum[:, :, :, None, :] - acum[:, :, None, :, :])
    decay = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    y_intra = jnp.einsum("bclm,bclmh,bcmhp->bclhp", scores, decay, dtx)
    # state injected by each chunk, decayed to chunk end
    decay_end = jnp.exp(acum[:, :, -1:, :] - acum)    # (B, nC, L, H)
    S_chunk = jnp.einsum("bclh,bcln,bclhp->bchpn", decay_end, Bf, dtx)

    # inter-chunk recurrence
    a_total = jnp.exp(acum[:, :, -1, :])              # (B, nC, H)

    def chunk_step(S, inputs):
        a_c, S_c = inputs
        return a_c[..., None, None] * S + S_c, S      # emit state ENTERING

    final_state, S_prev = jax.lax.scan(
        chunk_step, init_state,
        (jnp.moveaxis(a_total, 1, 0), jnp.moveaxis(S_chunk, 1, 0)))
    S_prev = jnp.moveaxis(S_prev, 0, 1)               # (B, nC, H, P, N)

    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp", Cf, jnp.exp(acum), S_prev)
    y = (y_intra + y_inter).reshape(Bsz, T, H, P)
    return y, final_state


def _project(params, spec: MambaSpec, x, conv_state):
    """Shared by apply/decode: projections + causal convs + dt.
    conv_state: None or dict of per-component conv states."""
    p = params
    z = jnp.einsum("btd,di->bti", x, p["w_z"])
    xs = jnp.einsum("btd,di->bti", x, p["w_x"])
    B_mat = jnp.einsum("btd,dn->btn", x, p["w_B"])
    C_mat = jnp.einsum("btd,dn->btn", x, p["w_C"])
    dt = jnp.einsum("btd,dh->bth", x, p["w_dt"])

    cs = conv_state or {}
    xs, cx = _causal_conv(xs, p["conv_x"], p["conv_x_b"],
                          state=cs.get("x"))
    B_mat, cb = _causal_conv(B_mat, p["conv_B"], p["conv_B_b"],
                             state=cs.get("B"))
    C_mat, cc = _causal_conv(C_mat, p["conv_C"], p["conv_C_b"],
                             state=cs.get("C"))
    new_conv = {"x": cx, "B": cb, "C": cc}
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    return z, xs, B_mat, C_mat, dt, new_conv


def apply(params, spec: MambaSpec, x, *, conv_state=None, ssm_state=None):
    """Full Mamba2 block (train / prefill).  x: (B, T, D).
    Returns (out (B, T, D), (new_conv_state, new_ssm_state))."""
    p = params
    Bsz, T, D = x.shape
    Din, H, P = spec.d_inner, spec.num_heads, spec.head_dim

    z, xs, B_mat, C_mat, dt, new_conv = _project(params, spec, x, conv_state)
    A = jnp.exp(p["A_log"])
    xh = xs.reshape(Bsz, T, H, P)
    y, new_ssm = _ssd_chunked(xh, dt, A, B_mat, C_mat, spec,
                              init_state=ssm_state)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(Bsz, T, Din).astype(x.dtype)
    y = layers.rmsnorm(p["norm"], y)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bti,id->btd", y, p["w_out"]), (new_conv, new_ssm)


def decode_step(params, spec: MambaSpec, x, conv_state, ssm_state):
    """Single-token decode.  x: (B, 1, D).  Exact recurrence (T=1)."""
    p = params
    Bsz, _, D = x.shape
    Din, N, H, P = spec.d_inner, spec.d_state, spec.num_heads, spec.head_dim

    z, xs, B_mat, C_mat, dt, new_conv = _project(params, spec, x, conv_state)
    A = jnp.exp(p["A_log"])
    a = jnp.exp(-dt[:, 0] * A[None, :])                               # (B,H)
    xh = xs[:, 0].reshape(Bsz, H, P).astype(jnp.float32)
    Bf = B_mat[:, 0].astype(jnp.float32)
    Cf = C_mat[:, 0].astype(jnp.float32)

    inject = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xh, Bf)
    new_ssm = a[..., None, None] * ssm_state + inject
    y = jnp.einsum("bn,bhpn->bhp", Cf, new_ssm)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(Bsz, 1, Din).astype(x.dtype)
    y = layers.rmsnorm(p["norm"], y)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bti,id->btd", y, p["w_out"]), (new_conv, new_ssm)


def init_states(spec: MambaSpec, batch: int, *, dtype):
    conv = {
        "x": jnp.zeros((batch, spec.conv_kernel - 1, spec.d_inner), dtype),
        "B": jnp.zeros((batch, spec.conv_kernel - 1, spec.d_state), dtype),
        "C": jnp.zeros((batch, spec.conv_kernel - 1, spec.d_state), dtype),
    }
    ssm = jnp.zeros((batch, spec.num_heads, spec.head_dim, spec.d_state),
                    jnp.float32)
    return conv, ssm

"""Fused Pallas TPU round kernel: grads -> batch means -> Weiszfeld, one pass.

The server's per-round hot path (paper Algorithm 2, steps 1-4) was three
separate HBM-level stages in the scan trainer:

    stacked per-worker gradients G (m, d)
      -> k batch means Z (k, d)          [gather + reshape + mean]
      -> norm trimming weights w (k,)    [one pass over Z]
      -> Weiszfeld loop on Z             [2-3 passes over Z per iteration]

This module fuses the whole thing into ONE kernel invocation:

  * G is streamed tile-by-tile (m, TILE_D) — a single HBM read of the
    stacked gradients;
  * batch means are a (k, m) x (m, TILE_D) matmul against the grouping's
    dense membership matrix (``core.grouping.assignment_matrix``), so any
    grouping scheme — contiguous / strided / seeded, even or uneven batch
    sizes — is the same MXU contraction;
  * the (k, d) batch-mean block Z is accumulated into a VMEM-resident
    buffer, and the trim weights (paper Remark 2) AND the full Weiszfeld
    fixed-point loop run on that buffer without touching HBM again; only
    the final aggregate y (d,) is written back.

VMEM budget: the resident set is Z (k, d_pad) + y (d_pad) + one G tile
(m, TILE_D) + S (k, m), all f32.  With k <= 64 this supports d up to
~10^5 per call inside the default 8 MiB cap (``VMEM_BUDGET_BYTES``); the
production dispatcher (``core.aggregators.gmom_aggregator``) falls back to
the unfused jnp path above that, so model-scale leaves keep working.

``round_aggregate_ref`` is the pure-jnp twin that mirrors the kernel's tile
loop and operation order exactly — it is bit-identical to the kernel in
interpret mode (tests/test_round_kernel.py asserts exact equality) and is
the fused formulation benchmarked on non-TPU backends.

``linreg_round_*`` goes one stage further for the paper's linear-regression
substrate (§4): the kernel receives the RAW worker batches (X, y) and the
current iterate theta, computes every worker's full-batch gradient
(1/n) X_j^T (X_j theta - y_j) in-kernel (two streamed passes over X), and
feeds it straight into the same means+trim+Weiszfeld tail — the entire
round of Algorithm 2 as one kernel.

The Weiszfeld loop is an early-exiting ``lax.while_loop`` with the same
stopping rule as the unfused jnp path (squared movement <= tol^2, capped at
``max_iters``).  In-kernel the loop carries ONLY scalars — the iterate
lives in the output ref (``_finish_round``) — which is the
Mosaic-friendliest shape for a data-dependent loop; the jnp reference
(``_weiszfeld_resident``) carries the iterate through an ordinary array
while-carry but computes the identical values iteration for iteration,
which is what makes the kernel/reference pair bit-identical in interpret
mode.  Validating the while-with-ref-state lowering on real TPU hardware
is a recorded ROADMAP follow-up.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.grouping import Grouping, assignment_matrix

# repro: bit-stable — the kernel/reference pair must stay bit-identical in
# interpret mode (tests/test_round_kernel.py): keep the shared op sequence,
# no jnp.sum/jnp.mean over the member axis outside it (repro.verify RV101).

TILE_D = 512
# The declared per-core VMEM capacity the budget is provisioned against
# (TPU v4/v5 class cores carry ~16 MiB).  repro.verify's static VMEM audit
# (RV204) checks VMEM_BUDGET_BYTES <= DEVICE_VMEM_BYTES and that the
# dispatcher's fits_vmem() and the kernel's own _check_vmem() guard agree
# on a shape grid, so the two formulas cannot drift apart silently.
DEVICE_VMEM_BYTES = 16 * 2**20
VMEM_BUDGET_BYTES = 8 * 2**20   # conservative half of DEVICE_VMEM_BYTES


def default_use_pallas(target_backend: str | None = None) -> bool:
    """Whether the fused Pallas kernel is the default lowering.

    ``target_backend`` names the backend the program will RUN on (threaded
    from a ShardSpec by ``aggregators.resolve_round_backend``); None falls
    back to the live host backend."""
    return (target_backend or jax.default_backend()) == "tpu"


def _pad_axis(x, tile: int, axis: int):
    pad = (-x.shape[axis]) % tile
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# building blocks shared verbatim by the kernel and its jnp reference —
# sharing the exact op sequence is what buys bit-equality in interpret mode.

def _median_small(x):
    """``jnp.median`` of a small 1D vector without sorting.

    Mosaic has no in-kernel sort; for the k <= 64 trim-weight median we rank
    every element against every other (O(k^2) compares on the VPU, ties
    broken by index so ranks are a permutation) and select the middle order
    statistic(s) by mask."""
    k = x.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (k, k), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (k, k), 1)
    xi, xj = x[:, None], x[None, :]
    rank = jnp.sum((xj < xi) | ((xj == xi) & (jj < ii)), axis=1)   # (k,)

    def order_stat(r):
        return jnp.sum(jnp.where(rank == r, x, jnp.zeros_like(x)))

    if k % 2 == 1:
        return order_stat(k // 2)
    return 0.5 * (order_stat(k // 2 - 1) + order_stat(k // 2))


def _trim_weights_resident(z, *, trim_multiplier, k):
    """Paper Remark-2 trim weights from the VMEM-resident batch means."""
    if trim_multiplier is None:
        return jnp.ones((k,), jnp.float32)
    norms = jnp.sqrt(jnp.sum(z * z, axis=1))
    tau = trim_multiplier * _median_small(norms) + 1e-12
    w = (norms <= tau).astype(jnp.float32)
    return jnp.where(jnp.sum(w) > 0, w, jnp.ones_like(w))


def _weiszfeld_init(z, w, eps):
    """Weighted-mean initial iterate (the k=1 aggregate), shape (1, d)."""
    w_sum = jnp.maximum(jnp.sum(w), eps)
    return jnp.dot(w.reshape(1, z.shape[0]), z,
                   preferred_element_type=jnp.float32) / w_sum


def _weiszfeld_step_vals(z, w, y, *, eps):
    """One Weiszfeld update on the resident block: (y_new, squared move)."""
    diff = z - y                                   # (k, d)
    sq = jnp.sum(diff * diff, axis=1)              # (k,)
    dist = jnp.sqrt(sq + eps * eps)
    inv = w / dist
    denom = jnp.maximum(jnp.sum(inv), eps)
    y_new = jnp.dot((inv / denom).reshape(1, z.shape[0]), z,
                    preferred_element_type=jnp.float32)
    return y_new, jnp.sum((y_new - y) ** 2)


def _weiszfeld_resident(z, w, *, max_iters, tol, eps):
    """Full Weiszfeld loop on a resident (k, d) block -> (1, d) median.

    Early-exiting loop: stop when the squared movement drops to tol^2 or
    after ``max_iters`` steps — the same stopping rule as the unfused jnp
    path.  The kernels inline the identical step with the iterate held in
    the output ref and only scalars in the while carry (``_finish_round``),
    so both forms compute the same values iteration for iteration."""
    def cond(carry):
        _, it, delta2 = carry
        return jnp.logical_and(it < max_iters, delta2 > tol * tol)

    def body(carry):
        y, it, _ = carry
        y_new, delta2 = _weiszfeld_step_vals(z, w, y, eps=eps)
        return y_new, it + 1, delta2

    y, _, _ = jax.lax.while_loop(
        cond, body, (_weiszfeld_init(z, w, eps),
                     jnp.zeros((), jnp.int32),
                     jnp.array(jnp.inf, jnp.float32)))
    return y


def _means_trim_weiszfeld(z, *, k, trim_multiplier, max_iters, tol, eps):
    w = _trim_weights_resident(z, trim_multiplier=trim_multiplier, k=k)
    return _weiszfeld_resident(z, w, max_iters=max_iters, tol=tol, eps=eps)


def _finish_round(z, y_ref, *, trim_multiplier, max_iters, tol, eps):
    """In-kernel tail: trim + Weiszfeld with the iterate living in the
    output ref.  The while carry holds only scalars (iteration count and
    last squared movement) — the Mosaic-friendly loop shape — while every
    per-iteration value matches ``_weiszfeld_resident`` exactly."""
    k = z.shape[0]
    w = _trim_weights_resident(z, trim_multiplier=trim_multiplier, k=k)
    y_ref[...] = _weiszfeld_init(z, w, eps)

    def cond(carry):
        it, delta2 = carry
        return jnp.logical_and(it < max_iters, delta2 > tol * tol)

    def body(carry):
        it, _ = carry
        y_new, delta2 = _weiszfeld_step_vals(z, w, y_ref[...], eps=eps)
        y_ref[...] = y_new
        return it + 1, delta2

    jax.lax.while_loop(cond, body, (jnp.zeros((), jnp.int32),
                                    jnp.array(jnp.inf, jnp.float32)))


# ---------------------------------------------------------------------------
# kernel 1: stacked gradients -> aggregate   (the scan trainer's hot path)

def _round_kernel(g_ref, s_ref, bsz_ref, y_ref, z_ref, *, n_tiles, tile_d,
                  trim_multiplier, max_iters, tol, eps):
    """Grid over d-tiles; z_ref is the VMEM-resident (k, d_pad) accumulator
    (an output revisited by every step so it persists across the grid)."""
    i = pl.program_id(0)
    sums = jnp.dot(s_ref[...], g_ref[...],
                   preferred_element_type=jnp.float32)      # (k, tile_d)
    z_ref[:, pl.ds(i * tile_d, tile_d)] = sums / bsz_ref[...]

    @pl.when(i == n_tiles - 1)
    def _finish():
        _finish_round(z_ref[...], y_ref, trim_multiplier=trim_multiplier,
                      max_iters=max_iters, tol=tol, eps=eps)


def round_resident_bytes(m: int, k: int, d: int,
                         tile_d: int = TILE_D) -> int:
    """VMEM-resident f32 footprint of ``round_aggregate_kernel``: the Z
    block + y output + one streamed G tile + the membership matrix.  The
    dispatcher (``core.aggregators.resolve_round_backend``) and the kernel's
    own guard use this same formula, so 'auto' never dispatches a shape the
    kernel would reject."""
    d_pad = -(-d // tile_d) * tile_d
    return ((k + 1) * d_pad + m * tile_d + k * m) * 4


def fits_vmem(m: int, k: int, d: int, tile_d: int = TILE_D) -> bool:
    return round_resident_bytes(m, k, d, tile_d) <= VMEM_BUDGET_BYTES


def _check_vmem(k: int, d_pad: int, extra_bytes: int = 0):
    resident = (k + 1) * d_pad * 4 + extra_bytes
    if resident > VMEM_BUDGET_BYTES:
        raise ValueError(
            f"fused round kernel resident set {resident} B (k={k}, "
            f"d_pad={d_pad}) exceeds VMEM budget {VMEM_BUDGET_BYTES} B; "
            "use the unfused jnp path (round_backend='reference')")


@functools.partial(jax.jit, static_argnames=(
    "grouping", "trim_multiplier", "max_iters", "tol", "eps", "tile_d",
    "interpret"))
def round_aggregate_kernel(stacked_grads, grouping: Grouping, *,
                           trim_multiplier: float | None = 3.0,
                           max_iters: int = 64, tol: float = 1e-8,
                           eps: float = 1e-12, tile_d: int = TILE_D,
                           interpret: bool = False):
    """Fused GMoM round: stacked (m, d) gradients -> (d,) aggregate.

    One HBM read of the stacked gradients; batch means, Remark-2 trimming,
    and the entire Weiszfeld loop happen on the VMEM-resident (k, d) block.
    Bit-identical to ``round_aggregate_ref`` in interpret mode.
    """
    m, d = stacked_grads.shape
    k = grouping.num_batches
    g = _pad_axis(stacked_grads.astype(jnp.float32), tile_d, 1)
    d_pad = g.shape[1]
    n_tiles = d_pad // tile_d
    _check_vmem(k, d_pad, extra_bytes=(m * tile_d + k * m) * 4)
    s = jnp.asarray(assignment_matrix(grouping))
    bsz = jnp.asarray(grouping.batch_sizes, jnp.float32).reshape(k, 1)

    y, _ = pl.pallas_call(
        functools.partial(_round_kernel, n_tiles=n_tiles, tile_d=tile_d,
                          trim_multiplier=trim_multiplier,
                          max_iters=max_iters, tol=tol, eps=eps),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((m, tile_d), lambda i: (0, i)),
            pl.BlockSpec((k, m), lambda i: (0, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d_pad), lambda i: (0, 0)),
            pl.BlockSpec((k, d_pad), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((k, d_pad), jnp.float32),
        ],
        interpret=interpret,
    )(g, s, bsz)
    return y[0, :d]


@functools.partial(jax.jit, static_argnames=(
    "grouping", "trim_multiplier", "max_iters", "tol", "eps", "tile_d"))
def round_aggregate_ref(stacked_grads, grouping: Grouping, *,
                        trim_multiplier: float | None = 3.0,
                        max_iters: int = 64, tol: float = 1e-8,
                        eps: float = 1e-12, tile_d: int = TILE_D):
    """jnp twin of ``round_aggregate_kernel``: same ops, same reductions.

    This is the fused formulation on non-TPU backends (one membership
    matmul for the means, early-exiting flat-block Weiszfeld) and the
    bit-exact oracle for the kernel in interpret mode.  The
    means are ONE flat dot rather than a d-tile loop: the contraction runs
    over the worker axis only, so every output column depends on exactly
    one input column and the d-tiling of the kernel cannot change any
    reduction order (tests/test_round_kernel.py asserts exact equality).
    Only the small (k, d) mean block is padded — the kernel's padded-G
    tiles produce exactly-zero padded mean columns, so padding Z after the
    matmul is bitwise the same and skips an O(m d) copy.
    """
    m, d = stacked_grads.shape
    k = grouping.num_batches
    g = stacked_grads.astype(jnp.float32)
    s = jnp.asarray(assignment_matrix(grouping))
    bsz = jnp.asarray(grouping.batch_sizes, jnp.float32).reshape(k, 1)
    z = jnp.dot(s, g, preferred_element_type=jnp.float32) / bsz
    z = _pad_axis(z, tile_d, 1)
    y = _means_trim_weiszfeld(z, k=k, trim_multiplier=trim_multiplier,
                              max_iters=max_iters, tol=tol, eps=eps)
    return y[0, :d]


def round_aggregate_pytree(stacked_grads, grouping: Grouping, *,
                           trim_multiplier: float | None = 3.0,
                           max_iters: int = 64, tol: float = 1e-8,
                           eps: float = 1e-12, tile_d: int = TILE_D,
                           use_pallas: bool | None = None,
                           interpret: bool = False):
    """Pytree front door: stacked (m, ...) gradient pytree -> aggregate.

    Leaves are flattened and concatenated into one (m, D) f32 block (the
    geometric median is taken in the concatenated R^D, exactly like
    ``core.geometric_median_pytree``) and the result is split back, cast to
    each leaf's dtype.  Compute is f32 throughout.
    """
    leaves, treedef = jax.tree.flatten(stacked_grads)
    m = leaves[0].shape[0]
    flat = [l.reshape(m, -1).astype(jnp.float32) for l in leaves]
    block = flat[0] if len(flat) == 1 else jnp.concatenate(flat, axis=1)
    use_pallas = default_use_pallas() if use_pallas is None else use_pallas
    fn = (round_aggregate_kernel if (use_pallas or interpret)
          else round_aggregate_ref)
    kwargs = dict(trim_multiplier=trim_multiplier, max_iters=max_iters,
                  tol=tol, eps=eps, tile_d=tile_d)
    if use_pallas or interpret:
        kwargs["interpret"] = interpret
    y = fn(block, grouping, **kwargs)
    out, offset = [], 0
    for l in leaves:
        size = int(np.prod(l.shape[1:], dtype=np.int64)) if l.ndim > 1 else 1
        piece = jax.lax.slice_in_dim(y, offset, offset + size, axis=0)
        out.append(piece.reshape(l.shape[1:]).astype(l.dtype))
        offset += size
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# kernel 2: raw linreg batches -> aggregate  (the whole round in-kernel)

def _linreg_round_kernel(x_ref, t_ref, theta_ref, s_ref, bsz_ref,
                         y_ref, r_ref, z_ref, *, n_tiles, tile_d, inv_n,
                         trim_multiplier, max_iters, tol, eps):
    """Grid (2, n_tiles).  Phase 0 streams X to build the residual
    R = X @ theta - y (resident, (m, n)); phase 1 streams X again to form
    each worker's gradient tile (1/n) X^T R, contracts it with the
    membership matrix into the resident batch means, and finishes with the
    same trim + Weiszfeld tail as the gradient-input kernel.  X is read
    twice and nothing else touches HBM."""
    phase = pl.program_id(0)
    i = pl.program_id(1)
    x = x_ref[...]                                     # (m, n, tile_d)
    theta_t = theta_ref[...]                           # (1, tile_d)

    @pl.when(phase == 0)
    def _residual():
        @pl.when(i == 0)
        def _init():
            r_ref[...] = -t_ref[...]
        # R += X[:, :, tile] @ theta[tile]
        part = jax.lax.dot_general(
            x, theta_t.reshape(tile_d, 1),
            dimension_numbers=(((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)        # (m, n, 1)
        r_ref[...] += part[..., 0]

    @pl.when(phase == 1)
    def _grads_means():
        r = r_ref[...]                                 # (m, n)
        # worker gradients for this tile: (1/n) X_j^T r_j, all j at once
        g = jax.lax.dot_general(
            r, x, dimension_numbers=(((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * inv_n  # (m, tile_d)
        sums = jnp.dot(s_ref[...], g,
                       preferred_element_type=jnp.float32)
        z_ref[:, pl.ds(i * tile_d, tile_d)] = sums / bsz_ref[...]

        @pl.when(i == n_tiles - 1)
        def _finish():
            _finish_round(z_ref[...], y_ref, trim_multiplier=trim_multiplier,
                          max_iters=max_iters, tol=tol, eps=eps)


@functools.partial(jax.jit, static_argnames=(
    "grouping", "trim_multiplier", "max_iters", "tol", "eps", "tile_d",
    "interpret"))
def linreg_round_kernel(features, targets, theta, grouping: Grouping, *,
                        trim_multiplier: float | None = 3.0,
                        max_iters: int = 64, tol: float = 1e-8,
                        eps: float = 1e-12, tile_d: int = 256,
                        interpret: bool = False):
    """One FULL failure-free round of Algorithm 2 on the linreg substrate:
    (X (m, n, d), y (m, n), theta (d,)) -> robust aggregate gradient (d,).

    The per-worker full-batch gradients (1/n) X_j^T (X_j theta - y_j) are
    computed in-kernel — the raw batches never materialize a gradient,
    batch-mean, or distance tensor in HBM.
    """
    m, n, d = features.shape
    k = grouping.num_batches
    x = _pad_axis(features.astype(jnp.float32), tile_d, 2)
    d_pad = x.shape[2]
    n_tiles = d_pad // tile_d
    _check_vmem(k, d_pad,
                extra_bytes=(m * n * tile_d + m * n + k * m) * 4)
    theta_p = _pad_axis(theta.astype(jnp.float32).reshape(1, d), tile_d, 1)
    s = jnp.asarray(assignment_matrix(grouping))
    bsz = jnp.asarray(grouping.batch_sizes, jnp.float32).reshape(k, 1)

    y, _, _ = pl.pallas_call(
        functools.partial(_linreg_round_kernel, n_tiles=n_tiles,
                          tile_d=tile_d, inv_n=1.0 / n,
                          trim_multiplier=trim_multiplier,
                          max_iters=max_iters, tol=tol, eps=eps),
        grid=(2, n_tiles),
        in_specs=[
            pl.BlockSpec((m, n, tile_d), lambda p, i: (0, 0, i)),
            pl.BlockSpec((m, n), lambda p, i: (0, 0)),
            pl.BlockSpec((1, tile_d), lambda p, i: (0, i)),
            pl.BlockSpec((k, m), lambda p, i: (0, 0)),
            pl.BlockSpec((k, 1), lambda p, i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d_pad), lambda p, i: (0, 0)),
            pl.BlockSpec((m, n), lambda p, i: (0, 0)),
            pl.BlockSpec((k, d_pad), lambda p, i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, d_pad), jnp.float32),
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((k, d_pad), jnp.float32),
        ],
        interpret=interpret,
    )(x, targets.astype(jnp.float32), theta_p, s, bsz)
    return y[0, :d]


@functools.partial(jax.jit, static_argnames=(
    "grouping", "trim_multiplier", "max_iters", "tol", "eps", "tile_d"))
def linreg_round_ref(features, targets, theta, grouping: Grouping, *,
                     trim_multiplier: float | None = 3.0,
                     max_iters: int = 64, tol: float = 1e-8,
                     eps: float = 1e-12, tile_d: int = 256):
    """jnp twin of ``linreg_round_kernel`` (same tiling and op order): the
    bit-exact interpret-mode oracle.  Unlike the gradient-input case, the
    residual accumulates over d-tiles (the contraction runs over the tiled
    axis), so the mirror must replay the kernel's tile loop and partial-sum
    chaining exactly; benchmarks use ``linreg_round_fused`` — the same
    algorithm without the tile structure — on non-TPU backends."""
    m, n, d = features.shape
    k = grouping.num_batches
    x = _pad_axis(features.astype(jnp.float32), tile_d, 2)
    d_pad = x.shape[2]
    n_tiles = d_pad // tile_d
    theta_p = _pad_axis(theta.astype(jnp.float32).reshape(1, d), tile_d, 1)
    s = jnp.asarray(assignment_matrix(grouping))
    bsz = jnp.asarray(grouping.batch_sizes, jnp.float32).reshape(k, 1)
    inv_n = 1.0 / n

    r = -targets.astype(jnp.float32)
    xt = [jax.lax.slice_in_dim(x, i * tile_d, (i + 1) * tile_d, axis=2)
          for i in range(n_tiles)]
    tt = [jax.lax.slice_in_dim(theta_p, i * tile_d, (i + 1) * tile_d, axis=1)
          for i in range(n_tiles)]
    for i in range(n_tiles):
        part = jax.lax.dot_general(
            xt[i], tt[i].reshape(tile_d, 1),
            dimension_numbers=(((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        r = r + part[..., 0]
    tiles = []
    for i in range(n_tiles):
        g = jax.lax.dot_general(
            r, xt[i], dimension_numbers=(((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32) * inv_n
        tiles.append(jnp.dot(s, g, preferred_element_type=jnp.float32)
                     / bsz)
    z = jnp.concatenate(tiles, axis=1) if n_tiles > 1 else tiles[0]
    y = _means_trim_weiszfeld(z, k=k, trim_multiplier=trim_multiplier,
                              max_iters=max_iters, tol=tol, eps=eps)
    return y[0, :d]


@functools.partial(jax.jit, static_argnames=(
    "grouping", "trim_multiplier", "max_iters", "tol", "eps"))
def linreg_round_fused(features, targets, theta, grouping: Grouping, *,
                       trim_multiplier: float | None = 3.0,
                       max_iters: int = 64, tol: float = 1e-8,
                       eps: float = 1e-12):
    """The fused full-round formulation for non-TPU backends: same algorithm
    as ``linreg_round_kernel`` (analytic per-worker gradients -> membership
    matmul means -> resident trim + Weiszfeld), written as flat jnp so XLA
    lowers it well on CPU/GPU.  Agrees with the kernel to float tolerance
    (reduction orders differ along d); the benchmark's "fused" entrant on
    this container's backend."""
    m, n, d = features.shape
    k = grouping.num_batches
    x = features.astype(jnp.float32)
    s = jnp.asarray(assignment_matrix(grouping))
    bsz = jnp.asarray(grouping.batch_sizes, jnp.float32).reshape(k, 1)
    theta = theta.astype(jnp.float32)
    r = jax.lax.dot_general(
        x, theta.reshape(d, 1),
        dimension_numbers=(((2,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)[..., 0] \
        - targets.astype(jnp.float32)                       # (m, n)
    g = jax.lax.dot_general(
        r, x, dimension_numbers=(((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * (1.0 / n)     # (m, d)
    z = jnp.dot(s, g, preferred_element_type=jnp.float32) / bsz
    y = _means_trim_weiszfeld(z, k=k, trim_multiplier=trim_multiplier,
                              max_iters=max_iters, tol=tol, eps=eps)
    return y[0, :d]

"""Pure-jnp oracle for the Weiszfeld-iteration kernel."""

from __future__ import annotations

import jax.numpy as jnp


def weiszfeld_distances_ref(points, y, *, eps: float = 1e-12):
    """Squared-distance accumulation: ||z_i - y||^2 per point.
    points: (k, d) f32, y: (d,) f32 -> (k,) f32."""
    diff = points.astype(jnp.float32) - y.astype(jnp.float32)[None, :]
    return jnp.sum(diff * diff, axis=-1)


def weiszfeld_reweight_ref(points, inv_weights):
    """Weighted sum: sum_i w_i z_i.  points: (k, d), inv_weights: (k,)
    -> (d,) f32 (normalization happens outside, it is O(k))."""
    return jnp.einsum("k,kd->d", inv_weights.astype(jnp.float32),
                      points.astype(jnp.float32))


def weiszfeld_step_ref(points, y, weights, *, eps: float = 1e-12):
    """One full Weiszfeld step (matches core.geometric_median.weiszfeld_step).
    points: (k, d), y: (d,), weights: (k,) -> (d,)."""
    sq = weiszfeld_distances_ref(points, y)
    dist = jnp.sqrt(sq + eps * eps)
    inv = weights.astype(jnp.float32) / dist
    denom = jnp.maximum(jnp.sum(inv), eps)
    return weiszfeld_reweight_ref(points, inv) / denom

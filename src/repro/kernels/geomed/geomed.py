"""Pallas TPU kernel: fused Weiszfeld iteration for the geometric median.

The server-side hot spot of the paper's Algorithm 2 is the Weiszfeld loop
over the k batch-mean gradients Z (k, d) with d up to ~10^9 elements (the
flattened model gradient shard).  The naive jnp implementation makes three
HBM passes over Z per iteration (diff, square-reduce, weighted-sum); this
kernel fuses each phase into d-tiled single passes with the (k, TILE_D)
working set resident in VMEM:

  phase 1 (``sqdist``):   partial  ||z_i - y||^2  accumulated across the
                          d-tile grid into a (k,) output — one HBM read of Z.
  phase 2 (``reweight``): y_new_tile = sum_i w_i z_i[tile] — one HBM read.

k <= 64 and TILE_D = 512 keeps the block at 64*512*4B = 128 KiB — far under
the ~16 MiB VMEM budget, leaving room for double buffering.  The d axis is
tiled by the grid; the k axis is kept whole inside the block (the reduction
over k is the minor matmul dim => VPU/MXU friendly).

The surrounding while-loop (convergence check) stays in jax.lax.while_loop —
it is O(k) work per iteration and does not touch Z.

Validated in interpret mode on CPU against ref.py (tests/test_geomed_kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_D = 512


def _sqdist_kernel(z_ref, y_ref, out_ref):
    """Grid over d-tiles; accumulates partial squared distances into (k,)."""
    i = pl.program_id(0)
    diff = z_ref[...].astype(jnp.float32) - y_ref[...].astype(jnp.float32)
    partial = jnp.sum(diff * diff, axis=1)          # (k,)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(i > 0)
    def _acc():
        out_ref[...] += partial


def _reweight_kernel(z_ref, w_ref, out_ref):
    """y_new[tile] = sum_k w_k * z[k, tile] — per-tile weighted reduction."""
    z = z_ref[...].astype(jnp.float32)              # (k, TILE_D)
    w = w_ref[...].astype(jnp.float32)              # (1, k)
    out_ref[...] = (w @ z)                          # (1, TILE_D)


def _pad_to_tile(x, tile, axis):
    size = x.shape[axis]
    pad = (-size) % tile
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def sqdist(points, y, *, tile_d: int = TILE_D, interpret: bool = False):
    """||z_i - y||^2 for each row.  points: (k, d), y: (d,) -> (k,) f32."""
    k, d = points.shape
    points = _pad_to_tile(points.astype(jnp.float32), tile_d, 1)
    y = _pad_to_tile(y.astype(jnp.float32), tile_d, 0)
    dp = points.shape[1]
    grid = (dp // tile_d,)
    return pl.pallas_call(
        _sqdist_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, tile_d), lambda i: (0, i)),
            pl.BlockSpec((tile_d,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((k,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((k,), jnp.float32),
        interpret=interpret,
    )(points, y)


def reweight(points, inv_weights, *, tile_d: int = TILE_D,
             interpret: bool = False):
    """sum_i w_i z_i.  points: (k, d), inv_weights: (k,) -> (d,) f32."""
    k, d = points.shape
    points = _pad_to_tile(points.astype(jnp.float32), tile_d, 1)
    dp = points.shape[1]
    w = inv_weights.astype(jnp.float32).reshape(1, k)
    grid = (dp // tile_d,)
    out = pl.pallas_call(
        _reweight_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, tile_d), lambda i: (0, i)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=interpret,
    )(points, w)
    return out[0, :d]


def weiszfeld_step(points, y, weights, *, eps: float = 1e-12,
                   tile_d: int = TILE_D, interpret: bool = False):
    """One fused Weiszfeld step (kernel-backed).  Matches ref.py exactly."""
    sq = sqdist(points, y, tile_d=tile_d, interpret=interpret)
    dist = jnp.sqrt(sq + eps * eps)
    inv = weights.astype(jnp.float32) / dist
    denom = jnp.maximum(jnp.sum(inv), eps)
    return reweight(points, inv, tile_d=tile_d, interpret=interpret) / denom

"""Jit'd public wrapper for the geomed kernel.

``geometric_median_kernel`` runs the full Weiszfeld loop with the fused
Pallas step.  On non-TPU backends (this container) the kernel runs in
interpret mode inside tests; production entry points select the jnp path
unless ``use_pallas`` is forced, mirroring kernels/attention/ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.geomed import geomed, ref


def default_use_pallas() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("max_iters", "use_pallas",
                                             "interpret"))
def geometric_median_kernel(points, weights=None, *, max_iters: int = 64,
                            tol: float = 1e-8, use_pallas: bool | None = None,
                            interpret: bool = False):
    """(1+gamma)-approx geometric median of ``points`` (k, d) via Weiszfeld
    with the fused Pallas step.  Drop-in for core.geometric_median."""
    k, d = points.shape
    if weights is None:
        weights = jnp.ones((k,), jnp.float32)
    use_pallas = default_use_pallas() if use_pallas is None else use_pallas

    if use_pallas or interpret:
        step = functools.partial(geomed.weiszfeld_step, interpret=interpret)
    else:
        step = ref.weiszfeld_step_ref

    w_sum = jnp.maximum(jnp.sum(weights), 1e-12)
    y0 = (weights @ points.astype(jnp.float32)) / w_sum

    def cond(carry):
        _, it, delta = carry
        return jnp.logical_and(it < max_iters, delta > tol)

    def body(carry):
        y, it, _ = carry
        y_new = step(points, y, weights)
        return y_new, it + 1, jnp.linalg.norm(y_new - y)

    y, _, _ = jax.lax.while_loop(
        cond, body, (y0, jnp.zeros((), jnp.int32),
                     jnp.array(jnp.inf, jnp.float32)))
    return y

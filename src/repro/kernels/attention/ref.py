"""Pure-jnp oracle for the flash-attention kernel.

The oracle IS the model's attention path (models.attention.attention_core),
so kernel == model semantics by construction.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import attention_core


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        sliding_window: int | None = None):
    """q: (B, Tq, H, hd); k, v: (B, Tk, KV, hd) -> (B, Tq, H, hd)."""
    return attention_core(q, k, v, causal=causal,
                          sliding_window=sliding_window)

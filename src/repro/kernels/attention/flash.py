"""Pallas TPU kernel: blocked flash attention (causal / sliding-window GQA).

The compute hot spot of every attention-family architecture.  Online-softmax
blocked attention (Dao et al.) adapted to the TPU memory hierarchy:

* grid = (batch x q_heads, q_blocks, kv_blocks); the TPU grid is executed
  sequentially with the last axis minor, so the kv axis acts as the inner
  accumulation loop, with running max/denominator/accumulator in VMEM
  scratch (no HBM traffic for the O(Tq x Tk) score matrix — it never exists).
* q/k/v tiles sit in VMEM; (block_q, block_kv) = (256, 256) by default at
  f32 costs 256·128·4·3 ≈ 400 KiB for the tiles plus 256·128·4 scratch —
  comfortably inside the ~16 MiB VMEM with double buffering, and the
  (256, 128)·(128, 256) partial matmuls are MXU-shaped (multiples of 128 on
  head_dim and both block dims).
* GQA is handled in the k/v BlockSpec index maps (q head -> kv head =
  h · KV // H) — kv tiles are never replicated in HBM.
* sliding windows just tighten the per-element mask; fully-masked kv blocks
  are wasted work in this baseline (skipping them is a recorded §Perf
  candidate — see EXPERIMENTS.md).

Validated in interpret mode against ref.py (= the model's attention path)
over shape/dtype sweeps in tests/test_attention_kernel.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_KV = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int | None, block_q: int,
                  block_kv: int, num_kv_blocks: int, scale: float):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bkv, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    iq = pl.program_id(1)
    q_pos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    mask = jnp.ones((block_q, block_kv), jnp.bool_)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                               # (bq,)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_prev + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + p @ v
    m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    sliding_window: int | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_kv: int = DEFAULT_BLOCK_KV,
                    interpret: bool = False):
    """q: (B, Tq, H, hd); k, v: (B, Tk, KV, hd) -> (B, Tq, H, hd).

    H must be a multiple of KV (GQA).  Tq/Tk are padded to block multiples
    internally; the causal mask makes padded kv positions unreachable for
    real q rows, and padded q rows are sliced away.
    """
    B, Tq, H, hd = q.shape
    Tk, KV = k.shape[1], k.shape[2]
    assert H % KV == 0

    block_q = min(block_q, max(Tq, 16))
    block_kv = min(block_kv, max(Tk, 16))
    pad_q = (-Tq) % block_q
    pad_k = (-Tk) % block_kv

    # layout: (B*H, T, hd) with heads folded into batch
    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Tq, hd)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * KV, Tk, hd)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * KV, Tk, hd)
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kh = jnp.pad(kh, ((0, 0), (0, pad_k), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad_k), (0, 0)))
    Tqp, Tkp = Tq + pad_q, Tk + pad_k
    nq, nk = Tqp // block_q, Tkp // block_kv
    G = H // KV

    def kv_index(bh, iq, ik):
        return ((bh // H) * KV + (bh % H) // G, ik, 0)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=sliding_window,
        block_q=block_q, block_kv=block_kv, num_kv_blocks=nk,
        scale=hd ** -0.5)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_kv, hd), kv_index),
            pl.BlockSpec((1, block_kv, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tqp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)

    out = out[:, :Tq, :].reshape(B, H, Tq, hd)
    return jnp.moveaxis(out, 1, 2)

"""Jit'd public wrapper for the flash-attention kernel.

``attention(...)`` dispatches to the Pallas kernel on TPU and to the jnp
reference elsewhere, so model code can call one entry point everywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.attention import flash, ref


def default_use_pallas() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "sliding_window", "block_q", "block_kv", "use_pallas",
    "interpret"))
def attention(q, k, v, *, causal: bool = True,
              sliding_window: int | None = None,
              block_q: int = flash.DEFAULT_BLOCK_Q,
              block_kv: int = flash.DEFAULT_BLOCK_KV,
              use_pallas: bool | None = None,
              interpret: bool = False):
    """q: (B, Tq, H, hd); k, v: (B, Tk, KV, hd) -> (B, Tq, H, hd)."""
    use_pallas = default_use_pallas() if use_pallas is None else use_pallas
    if use_pallas or interpret:
        return flash.flash_attention(
            q, k, v, causal=causal, sliding_window=sliding_window,
            block_q=block_q, block_kv=block_kv, interpret=interpret)
    return ref.flash_attention_ref(q, k, v, causal=causal,
                                   sliding_window=sliding_window)

"""The paper's linear-regression data model (§4).

    y_i = <w_i, theta*> + zeta_i,   w_i ~ N(0, I_d),  zeta_i ~ N(0, 1)

Population risk F(theta) = 0.5 ||theta - theta*||^2 + 0.5 — strongly convex
with L = M = 1, so the paper's step size is eta = 1/2 and the Corollary-1
contraction factor is 1/2 + sqrt(3)/4.

Data is generated once, split evenly into the m workers' local shards S_j
(|S_j| = N/m, disjoint — the paper's storage model), and kept fixed across
rounds: full-batch gradients, exactly Algorithm 1/2.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RegressionDataset:
    features: jax.Array   # (m, N/m, d) — worker-major layout
    targets: jax.Array    # (m, N/m)
    theta_star: jax.Array  # (d,)

    @property
    def num_workers(self) -> int:
        return self.features.shape[0]

    @property
    def samples_per_worker(self) -> int:
        return self.features.shape[1]

    @property
    def dim(self) -> int:
        return self.features.shape[2]


def generate(key, *, dim: int, total_samples: int, num_workers: int,
             theta_star: jax.Array | None = None,
             noise_std: float = 1.0,
             heterogeneity: float = 0.0) -> RegressionDataset:
    """``heterogeneity`` > 0 departs from the paper's iid assumption
    (federated reality): worker j's covariates are scaled by a per-worker
    factor in [1-h, 1+h] and its label noise by an independent factor —
    workers then estimate the same theta* from differently-distributed
    local data.  h=0 recovers the paper's model exactly."""
    if total_samples % num_workers != 0:
        raise ValueError("N must be divisible by m (paper: |S_j| = N/m)")
    per = total_samples // num_workers
    k_theta, k_w, k_z, k_h = jax.random.split(key, 4)
    if theta_star is None:
        theta_star = jax.random.normal(k_theta, (dim,))
    w = jax.random.normal(k_w, (num_workers, per, dim))
    zeta = noise_std * jax.random.normal(k_z, (num_workers, per))
    if heterogeneity > 0:
        k1, k2 = jax.random.split(k_h)
        scale_w = 1.0 + heterogeneity * jax.random.uniform(
            k1, (num_workers, 1, 1), minval=-1.0, maxval=1.0)
        scale_z = 1.0 + heterogeneity * jax.random.uniform(
            k2, (num_workers, 1), minval=-1.0, maxval=1.0)
        w = w * scale_w
        zeta = zeta * scale_z
    y = jnp.einsum("mnd,d->mn", w, theta_star) + zeta
    return RegressionDataset(features=w, targets=y, theta_star=theta_star)


def squared_loss(theta, batch) -> jax.Array:
    """0.5 (<w, theta> - y)^2 averaged over the batch — the local empirical
    risk f̄^(j) when batch = S_j."""
    w, y = batch
    pred = w @ theta
    return 0.5 * jnp.mean((pred - y) ** 2)


def worker_batches(ds: RegressionDataset):
    """Pytree with leading worker axis, as robust_train.per_worker_grads
    expects."""
    return (ds.features, ds.targets)


def centralized_erm(ds: RegressionDataset) -> jax.Array:
    """Oracle: the failure-free centralized least-squares solution
    (minimax-rate baseline sqrt(d/N) the paper compares against)."""
    w = ds.features.reshape(-1, ds.dim)
    y = ds.targets.reshape(-1)
    sol, *_ = jnp.linalg.lstsq(w, y, rcond=None)
    return sol

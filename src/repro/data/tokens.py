"""Synthetic deterministic token / frame / patch pipelines.

Real federated text corpora are a hardware/data gate (repro band <= 2); per
the assignment we simulate them: reproducible synthetic streams whose shapes
and dtypes match the real thing.  Three generators:

* ``TokenStream``     — LM tokens with a Zipfian unigram + Markov bigram mix
                        (so the loss is learnable, not pure noise).
* ``frame_embeddings``— [audio] carve-out: precomputed conv-frontend frames.
* ``patch_embeddings``— [vlm] carve-out: precomputed ViT patch embeddings.

All are pure functions of (seed, step) => fully deterministic, resumable, and
shardable: the worker axis is the leading dim so each data rank materializes
only its own shard under pjit.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    num_workers: int
    seed: int = 0

    def __post_init__(self):
        if self.global_batch % self.num_workers != 0:
            raise ValueError("global_batch must divide evenly among workers")

    @property
    def per_worker(self) -> int:
        return self.global_batch // self.num_workers

    def batch(self, step: int):
        """Returns dict(tokens=(m, B/m, T) int32, labels likewise).

        Tokens follow a two-state mixture: a Zipf-ish unigram draw mixed with
        a deterministic affine bigram map (t_{i+1} = (a t_i + c) % V) so that
        next-token prediction has learnable structure.
        """
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k_uni, k_mix, k_start = jax.random.split(key, 3)
        shape = (self.num_workers, self.per_worker, self.seq_len)
        # Zipf via inverse-CDF on uniform: rank ~ u^(-1/s) truncated.
        u = jax.random.uniform(k_uni, shape, minval=1e-6, maxval=1.0)
        zipf = jnp.clip((u ** (-0.7) - 1.0).astype(jnp.int32),
                        0, self.vocab_size - 1)
        start = jax.random.randint(k_start, shape[:2] + (1,),
                                   0, self.vocab_size)
        pos = jnp.arange(self.seq_len, dtype=jnp.int32)[None, None, :]
        bigram = (start * 31 + pos * 7919) % self.vocab_size
        mix = jax.random.bernoulli(k_mix, 0.5, shape)
        tokens = jnp.where(mix, zipf, bigram).astype(jnp.int32)
        labels = jnp.roll(tokens, -1, axis=-1)
        return {"tokens": tokens, "labels": labels}


def frame_embeddings(key, *, num_workers: int, per_worker: int,
                     num_frames: int, d_model: int,
                     dtype=jnp.bfloat16):
    """[audio] stub: precomputed mel+conv frontend output (paper carve-out).
    Shaped like SeamlessM4T's speech encoder input after feature extraction."""
    x = jax.random.normal(key, (num_workers, per_worker, num_frames, d_model))
    return x.astype(dtype)


def patch_embeddings(key, *, num_workers: int, per_worker: int,
                     num_patches: int, d_model: int,
                     dtype=jnp.bfloat16):
    """[vlm] stub: precomputed InternViT patch embeddings after the MLP
    projector (paper carve-out)."""
    x = jax.random.normal(key, (num_workers, per_worker, num_patches, d_model))
    return x.astype(dtype)

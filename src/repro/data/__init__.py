from repro.data import regression, tokens  # noqa: F401
from repro.data.regression import RegressionDataset, generate, squared_loss  # noqa: F401
from repro.data.tokens import TokenStream, frame_embeddings, patch_embeddings  # noqa: F401

"""Layer A: the AST contract lint (rules RV101–RV107).

Pure ``ast`` — no jax import, no execution of the linted code — so the lint
runs in milliseconds over all of ``src/`` and is safe to point at arbitrary
fixture files.  Each rule is a function ``SourceContext -> [Finding]``;
:func:`lint_file` runs them all and applies the ignore[...] escape hatch.
"""

from __future__ import annotations

import ast
import os

from repro.verify.rules import Finding, SourceContext, apply_suppressions

_AXIS_FNS = ("sum", "mean")
_NUMPY_ROOTS = ("jnp", "np", "numpy")
_DOT_FNS = ("dot", "matmul", "einsum", "tensordot", "vdot", "inner")
_ENV_MUTATORS = ("setdefault", "update", "pop", "clear")


def _attr_chain(node: ast.AST) -> list[str]:
    """``jax.random.PRNGKey`` -> ["jax", "random", "PRNGKey"]; [] when the
    expression is not a plain dotted name."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def _finding(rule: str, ctx: SourceContext, node: ast.AST,
             message: str) -> Finding:
    return Finding(
        rule=rule, path=ctx.path, line=node.lineno, col=node.col_offset,
        end_line=getattr(node, "end_lineno", 0) or 0,
        end_col=getattr(node, "end_col_offset", 0) or 0, message=message)


def _axis_literal_has_zero(node: ast.AST | None) -> bool:
    """axis=0 or axis=(0, ...) with literal ints (negative axes and
    non-literal axes are out of scope — the shard/member axis is axis 0
    by the stacking convention)."""
    if node is None:
        return False
    if isinstance(node, ast.Constant):
        return node.value == 0 and isinstance(node.value, int) \
            and not isinstance(node.value, bool)
    if isinstance(node, (ast.Tuple, ast.List)):
        return any(isinstance(e, ast.Constant) and e.value == 0
                   for e in node.elts)
    return False


def _call_axis(call: ast.Call) -> ast.AST | None:
    for kw in call.keywords:
        if kw.arg == "axis":
            return kw.value
    if len(call.args) >= 2:       # jnp.sum(x, 0)
        return call.args[1]
    return None


def _is_numpy_reduce(call: ast.Call) -> bool:
    chain = _attr_chain(call.func)
    return (len(chain) >= 2 and chain[-1] in _AXIS_FNS
            and chain[0] in _NUMPY_ROOTS + ("jax",))


def _subtree_has_f32_astype(node: ast.AST) -> bool:
    """True when the operand subtree visibly up-casts to float32:
    ``x.astype(jnp.float32)`` / ``.astype("float32")`` / ``np.float32``."""
    for sub in ast.walk(node):
        if not (isinstance(sub, ast.Call)
                and isinstance(sub.func, ast.Attribute)
                and sub.func.attr == "astype" and sub.args):
            continue
        arg = sub.args[0]
        if isinstance(arg, ast.Constant) and arg.value == "float32":
            return True
        chain = _attr_chain(arg)
        if chain and chain[-1] == "float32":
            return True
    return False


# --------------------------------------------------------------------------
# RV101 — no jnp.sum/jnp.mean over the shard/member axis in bit-stable
# modules (use the unrolled chain helpers of core/shard_aggregation.py).

def rv101(ctx: SourceContext) -> list[Finding]:
    if not ctx.bit_stable:
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call) and _is_numpy_reduce(node) \
                and _axis_literal_has_zero(_call_axis(node)):
            fn = _attr_chain(node.func)[-1]
            out.append(_finding(
                "RV101", ctx, node,
                f"jnp.{fn}(..., axis=0) over the shard/member axis in a "
                "bit-stable module — XLA may reassociate it per fusion "
                "context; use blocked_partial_sum / an unrolled add chain "
                "(core/shard_aggregation.py)"))
    return out


# --------------------------------------------------------------------------
# RV102 — no literal PRNGKey(<int>) outside entry points.  Exempt regions:
# functions named ``main`` and the ``if __name__ == "__main__":`` block.

def _is_main_guard(node: ast.AST) -> bool:
    if not isinstance(node, ast.If):
        return False
    t = node.test
    return (isinstance(t, ast.Compare)
            and isinstance(t.left, ast.Name) and t.left.id == "__name__"
            and any(isinstance(c, ast.Constant) and c.value == "__main__"
                    for c in t.comparators))


def _exempt_spans(ctx: SourceContext) -> list[tuple[int, int]]:
    spans = []
    for node in ast.walk(ctx.tree):
        if (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
                and node.name == "main") or _is_main_guard(node):
            spans.append((node.lineno, node.end_lineno or node.lineno))
    return spans


def rv102(ctx: SourceContext) -> list[Finding]:
    spans = _exempt_spans(ctx)
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not chain:
            continue
        is_key_ctor = chain[-1] == "PRNGKey" or (
            chain[-1] == "key" and "random" in chain[:-1])
        if not is_key_ctor:
            continue
        if not (node.args and isinstance(node.args[0], ast.Constant)
                and isinstance(node.args[0].value, int)
                and not isinstance(node.args[0].value, bool)):
            continue
        if any(lo <= node.lineno <= hi for lo, hi in spans):
            continue
        out.append(_finding(
            "RV102", ctx, node,
            f"literal {'.'.join(chain)}({node.args[0].value!r}) outside an "
            "entry point — thread the key/seed from the caller (the PR 5 "
            "random_select fixed-subset bug class)"))
    return out


# --------------------------------------------------------------------------
# RV103 — no import-time os.environ / XLA_FLAGS mutation.  Import-time =
# any statement that executes when the module is imported: module body,
# top-level if/try/with/for bodies, and class bodies — everything except
# function bodies.

def _is_environ(node: ast.AST) -> bool:
    return _attr_chain(node)[-2:] == ["os", "environ"] or \
        _attr_chain(node) == ["environ"]


class _ImportTimeEnvVisitor(ast.NodeVisitor):
    def __init__(self, ctx: SourceContext):
        self.ctx = ctx
        self.out: list[Finding] = []

    # do not descend into runtime-only scopes
    def visit_FunctionDef(self, node):       # noqa: N802
        pass

    def visit_AsyncFunctionDef(self, node):  # noqa: N802
        pass

    def visit_Lambda(self, node):            # noqa: N802
        pass

    def _flag(self, node, what: str):
        self.out.append(_finding(
            "RV103", self.ctx, node,
            f"import-time {what} — a later import silently reconfigures an "
            "already-initialized jax backend (the PR 4 dryrun XLA_FLAGS "
            "poisoning class); mutate the environment inside an explicit "
            "entry-point call instead"))

    def visit_Assign(self, node):            # noqa: N802
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript) and _is_environ(tgt.value):
                self._flag(node, "os.environ[...] assignment")
        self.generic_visit(node)

    def visit_AugAssign(self, node):         # noqa: N802
        if isinstance(node.target, ast.Subscript) \
                and _is_environ(node.target.value):
            self._flag(node, "os.environ[...] augmented assignment")
        self.generic_visit(node)

    def visit_Delete(self, node):            # noqa: N802
        for tgt in node.targets:
            if isinstance(tgt, ast.Subscript) and _is_environ(tgt.value):
                self._flag(node, "del os.environ[...]")
        self.generic_visit(node)

    def visit_Call(self, node):              # noqa: N802
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _ENV_MUTATORS \
                and _is_environ(node.func.value):
            self._flag(node, f"os.environ.{node.func.attr}(...)")
        if _attr_chain(node.func)[-2:] == ["os", "putenv"]:
            self._flag(node, "os.putenv(...)")
        self.generic_visit(node)


def rv103(ctx: SourceContext) -> list[Finding]:
    v = _ImportTimeEnvVisitor(ctx)
    v.visit(ctx.tree)
    return v.out


# --------------------------------------------------------------------------
# RV104 — every aggregators.register call declares a non-empty description
# and a valid literal shard_contract.

_SHARD_CONTRACTS = ("coordinate_wise", "norm_based", "whole_gradient")


def _is_aggregator_register(call: ast.Call, ctx: SourceContext) -> bool:
    chain = _attr_chain(call.func)
    if chain[-2:] == ["aggregators", "register"]:
        return True
    # bare register(...) only counts inside the registry module itself
    return chain == ["register"] and \
        ctx.path.replace(os.sep, "/").endswith("core/aggregators.py")


def rv104(ctx: SourceContext) -> list[Finding]:
    out = []
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and _is_aggregator_register(node, ctx)):
            continue
        desc = node.args[1] if len(node.args) >= 2 else next(
            (kw.value for kw in node.keywords if kw.arg == "description"),
            None)
        if desc is None or (isinstance(desc, ast.Constant)
                            and not str(desc.value).strip()):
            out.append(_finding(
                "RV104", ctx, node,
                "aggregators.register call without a (non-empty) "
                "description — the registry IS the documentation surface "
                "(check_docs renders it into README/PAPER_MAP)"))
        contract = next(
            (kw.value for kw in node.keywords if kw.arg == "shard_contract"),
            None)
        if contract is None:
            out.append(_finding(
                "RV104", ctx, node,
                "aggregators.register call without an explicit "
                f"shard_contract= (one of {_SHARD_CONTRACTS}) — the Layer-B "
                "collective analyzer verifies the declared contract"))
        elif not (isinstance(contract, ast.Constant)
                  and contract.value in _SHARD_CONTRACTS):
            out.append(_finding(
                "RV104", ctx, node,
                "shard_contract must be a literal from "
                f"{_SHARD_CONTRACTS} so the contract is statically known"))
    return out


# --------------------------------------------------------------------------
# RV105 — reductions feeding a robust statistic accumulate in f32.  Scope:
# robust-stat (and bit-stable) marked modules.  Two shapes:
#   (a) dot-like calls need preferred_element_type=... or a visible
#       .astype(float32) on an operand;
#   (b) member-axis sums/means (axis 0) need a visible .astype(float32)
#       in the operand subtree.

def rv105(ctx: SourceContext) -> list[Finding]:
    if not ctx.robust_stat:
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        is_dot = (chain[-1:] and chain[-1] in _DOT_FNS
                  and chain[0] in _NUMPY_ROOTS + ("jax",)) or \
            chain[-1:] == ["dot_general"]
        if is_dot:
            has_pref = any(kw.arg == "preferred_element_type"
                           for kw in node.keywords)
            operands_f32 = any(_subtree_has_f32_astype(a)
                               for a in node.args)
            if not (has_pref or operands_f32):
                out.append(_finding(
                    "RV105", ctx, node,
                    f"{'.'.join(chain)} feeding a robust statistic without "
                    "an f32 accumulator — pass "
                    "preferred_element_type=jnp.float32 or .astype the "
                    "operands"))
            continue
        if _is_numpy_reduce(node) \
                and _axis_literal_has_zero(_call_axis(node)):
            if not any(_subtree_has_f32_astype(a) for a in node.args):
                fn = chain[-1]
                out.append(_finding(
                    "RV105", ctx, node,
                    f"jnp.{fn}(..., axis=0) over the member axis without "
                    "f32 accumulation — reduce .astype(jnp.float32) "
                    "operands and cast back at the boundary"))
    return out


# --------------------------------------------------------------------------
# RV106 — training-scan carry elements must be TrainState-backed names.

_CARRY_ALIASES = {"astate": "attack_state"}


def train_state_fields() -> tuple[str, ...]:
    """TrainState's field names, parsed from core/train_state.py's AST (no
    import — Layer A never executes repo code)."""
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        os.pardir, "core", "train_state.py")
    with open(os.path.normpath(path)) as f:
        tree = ast.parse(f.read())
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == "TrainState":
            return tuple(
                stmt.target.id for stmt in node.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name))
    raise RuntimeError("TrainState class not found in core/train_state.py")


def rv106(ctx: SourceContext,
          fields: tuple[str, ...] | None = None) -> list[Finding]:
    if not ctx.train_scan:
        return []
    if fields is None:
        fields = train_state_fields()
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        chain = _attr_chain(node.func)
        if not (chain[-1:] == ["scan"] and "lax" in chain[:-1]):
            continue
        if len(node.args) < 2:
            continue
        init = node.args[1]
        elts = init.elts if isinstance(init, (ast.Tuple, ast.List)) \
            else [init]
        for elt in elts:
            if isinstance(elt, ast.Name):
                name = _CARRY_ALIASES.get(elt.id, elt.id)
                if name in fields:
                    continue
                out.append(_finding(
                    "RV106", ctx, elt,
                    f"scan carry element {elt.id!r} does not map to a "
                    f"TrainState field {fields} — state riding the carry "
                    "outside TrainState breaks bit-exact resume (PR 2); "
                    "add the field to TrainState (fixed structure, array "
                    "leaves)"))
            else:
                out.append(_finding(
                    "RV106", ctx, elt,
                    "scan carry element is not a plain name — carry "
                    "exactly the TrainState-backed values so the "
                    "checkpoint contract stays auditable"))
    return out


# --------------------------------------------------------------------------
# RV107 — StalenessBuffer integrity: every construction passes an
# integer-dtype age vector, and the buffer stays TrainState-resident
# (a ``stale_buffer`` field must exist).  A float age drifts under
# accumulated where/add rounding and silently mis-weights or never drops
# stale rows; a buffer outside TrainState is the RV106 bug class again.

_INT_DTYPES = ("int8", "int16", "int32", "int64", "uint8", "uint16",
               "uint32", "uint64")


def _subtree_has_int_dtype(node: ast.AST) -> bool:
    """True when the age-argument subtree visibly pins an integer dtype:
    ``jnp.int32`` / ``"int32"`` as a dtype arg or an ``.astype`` target."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and sub.value in _INT_DTYPES:
            return True
        chain = _attr_chain(sub)
        if chain and chain[-1] in _INT_DTYPES:
            return True
    return False


def _buffer_age_arg(call: ast.Call) -> ast.AST | None:
    for kw in call.keywords:
        if kw.arg == "age":
            return kw.value
    if len(call.args) >= 2:       # StalenessBuffer(grads, age, bound)
        return call.args[1]
    return None


def rv107(ctx: SourceContext,
          fields: tuple[str, ...] | None = None) -> list[Finding]:
    out = []
    first_ctor = None
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        if _attr_chain(node.func)[-1:] != ["StalenessBuffer"]:
            continue
        if first_ctor is None:
            first_ctor = node
        age = _buffer_age_arg(node)
        if age is None:
            out.append(_finding(
                "RV107", ctx, node,
                "StalenessBuffer constructed without an age vector — the "
                "bounded-staleness drop rule (docs/ASYNC.md) is undefined "
                "without per-worker ages"))
        elif not _subtree_has_int_dtype(age):
            out.append(_finding(
                "RV107", ctx, node,
                "StalenessBuffer age vector without a visible integer "
                "dtype (jnp.int32 / .astype(jnp.int32)) — float ages "
                "drift under accumulated arithmetic and break the exact "
                "age > τ drop rule (docs/ASYNC.md)"))
    if first_ctor is not None:
        if fields is None:
            fields = train_state_fields()
        if "stale_buffer" not in fields:
            out.append(_finding(
                "RV107", ctx, first_ctor,
                "StalenessBuffer is constructed but TrainState has no "
                "'stale_buffer' field — buffer state outside TrainState "
                "breaks bit-exact resume (PR 2 contract)"))
    return out


# --------------------------------------------------------------------------
# driver

_ALL_RULES = (rv101, rv102, rv103, rv104, rv105, rv106, rv107)


def lint_file(path: str, src: str | None = None) -> list[Finding]:
    if src is None:
        with open(path) as f:
            src = f.read()
    ctx = SourceContext(path, src)
    findings: list[Finding] = []
    for rule in _ALL_RULES:
        findings.extend(rule(ctx))
    return sorted(apply_suppressions(findings, ctx),
                  key=lambda f: (f.line, f.col, f.rule))


def iter_python_files(paths: list[str]) -> list[str]:
    """Every ``.py`` file under each path, sorted, ``__pycache__`` pruned
    (shared by the linter and the ``--audit-ignores`` suppression audit)."""
    files: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            files += [os.path.join(dirpath, f) for f in sorted(filenames)
                      if f.endswith(".py")]
    return sorted(set(files))


def lint_paths(paths: list[str]) -> list[Finding]:
    """Lint every ``.py`` file under each path (files are linted as-is)."""
    findings: list[Finding] = []
    for f in iter_python_files(paths):
        findings.extend(lint_file(f))
    return findings

"""Layer B: verify every registered aggregator's *declared* shard contract
against what it actually lowers to.

For each aggregator the analyzer traces the exact production path — the
``make_sharded_aggregate`` shard-local body under ``shard_map`` on a real
(host-virtualized) mesh — and checks the declaration:

* ``coordinate_wise`` — ZERO cross-shard collectives, in both the jaxpr
  (what the code asked for) and the compiled HLO (what the partitioner
  actually emitted).  RV201 on violation.
* ``norm_based`` — collectives allowed, but they must be *d-independent*:
  tracing at hidden size d and 2d must produce identical collective
  shapes (the (k,)/(m,)/(m,m) partial reductions of PAPER.md §Thm 3 —
  never O(d) traffic), and no single collective may move more than
  ``num_shards * m * m`` elements.  RV202 on violation.
* ``whole_gradient`` — selection rules (krum) that output one worker's
  whole gradient; their *collectives* must still be d-independent (the
  selection score is a psum'd (m, m) distance partial; the winning
  gradient itself is taken shard-locally).  RV202 on violation.

Independently, a **determinism audit** (RV203) traces the gathered
``"virtual"``-mode oracle with a uniquely-sized shard axis (S=5, chosen to
collide with no worker/group/leaf extent) and flags any ``reduce_sum`` /
``reduce_prod`` over an extent-5 axis: such a reduction re-introduces the
XLA reassociation freedom that the unrolled ``chain_sum`` of
``core/shard_aggregation.py`` exists to remove (PR 6's 1-ulp drift bug).

No literal PRNG seeds here — this module is linted by its own Layer A
(RV102); harness arrays are deterministic arange/sin fills and the traced
key is built from a caller-supplied seed.
"""

from __future__ import annotations

import numpy as np

from repro.verify import collectives
from repro.verify.rules import Finding

# harness geometry: m workers, k groups, one Byzantine; leaf last-dims are
# multiples of 8 so every supported shard count (2/4/8) divides them.
HARNESS_M = 8
HARNESS_K = 4
HARNESS_Q = 1

# determinism-audit geometry: shard count 5 appears as NO other extent
# (workers 12, groups 6, trim slice 4, leaf dims 15/3/10 and their
# per-shard slices 3/2) — so an extent-5 reduction can only be a
# reduction over the shard-stack axis.
DET_SHARDS = 5
DET_M = 12
DET_K = 6


def _fill(shape, salt: int):
    import jax.numpy as jnp
    n = int(np.prod(shape)) if shape else 1
    base = np.arange(n, dtype=np.float64) * 0.37 + float(salt) * 1.61
    return jnp.asarray(np.sin(base).reshape(shape), jnp.float32)


def harness_tree(m: int, scale: int):
    """Stacked-gradient pytree; ``scale`` multiplies the sharded last dims
    (the d-independence probe)."""
    return {
        "w": _fill((m, 16 * scale), 3),
        "b": {"x": _fill((m, 4, 8 * scale), 5)},
        "s": _fill((m,), 7),
    }


def harness_cfg(name: str, *, m: int = HARNESS_M, k: int = HARNESS_K,
                q: int = HARNESS_Q, codec: str | None = None,
                round_backend: str = "auto"):
    from repro.core import aggregators
    from repro.core.robust_train import RobustConfig
    # an aggregator with a native wire codec is traced through its
    # COMPRESSED production path (encode -> payload -> native consume):
    # that is the path the contract claims are about — sign_sgd_majority's
    # zero-collective guarantee must hold for the packing + vote, and
    # int8_gmom's d-independence must cover the per-worker scale combine.
    # Layer C's full matrix overrides ``codec`` to probe every wire format.
    if codec is None:
        codec = aggregators.get_aggregator(name).native_codec or "none"
    return RobustConfig(num_workers=m, num_byzantine=q, num_batches=k,
                        attack="none", aggregator=name,
                        gmom_max_iters=8, gmom_tol=1e-7,
                        compression=codec, round_backend=round_backend)


def _specs(tree, axis: str):
    import jax
    from jax.sharding import PartitionSpec as P

    def in_spec(x):
        if x.ndim == 1:
            return P(None)                       # (m,) — replicated
        return P(*((None,) * (x.ndim - 1) + (axis,)))

    def out_spec(x):
        if x.ndim == 0:
            return P()
        return P(*((None,) * (x.ndim - 1) + (axis,)))

    return jax.tree.map(in_spec, tree), out_spec


def _sharded_fn(name: str, num_shards: int, scale: int, *, seed: int,
                codec: str | None = None):
    """(traceable fn, example args) — the production shard_map path."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.core.robust_train import make_sharded_aggregate
    from repro.models.meshctx import shard_map

    axis = "model"
    cfg = harness_cfg(name, codec=codec)
    stacked = harness_tree(HARNESS_M, scale)
    key = jax.random.PRNGKey(seed)
    mesh = jax.make_mesh((num_shards,), (axis,))
    in_specs, _ = _specs(stacked, axis)
    # aggregation drops the leading worker axis of every leaf — derive the
    # output specs structurally rather than via eval_shape (which would run
    # the aggregator body outside the mesh context and break on any rule
    # that uses collectives)
    out_specs = jax.tree.map(
        lambda x: (P() if x.ndim == 1
                   else P(*((None,) * (x.ndim - 2) + (axis,)))),
        stacked)
    agg = make_sharded_aggregate(cfg, mesh, axis=axis)
    fn = shard_map(agg, mesh=mesh, in_specs=(in_specs, P(None)),
                   out_specs=out_specs, check_rep=False)
    return fn, (stacked, key)


def _anchor(name: str) -> str:
    return f"<aggregator:{name}>"


# --------------------------------------------------------------------------
# trace cache
#
# One production trace serves every rule that inspects it: RV201/RV202 read
# the shard_map jaxpr + HLO, RV203 the virtual-mode jaxpr, and Layer C's
# taint pass re-walks the very same jaxprs with influence labels.  Tracing
# (and especially XLA compilation) dominates `--strict` wall time, so each
# (kind, aggregator, codec, shards, scale, seed) cell is traced exactly
# once per process.

_TRACE_CACHE: dict[tuple, object] = {}


def clear_trace_cache() -> None:
    """Drop every cached trace (tests re-registering dummy aggregators)."""
    _TRACE_CACHE.clear()


def _resolve_codec(name: str, codec: str | None) -> str:
    if codec is not None:
        return codec
    from repro.core import aggregators
    return aggregators.get_aggregator(name).native_codec or "none"


def traced_shard_map(name: str, *, num_shards: int, scale: int, seed: int,
                     codec: str | None = None):
    """(closed_jaxpr, out_shape, example_args) for the shard_map path."""
    import jax
    codec = _resolve_codec(name, codec)
    key = ("shard_map", name, codec, num_shards, scale, seed)
    if key not in _TRACE_CACHE:
        fn, args = _sharded_fn(name, num_shards, scale, seed=seed,
                               codec=codec)
        jaxpr, out_shape = jax.make_jaxpr(fn, return_shape=True)(*args)
        _TRACE_CACHE[key] = (jaxpr, out_shape, args)
    return _TRACE_CACHE[key]


def compiled_shard_map_text(name: str, *, num_shards: int, scale: int,
                            seed: int, codec: str | None = None) -> str:
    """Compiled-HLO text for the shard_map path (the expensive view)."""
    import jax
    codec = _resolve_codec(name, codec)
    key = ("hlo", name, codec, num_shards, scale, seed)
    if key not in _TRACE_CACHE:
        fn, args = _sharded_fn(name, num_shards, scale, seed=seed,
                               codec=codec)
        _TRACE_CACHE[key] = jax.jit(fn).lower(*args).compile().as_text()
    return _TRACE_CACHE[key]


def traced_flat(name: str, *, seed: int, codec: str | None = None):
    """(closed_jaxpr, out_shape, example_args) for the unsharded
    ``aggregate_reported`` path on the Layer-B harness tree.

    ``round_backend`` is pinned to the jnp reference pipeline: the fused
    Pallas kernel is an opaque primitive to jaxpr-level analysis, and the
    reference path is the semantics the kernel is bit-tested against.
    """
    import jax
    from repro.core.robust_train import aggregate_reported
    codec = _resolve_codec(name, codec)
    key = ("flat", name, codec, None, 1, seed)
    if key not in _TRACE_CACHE:
        cfg = harness_cfg(name, codec=codec, round_backend="reference")
        stacked = harness_tree(HARNESS_M, 1)
        prng = jax.random.PRNGKey(seed)
        jaxpr, out_shape = jax.make_jaxpr(
            lambda s, k: aggregate_reported(s, cfg, key=k),
            return_shape=True)(stacked, prng)
        _TRACE_CACHE[key] = (jaxpr, out_shape, (stacked, prng))
    return _TRACE_CACHE[key]


def _fmt_uses(uses) -> str:
    return ", ".join(
        f"{u.prim}{list(u.out_shapes)}" for u in uses) or "none"


def check_aggregator(name: str, *, num_shards: int = 4, seed: int = 0,
                     hlo_both_scales: bool = False) -> list[Finding]:
    """All Layer-B findings for one registered aggregator."""
    import jax
    from repro.core import aggregators

    agg = aggregators.get_aggregator(name)
    contract = agg.shard_contract
    findings: list[Finding] = []
    anchor = _anchor(name)

    # --- jaxpr view at both scales (cached — Layer C re-walks these)
    uses = {}
    for scale in (1, 2):
        jaxpr, _, _ = traced_shard_map(name, num_shards=num_shards,
                                       scale=scale, seed=seed)
        uses[scale] = collectives.jaxpr_collectives(jaxpr)

    if contract == "coordinate_wise":
        if uses[1]:
            findings.append(Finding(
                rule="RV201", path=anchor, line=0, col=0,
                message=f"declared coordinate_wise but the jaxpr contains "
                        f"cross-shard collectives: {_fmt_uses(uses[1])}"))
    else:
        key1 = sorted((u.prim, u.out_shapes) for u in uses[1])
        key2 = sorted((u.prim, u.out_shapes) for u in uses[2])
        if key1 != key2:
            findings.append(Finding(
                rule="RV202", path=anchor, line=0, col=0,
                message=f"collective shapes change with hidden size d "
                        f"(d-dependent traffic): d -> {_fmt_uses(uses[1])} "
                        f"vs 2d -> {_fmt_uses(uses[2])}"))
        if contract == "norm_based":
            cap = num_shards * HARNESS_M * HARNESS_M
            for u in uses[1]:
                if u.elements > cap:
                    findings.append(Finding(
                        rule="RV202", path=anchor, line=0, col=0,
                        message=f"norm_based collective {u.prim}"
                                f"{list(u.out_shapes)} moves {u.elements} "
                                f"elements > cap {cap} "
                                f"(num_shards*m*m) — partial reductions "
                                f"must stay (k,)/(m,)/(m,m)-shaped"))

    # --- compiled-HLO view (the partitioner can insert collectives the
    # jaxpr never asked for)
    hlo = {}
    for scale in (1, 2) if hlo_both_scales else (1,):
        hlo[scale] = compiled_shard_map_text(
            name, num_shards=num_shards, scale=scale, seed=seed)

    if contract == "coordinate_wise":
        nbytes = collectives.hlo_collective_bytes(hlo[1])
        if nbytes > 0:
            shapes = collectives.hlo_collective_shapes(hlo[1])
            findings.append(Finding(
                rule="RV201", path=anchor, line=0, col=0,
                message=f"declared coordinate_wise but the compiled HLO "
                        f"moves {nbytes:.0f} collective bytes: {shapes}"))
    elif hlo_both_scales:
        s1 = collectives.hlo_collective_shapes(hlo[1])
        s2 = collectives.hlo_collective_shapes(hlo[2])
        if s1 != s2:
            findings.append(Finding(
                rule="RV202", path=anchor, line=0, col=0,
                message=f"compiled collective shapes change with hidden "
                        f"size d: {s1} vs {s2}"))

    findings.extend(audit_determinism(name, seed=seed))
    return findings


# --------------------------------------------------------------------------
# determinism audit (RV203)


def _walk_eqns(jaxpr):
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in collectives._sub_jaxprs(eqn):
            yield from _walk_eqns(sub)


def audit_determinism(name: str, *, seed: int = 0) -> list[Finding]:
    """Trace the gathered virtual-mode oracle with the uniquely-sized
    shard axis and flag reassociation-sensitive reductions over it."""
    import jax
    from repro.core.robust_train import aggregate_reported
    from repro.core.shard_aggregation import ShardSpec

    cache_key = ("virtual", name, None, DET_SHARDS, 1, seed)
    try:
        if cache_key in _TRACE_CACHE:
            jaxpr = _TRACE_CACHE[cache_key]
        else:
            cfg = harness_cfg(name, m=DET_M, k=DET_K)
            stacked = {
                "w": _fill((DET_M, 15), 11),
                "b": {"x": _fill((DET_M, 3, 10), 13)},
                "s": _fill((DET_M,), 17),
            }
            key = jax.random.PRNGKey(seed)
            spec = ShardSpec(num_shards=DET_SHARDS, mode="virtual",
                             axis="model")
            jaxpr = jax.make_jaxpr(
                lambda s, k: aggregate_reported(
                    s, cfg, key=k, shard_spec=spec))(stacked, key)
            _TRACE_CACHE[cache_key] = jaxpr
    except Exception as e:  # noqa: BLE001
        # an aggregator that cannot trace under the meshless virtual spec
        # (e.g. a hardcoded collective) also breaks the sharded-vs-gathered
        # bit-equality oracle — that IS a contract violation, not an
        # internal error of the checker
        return [Finding(
            rule="RV203", path=_anchor(name), line=0, col=0,
            message=f"gathered virtual-mode oracle failed to trace "
                    f"({type(e).__name__}: {e}) — every aggregator must "
                    f"route cross-shard work through the ShardSpec so the "
                    f"single-device oracle stays traceable")]

    findings: list[Finding] = []
    for eqn in _walk_eqns(jaxpr):
        if eqn.primitive.name not in ("reduce_sum", "reduce_prod"):
            continue
        shape = tuple(eqn.invars[0].aval.shape)
        axes = eqn.params.get("axes", ())
        bad = [a for a in axes if shape[a] == DET_SHARDS]
        if bad:
            findings.append(Finding(
                rule="RV203", path=_anchor(name), line=0, col=0,
                message=f"{eqn.primitive.name} over axis {bad} of shape "
                        f"{shape} reduces the {DET_SHARDS}-extent shard "
                        f"stack — use the unrolled chain_sum of "
                        f"core/shard_aggregation.py (bit-stability)"))
    return findings

"""Jaxpr-level influence lattice for the Layer C Byzantine taint analysis.

This module is the *engine*: it propagates adversary-influence labels
through a traced jaxpr, one equation at a time, with no knowledge of
aggregator names or registry metadata.  ``repro.verify.taint`` builds the
harnesses (which inputs are adversary-controlled) and turns the resulting
output labels into RV301/RV302/RV303 findings.

The lattice tracks, per value, the worst-case influence a SINGLE Byzantine
worker's report can exert on it:

* ``CLEAN``   — no dependence on any adversary-controlled input.
* ``BOUNDED`` — depends on adversary inputs, but every path crosses an op
  whose per-worker influence is bounded no matter what the worker sends
  (an order statistic, a rank selection, a clip against a robust
  threshold, a sign/majority vote, or a Weiszfeld reweighting).
* ``RAW``     — at least one path lets a single report move the value
  arbitrarily far (sums, means, scale multiplies, dequantize-by-scale).

Alongside the level each label carries ``kinds`` — which bounded-op
families appear on the dataflow (``order_stat`` / ``rank_select`` /
``sign_vote`` / ``clip`` / ``weiszfeld``) — and ``sources`` — which
adversary surfaces feed it (``report`` / ``age`` / ``attack_state``).

Design rules (see docs/STATIC_ANALYSIS.md for the full table and the
documented imprecisions):

* The DEFAULT transfer for every primitive is ``join`` (max level, union
  kinds/sources).  In particular ``mul(RAW, mask)`` stays RAW — masking a
  raw report by a robust 0/1 mask rescales it, it does not bound it
  (exactly the ``norm_select`` unsoundness of PR 5), and an int8 wire
  scale derived via ``reduce_max`` over a raw report stays RAW.
* Only a handful of primitives may *demote* RAW to BOUNDED, and each
  demotion records its kind so RV303 can compare discovered kinds against
  the registry's declared ``sanitization_point``.
* Composite sanitizers that are invisible at single-primitive granularity
  (the Weiszfeld ``1/dist`` reweighting inside a ``while`` loop) are
  recognized structurally by a flag-propagation pass over the loop body —
  still with zero name-based special cases.
"""

from __future__ import annotations

import dataclasses
from typing import Any

CLEAN = 0
BOUNDED = 1
RAW = 2

_LEVEL_NAMES = {CLEAN: "CLEAN", BOUNDED: "BOUNDED", RAW: "RAW"}

#: the closed set of bounded-op families a demotion may record; the
#: registry's ``sanitization_point`` declarations are validated against it.
SANITIZER_KINDS = ("clip", "order_stat", "rank_select", "sign_vote",
                   "weiszfeld")


@dataclasses.dataclass(frozen=True)
class Label:
    level: int = CLEAN
    kinds: frozenset = frozenset()
    sources: frozenset = frozenset()

    def join(self, other: "Label") -> "Label":
        if other is CLEAN_LABEL:
            return self
        if self is CLEAN_LABEL:
            return other
        return Label(level=max(self.level, other.level),
                     kinds=self.kinds | other.kinds,
                     sources=self.sources | other.sources)

    def cap_bounded(self) -> "Label":
        """Influence through a comparison / index-valued op: the value
        range is tiny, so per-worker influence is bounded — but no
        sanitizer kind is credited (a bool is not a defense)."""
        if self.level <= BOUNDED:
            return self
        return Label(level=BOUNDED, kinds=self.kinds, sources=self.sources)

    def demote(self, kind: str) -> "Label":
        """Pass through a bounded-influence op of family ``kind``."""
        if self.level == CLEAN:
            return self
        return Label(level=BOUNDED, kinds=self.kinds | frozenset({kind}),
                     sources=self.sources)

    def describe(self) -> str:
        parts = [_LEVEL_NAMES[self.level]]
        if self.kinds:
            parts.append("kinds={" + ",".join(sorted(self.kinds)) + "}")
        if self.sources:
            parts.append("sources={" + ",".join(sorted(self.sources)) + "}")
        return " ".join(parts)


CLEAN_LABEL = Label()


def raw(source: str) -> Label:
    return Label(level=RAW, sources=frozenset({source}))


def join_all(labels) -> Label:
    out = CLEAN_LABEL
    for l in labels:
        out = out.join(l)
    return out


# --------------------------------------------------------------------------
# primitive tables

_ORDER_STAT_PRIMS = {"sort", "top_k", "approx_top_k"}

# bool- or index-valued outputs: tainted inputs can steer them, but the
# per-worker influence on the VALUE is bounded by the tiny output range.
_CAP_PRIMS = {"lt", "gt", "le", "ge", "eq", "ne", "argmin", "argmax",
              "reduce_and", "reduce_or", "is_finite", "sign"}

# value-selection by index; dynamic_update_slice is deliberately absent
# (its update operand embeds a raw VALUE — default join applies).
_GATHER_PRIMS = {"gather", "dynamic_slice"}

# higher-order call-like primitives: the sub-jaxpr binds eqn.invars
# positionally (jaxpr param key varies by primitive / jax version).
_SUBJAXPR_PARAM_KEYS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


def _is_literal(v) -> bool:
    return hasattr(v, "val")


class _Env:
    __slots__ = ("m",)

    def __init__(self):
        self.m: dict[Any, Label] = {}

    def read(self, v) -> Label:
        if _is_literal(v):
            return CLEAN_LABEL
        return self.m.get(v, CLEAN_LABEL)

    def write(self, v, label: Label) -> None:
        self.m[v] = label


# --------------------------------------------------------------------------
# per-equation transfer

def _transfer(name: str, eqn, ins: list[Label]) -> Label:
    """Label for every outvar of a first-order equation."""
    if name in _ORDER_STAT_PRIMS:
        # sort/top_k: any single report moves the output by at most one
        # rank slot — the PAPER.md Remark-2 / Yin'18 coordinate-wise
        # argument.  Covers the co-sorted argsort operand and the index
        # output alike.
        return join_all(ins).demote("order_stat")
    if name in _CAP_PRIMS:
        # `sign` is capped (range {-1,0,1}) but does NOT credit the
        # sign_vote kind by itself: a per-worker sign is sanitized only
        # once it feeds a majority vote (the select_n rule below).
        return join_all(ins).cap_bounded()
    if name == "clamp":
        lo, x, hi = ins[0], ins[1], ins[2]
        if lo.level < RAW and hi.level < RAW:
            return join_all(ins).demote("clip")
        return join_all(ins)
    if name == "select_n":
        pred, vals = ins[0], ins[1:]
        if all(v.level == CLEAN for v in vals):
            # where(vote_condition, ±const, ∓const): the report only
            # steers a choice among clean constants — the majority-vote
            # shape, however `signbit`/threshold lowered upstream.
            if pred.level == CLEAN:
                return CLEAN_LABEL
            return Label(level=BOUNDED,
                         kinds=pred.kinds | frozenset({"sign_vote"}),
                         sources=pred.sources)
        return join_all(vals).join(pred.cap_bounded())
    if name in _GATHER_PRIMS:
        operand, idx = ins[0], join_all(ins[1:])
        if idx.level == CLEAN:
            return operand
        # Tainted index over any operand: the adversary picks WHICH row
        # wins, not its value — bounded per-worker influence, credited as
        # rank selection (krum's winner-take).  Documented caveat: this
        # presumes the selection score itself is robust; the verbatim
        # selected gradient is still one worker's report.
        return Label(level=BOUNDED,
                     kinds=operand.kinds | idx.kinds
                           | frozenset({"rank_select"}),
                     sources=operand.sources | idx.sources)
    # default: join.  Sums, means, muls, dots, scatters, bitwise ops,
    # conversions, broadcasts — none of them bound per-worker influence.
    return join_all(ins)


# --------------------------------------------------------------------------
# jaxpr walk

def _closed_parts(closed):
    """(raw_jaxpr) for either a ClosedJaxpr or a raw Jaxpr param."""
    return closed.jaxpr if hasattr(closed, "jaxpr") else closed


def run_jaxpr(jaxpr, in_labels: list[Label],
              capture: dict | None = None) -> list[Label]:
    """Propagate labels through one (raw or closed) jaxpr.

    ``in_labels`` matches ``jaxpr.invars``; constvars are CLEAN (they are
    trace-time constants, not runtime adversary inputs).  When ``capture``
    is given, every intermediate var's label is recorded into it (used by
    the Weiszfeld detector).
    """
    jaxpr = _closed_parts(jaxpr)
    if len(in_labels) != len(jaxpr.invars):
        raise ValueError(
            f"label/invar arity mismatch: {len(in_labels)} labels for "
            f"{len(jaxpr.invars)} invars")
    env = _Env()
    if capture is not None:
        env.m = capture
    for v in jaxpr.constvars:
        env.write(v, CLEAN_LABEL)
    for v, lab in zip(jaxpr.invars, in_labels):
        env.write(v, lab)
    for eqn in jaxpr.eqns:
        _step(eqn, env)
    return [env.read(v) for v in jaxpr.outvars]


def _is_bool_var(v) -> bool:
    aval = getattr(v, "aval", None)
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and dtype == bool


def _step(eqn, env: _Env) -> None:
    name = eqn.primitive.name
    ins = [env.read(v) for v in eqn.invars]
    if name == "while":
        outs = _while(eqn, ins)
    elif name == "scan":
        outs = _scan(eqn, ins)
    elif name == "cond":
        outs = _cond(eqn, ins)
    else:
        outs = _call_like(eqn, ins)
        if outs is None:
            lab = _transfer(name, eqn, ins)
            outs = [lab] * len(eqn.outvars)
    for v, lab in zip(eqn.outvars, outs):
        # a boolean's VALUE range is {0,1}: whatever fed it, one worker's
        # per-value influence is bounded (and sums of bools stay bounded).
        # Applied per-outvar on dtype, not per-primitive, so and/or/not
        # chains over predicates (attack strike logic, arrival masks)
        # never spuriously escalate to RAW.
        if _is_bool_var(v):
            lab = lab.cap_bounded()
        env.write(v, lab)


def _call_like(eqn, ins: list[Label]) -> list[Label] | None:
    """Descend into pjit/closed_call/remat/custom_*/shard_map bodies by
    positional binding; None when the eqn has no sub-jaxpr.  An arity
    mismatch (exotic primitive) falls back to a conservative join-all."""
    subs = []
    for key in _SUBJAXPR_PARAM_KEYS:
        sub = eqn.params.get(key) if eqn.params else None
        if sub is not None:
            subs.append(sub)
    if not subs:
        if _has_any_subjaxpr(eqn):
            j = join_all(ins)
            return [j] * len(eqn.outvars)
        return None
    for sub in subs:
        jaxpr = _closed_parts(sub)
        if len(jaxpr.invars) == len(ins):
            outs = run_jaxpr(jaxpr, ins)
            if len(outs) >= len(eqn.outvars):
                return outs[:len(eqn.outvars)]
    j = join_all(ins)
    return [j] * len(eqn.outvars)


def _has_any_subjaxpr(eqn) -> bool:
    if not eqn.params:
        return False
    for val in eqn.params.values():
        for v in (val if isinstance(val, (tuple, list)) else (val,)):
            if hasattr(v, "jaxpr") or hasattr(v, "eqns"):
                return True
    return False


_FIXPOINT_LIMIT = 64


def _while(eqn, ins: list[Label]) -> list[Label]:
    cn = eqn.params["cond_nconsts"]
    bn = eqn.params["body_nconsts"]
    body = eqn.params["body_jaxpr"]
    body_consts = ins[cn:cn + bn]
    carry = list(ins[cn + bn:])
    for _ in range(_FIXPOINT_LIMIT):
        outs = run_jaxpr(body, body_consts + carry)
        new = [c.join(o) for c, o in zip(carry, outs)]
        if new == carry:
            break
        carry = new
    if any(l.level == RAW for l in carry) and \
            _weiszfeld_fires(body, body_consts + carry, bn):
        carry = [l.demote("weiszfeld") if l.level == RAW else l
                 for l in carry]
    return carry


def _scan(eqn, ins: list[Label]) -> list[Label]:
    nc = eqn.params["num_consts"]
    n_carry = eqn.params["num_carry"]
    body = eqn.params["jaxpr"]
    consts = ins[:nc]
    carry = list(ins[nc:nc + n_carry])
    xs = ins[nc + n_carry:]
    ys: list[Label] = []
    for _ in range(_FIXPOINT_LIMIT):
        outs = run_jaxpr(body, consts + carry + xs)
        new = [c.join(o) for c, o in zip(carry, outs[:n_carry])]
        ys = outs[n_carry:]
        if new == carry:
            break
        carry = new
    return carry + ys


def _cond(eqn, ins: list[Label]) -> list[Label]:
    pred, ops = ins[0], ins[1:]
    outs: list[Label] | None = None
    for br in eqn.params["branches"]:
        o = run_jaxpr(br, ops)
        outs = o if outs is None else [a.join(b) for a, b in zip(outs, o)]
    capped = pred.cap_bounded()
    return [o.join(capped) for o in (outs or [])] or \
        [capped] * len(eqn.outvars)


# --------------------------------------------------------------------------
# Weiszfeld composite detector
#
# The geometric-median iteration y' = Σ (w_i/d_i(y)) x_i / Σ (w_i/d_i(y))
# is a weighted MEAN at primitive granularity — every eqn on the path is
# join-unbounded — yet its fixed point has bounded per-point influence
# (breakdown 1/2).  The signature, structural and name-free:
#
#   carry-and-raw value → sqrt        (the distance d_i(y))
#   something / sqrt_d                (the inverse weight w_i/d_i)
#   inv_w ⊙ raw_points  (mul or dot)  (the reweighted report sum)
#   … reaching a carry output of the while body.
#
# Flags union-propagate forward; sub-jaxpr-bearing eqns inside the body
# propagate conservatively (flags joined across the call, no descent).

def _weiszfeld_fires(body, in_labels: list[Label], nconsts: int) -> bool:
    jaxpr = _closed_parts(body)
    labels: dict[Any, Label] = {}
    try:
        run_jaxpr(jaxpr, in_labels, capture=labels)
    except ValueError:
        return False

    def lab(v) -> Label:
        if _is_literal(v):
            return CLEAN_LABEL
        return labels.get(v, CLEAN_LABEL)

    flags: dict[Any, frozenset] = {}

    def fl(v) -> frozenset:
        if _is_literal(v):
            return frozenset()
        return flags.get(v, frozenset())

    for i, v in enumerate(jaxpr.invars):
        tag = set()
        if i >= nconsts:
            tag.add("carry")
        if lab(v).level == RAW:
            tag.add("raw")
        flags[v] = frozenset(tag)

    for eqn in jaxpr.eqns:
        out = frozenset()
        for v in eqn.invars:
            out |= fl(v)
        name = eqn.primitive.name
        if name == "sqrt" and eqn.invars:
            f0 = fl(eqn.invars[0])
            if "carry" in f0 and "raw" in f0 and \
                    lab(eqn.invars[0]).level == RAW:
                out |= {"sqrt_d"}
        elif name == "div" and len(eqn.invars) == 2:
            if "sqrt_d" in fl(eqn.invars[1]):
                out |= {"inv_w"}
        elif name in ("mul", "dot_general") and len(eqn.invars) >= 2:
            a, b = eqn.invars[0], eqn.invars[1]
            if ("inv_w" in fl(a) and lab(b).level == RAW) or \
                    ("inv_w" in fl(b) and lab(a).level == RAW):
                out |= {"wprod"}
        for v in eqn.outvars:
            flags[v] = out

    return any("wprod" in fl(v) for v in jaxpr.outvars)

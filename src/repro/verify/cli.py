"""``python -m repro.verify`` — the three-layer invariant checker.

Layer A (default: lint all of ``src/``) is pure-AST and runs in
milliseconds; Layer B traces/compiles every registered aggregator on a
host-virtualized 8-device mesh and audits the Pallas round kernel's VMEM
budget; Layer C (``--taint``) runs the Byzantine taint/influence
analysis over the same traces plus the full production round step.
``--strict`` turns findings into a non-zero exit (the tier-1 CI gate);
without it the checker reports and exits 0 (the local triage mode).

``--format sarif`` serializes the findings as SARIF 2.1.0 for GitHub
code scanning (to ``--output`` or stdout, with progress rerouted to
stderr).  ``--audit-ignores`` lists every ``# repro: ignore[...]``
escape hatch in the tree with its justification and fails on rule IDs
that no longer exist in the catalog.

Exit codes: 0 clean (or non-strict), 1 findings under ``--strict``
(or stale ignores under ``--audit-ignores``), 2 internal error (the
checker itself failed — never conflated with a finding).
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

from repro.verify.rules import RULES, Finding

_LAYER_B_DEVICES = 8


def _default_src_root() -> str:
    # src/repro/verify/cli.py -> src/
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(here))


def run_layer_a(paths: list[str]) -> list[Finding]:
    from repro.verify.ast_rules import lint_paths
    return lint_paths(paths)


def run_layer_b(*, aggregators_filter: list[str] | None,
                num_shards_list: list[int], seed: int,
                hlo_both_scales: bool, log=print) -> list[Finding]:
    from repro.launch.dryrun import force_host_device_count
    force_host_device_count(_LAYER_B_DEVICES)

    from repro.core import aggregators
    from repro.verify import contracts, vmem

    names = [n for n in aggregators.available()
             if not n.startswith("_")]
    if aggregators_filter:
        unknown = sorted(set(aggregators_filter) - set(names))
        if unknown:
            raise SystemExit(f"unknown aggregator(s): {', '.join(unknown)}")
        names = [n for n in names if n in aggregators_filter]

    findings: list[Finding] = []
    for name in names:
        for num_shards in num_shards_list:
            log(f"[verify] layer B: {name} × {num_shards} shards",
                flush=True)
            findings.extend(contracts.check_aggregator(
                name, num_shards=num_shards, seed=seed,
                hlo_both_scales=hlo_both_scales))
    findings.extend(vmem.check_vmem_budget())
    return findings


def run_layer_c(*, aggregators_filter: list[str] | None, full_matrix: bool,
                num_shards: int, seed: int, log=print) -> list[Finding]:
    from repro.launch.dryrun import force_host_device_count
    force_host_device_count(_LAYER_B_DEVICES)

    from repro.verify import taint
    return taint.run_taint(aggregators_filter=aggregators_filter,
                           full_matrix=full_matrix, num_shards=num_shards,
                           seed=seed, log=log)


def audit_ignores(paths: list[str], *, log=print) -> int:
    """List every ``# repro: ignore[...]`` escape hatch with its
    justification; exit non-zero when an ignore names a rule ID that no
    longer exists in the catalog (a stale suppression is dead weight at
    best and a masked regression at worst)."""
    from repro.verify.ast_rules import iter_python_files
    from repro.verify.rules import SourceContext

    total, stale = 0, 0
    for path in iter_python_files(paths):
        try:
            with open(path, encoding="utf-8") as fh:
                ctx = SourceContext(path, fh.read())
        except (OSError, SyntaxError):
            continue
        for sup in ctx.suppressions:
            total += 1
            ids = ", ".join(sup.rule_ids)
            just = sup.justification or "(NO JUSTIFICATION)"
            log(f"{path}:{sup.line}: ignore[{ids}] — {just}")
            unknown = [r for r in sup.rule_ids if r not in RULES]
            if unknown:
                stale += 1
                log(f"{path}:{sup.line}: STALE — rule ID(s) "
                    f"{', '.join(unknown)} not in the catalog")
    log(f"[verify] {total} ignore(s), {stale} stale")
    return 1 if stale else 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.verify",
        description="three-layer invariant checker "
                    "(docs/STATIC_ANALYSIS.md)")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 when any finding survives (the CI gate)")
    p.add_argument("--layer", choices=["a", "b", "c", "all"], default="all",
                   help="which layer(s) to run (default: all = A+B; "
                        "add Layer C with --taint or --layer c)")
    p.add_argument("--taint", action="store_true",
                   help="also run Layer C (Byzantine taint/influence "
                        "analysis, RV30x)")
    p.add_argument("--paths", nargs="*", default=None,
                   help="files/dirs for Layer A (default: the src/ tree)")
    p.add_argument("--aggregators", nargs="*", default=None,
                   help="restrict Layers B/C to these registered names")
    p.add_argument("--num-shards", type=int, default=4,
                   help="mesh size for the Layer-B contract trace "
                        "(default 4; must divide 8)")
    p.add_argument("--full-matrix", action="store_true",
                   help="Layer B over shard counts 2/4/8 with the compiled-"
                        "HLO d-independence pass at both scales; Layer C "
                        "over every aggregator × codec cell (nightly)")
    p.add_argument("--seed", type=int, default=0,
                   help="seed for the traced aggregation key")
    p.add_argument("--format", choices=["text", "sarif"], default="text",
                   help="findings output format (sarif = SARIF 2.1.0 for "
                        "code scanning)")
    p.add_argument("--output", default=None,
                   help="write the findings report to this file instead of "
                        "stdout")
    p.add_argument("--audit-ignores", action="store_true",
                   help="list every # repro: ignore[...] with its "
                        "justification; exit 1 on stale rule IDs")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    args = p.parse_args(argv)

    if args.list_rules:
        for rule in RULES.values():
            print(f"{rule.id} [layer {rule.layer}] {rule.title}")
            print(f"    motivation: {rule.motivation}")
        return 0

    if args.audit_ignores:
        paths = args.paths or [_default_src_root()]
        return audit_ignores(paths)

    # SARIF to stdout must stay machine-parseable: progress and the text
    # rendering of the findings go to stderr in that mode.
    sarif_to_stdout = args.format == "sarif" and args.output is None
    report = sys.stderr if sarif_to_stdout else sys.stdout

    def log(*a, **kw):
        kw.setdefault("file", report)
        print(*a, **kw)

    run_c = args.taint or args.layer == "c"
    findings: list[Finding] = []
    try:
        if args.layer in ("a", "all"):
            paths = args.paths or [_default_src_root()]
            a = run_layer_a(paths)
            log(f"[verify] layer A: {len(a)} finding(s) over "
                f"{', '.join(paths)}")
            findings.extend(a)
        if args.layer in ("b", "all"):
            shards = [2, 4, 8] if args.full_matrix else [args.num_shards]
            b = run_layer_b(aggregators_filter=args.aggregators,
                            num_shards_list=shards, seed=args.seed,
                            hlo_both_scales=args.full_matrix, log=log)
            log(f"[verify] layer B: {len(b)} finding(s)")
            findings.extend(b)
        if run_c:
            c = run_layer_c(aggregators_filter=args.aggregators,
                            full_matrix=args.full_matrix,
                            num_shards=args.num_shards, seed=args.seed,
                            log=log)
            log(f"[verify] layer C: {len(c)} finding(s)")
            findings.extend(c)
    except SystemExit:
        raise
    except Exception:
        traceback.print_exc()
        print("[verify] INTERNAL ERROR — the checker itself failed "
              "(this is not a finding)", file=sys.stderr)
        return 2

    for f in findings:
        log(f.format())
    log(f"[verify] {len(findings)} finding(s) total")

    if args.format == "sarif":
        from repro.verify import sarif
        if args.output is not None:
            with open(args.output, "w", encoding="utf-8") as fh:
                sarif.dump(findings, fh)
            log(f"[verify] SARIF written to {args.output}")
        else:
            sarif.dump(findings, sys.stdout)

    if findings and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

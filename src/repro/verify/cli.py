"""``python -m repro.verify`` — the two-layer invariant checker.

Layer A (default: lint all of ``src/``) is pure-AST and runs in
milliseconds; Layer B traces/compiles every registered aggregator on a
host-virtualized 8-device mesh and audits the Pallas round kernel's VMEM
budget.  ``--strict`` turns findings into a non-zero exit (the tier-1 CI
gate); without it the checker reports and exits 0 (the local
triage mode).

Exit codes: 0 clean (or non-strict), 1 findings under ``--strict``,
2 internal error (the checker itself failed — never conflated with a
finding).
"""

from __future__ import annotations

import argparse
import os
import sys
import traceback

from repro.verify.rules import RULES, Finding

_LAYER_B_DEVICES = 8


def _default_src_root() -> str:
    # src/repro/verify/cli.py -> src/
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(here))


def run_layer_a(paths: list[str]) -> list[Finding]:
    from repro.verify.ast_rules import lint_paths
    return lint_paths(paths)


def run_layer_b(*, aggregators_filter: list[str] | None,
                num_shards_list: list[int], seed: int,
                hlo_both_scales: bool) -> list[Finding]:
    from repro.launch.dryrun import force_host_device_count
    force_host_device_count(_LAYER_B_DEVICES)

    from repro.core import aggregators
    from repro.verify import contracts, vmem

    names = [n for n in aggregators.available()
             if not n.startswith("_")]
    if aggregators_filter:
        unknown = sorted(set(aggregators_filter) - set(names))
        if unknown:
            raise SystemExit(f"unknown aggregator(s): {', '.join(unknown)}")
        names = [n for n in names if n in aggregators_filter]

    findings: list[Finding] = []
    for name in names:
        for num_shards in num_shards_list:
            print(f"[verify] layer B: {name} × {num_shards} shards",
                  flush=True)
            findings.extend(contracts.check_aggregator(
                name, num_shards=num_shards, seed=seed,
                hlo_both_scales=hlo_both_scales))
    findings.extend(vmem.check_vmem_budget())
    return findings


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.verify",
        description="two-layer invariant checker "
                    "(docs/STATIC_ANALYSIS.md)")
    p.add_argument("--strict", action="store_true",
                   help="exit 1 when any finding survives (the CI gate)")
    p.add_argument("--layer", choices=["a", "b", "all"], default="all",
                   help="which layer(s) to run (default: all)")
    p.add_argument("--paths", nargs="*", default=None,
                   help="files/dirs for Layer A (default: the src/ tree)")
    p.add_argument("--aggregators", nargs="*", default=None,
                   help="restrict Layer B to these registered names")
    p.add_argument("--num-shards", type=int, default=4,
                   help="mesh size for the Layer-B contract trace "
                        "(default 4; must divide 8)")
    p.add_argument("--full-matrix", action="store_true",
                   help="Layer B over shard counts 2/4/8 with the compiled-"
                        "HLO d-independence pass at both scales (nightly)")
    p.add_argument("--seed", type=int, default=0,
                   help="seed for the traced aggregation key")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    args = p.parse_args(argv)

    if args.list_rules:
        for rule in RULES.values():
            print(f"{rule.id} [layer {rule.layer}] {rule.title}")
            print(f"    motivation: {rule.motivation}")
        return 0

    findings: list[Finding] = []
    try:
        if args.layer in ("a", "all"):
            paths = args.paths or [_default_src_root()]
            a = run_layer_a(paths)
            print(f"[verify] layer A: {len(a)} finding(s) over "
                  f"{', '.join(paths)}")
            findings.extend(a)
        if args.layer in ("b", "all"):
            shards = [2, 4, 8] if args.full_matrix else [args.num_shards]
            b = run_layer_b(aggregators_filter=args.aggregators,
                            num_shards_list=shards, seed=args.seed,
                            hlo_both_scales=args.full_matrix)
            print(f"[verify] layer B: {len(b)} finding(s)")
            findings.extend(b)
    except SystemExit:
        raise
    except Exception:
        traceback.print_exc()
        print("[verify] INTERNAL ERROR — the checker itself failed "
              "(this is not a finding)", file=sys.stderr)
        return 2

    for f in findings:
        print(f.format())
    n = len(findings)
    print(f"[verify] {n} finding(s) total")
    if findings and args.strict:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())

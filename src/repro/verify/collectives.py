"""Collective extraction from both IR levels (Layer B's measuring stick).

Two views of the same question — "what crosses shards, and how big is it?":

* :func:`jaxpr_collectives` walks a (closed) jaxpr recursively (while/scan/
  cond/shard_map sub-jaxprs included) and returns every collective-primitive
  equation with its output shapes and mesh axes.  This is the *pre-XLA*
  view: exactly the collectives the aggregation code asked for.
* :func:`hlo_collective_shapes` / the reused
  :func:`repro.roofline.hlo_parser.analyze` read the compiled per-device
  HLO text — the *post-XLA* view, catching collectives the partitioner
  inserted on its own.

The contract analyzer (``repro.verify.contracts``) requires both views to
agree with the registered aggregator's declared ``shard_contract``.
"""

from __future__ import annotations

import dataclasses

from repro.roofline import hlo_parser

# primitive names across the supported jax version range (0.4.x floor —
# current): shard_map lowers lax.psum to psum2/psum_invariant on some
# versions, all_gather keeps its name everywhere.
COLLECTIVE_PRIMS = frozenset({
    "psum", "psum2", "psum_invariant", "pmax", "pmin",
    "all_gather", "all_gather_invariant", "all_to_all", "ppermute",
    "pbroadcast", "reduce_scatter", "psum_scatter", "pgather",
})


@dataclasses.dataclass(frozen=True)
class CollectiveUse:
    prim: str
    axes: tuple[str, ...]
    out_shapes: tuple[tuple[int, ...], ...]

    @property
    def elements(self) -> int:
        total = 0
        for shape in self.out_shapes:
            n = 1
            for d in shape:
                n *= int(d)
            total += n
        return total


def _sub_jaxprs(eqn):
    """Every jaxpr nested in an equation's params (while/scan/cond/pjit/
    shard_map/custom_* — matched structurally, not by primitive name, so
    version drift in param spellings cannot hide a nesting level)."""
    subs = []

    def visit(val):
        if hasattr(val, "jaxpr") and hasattr(val.jaxpr, "eqns"):
            subs.append(val.jaxpr)          # ClosedJaxpr
        elif hasattr(val, "eqns"):
            subs.append(val)                # raw Jaxpr
        elif isinstance(val, (tuple, list)):
            for v in val:
                visit(v)

    for val in eqn.params.values():
        visit(val)
    return subs


def jaxpr_collectives(jaxpr) -> list[CollectiveUse]:
    """All collective-primitive uses in ``jaxpr`` (recursive)."""
    if hasattr(jaxpr, "jaxpr"):            # ClosedJaxpr
        jaxpr = jaxpr.jaxpr
    uses: list[CollectiveUse] = []
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            axes = eqn.params.get("axes",
                                  eqn.params.get("axis_name", ()))
            if isinstance(axes, str):
                axes = (axes,)
            axes = tuple(str(a) for a in axes)
            shapes = tuple(tuple(int(d) for d in v.aval.shape)
                           for v in eqn.outvars)
            uses.append(CollectiveUse(prim=name, axes=axes,
                                      out_shapes=shapes))
        for sub in _sub_jaxprs(eqn):
            uses.extend(jaxpr_collectives(sub))
    return uses


def hlo_collective_shapes(hlo_text: str) -> list[tuple[str, tuple[int, ...]]]:
    """(op, result dims) for every collective instruction in the HLO text,
    sorted — the d-independence comparison key for the compiled view."""
    out = []
    for comp in hlo_parser.parse_computations(hlo_text).values():
        for ins in comp.instrs:
            base = ins.op.replace("-start", "")
            if base not in hlo_parser._COLLECTIVES or \
                    ins.op.endswith("-done"):
                continue
            for _, dims in hlo_parser._SHAPE_RE.findall(ins.result_text):
                shape = tuple(int(d) for d in dims.split(",") if d)
                out.append((base, shape))
    return sorted(out)


def hlo_collective_bytes(hlo_text: str) -> float:
    """Trip-count-corrected collective bytes of the compiled module (reuses
    the roofline cost walker)."""
    return hlo_parser.analyze(hlo_text).collective_bytes

"""Layer C: Byzantine taint analysis over the traced production paths.

PAPER.md §1.3: Byzantine reports "create arbitrary and unspecified
dependency among the iterations and the aggregated gradients" — the Thm-3
argument holds only because the sanitizing aggregator is the SOLE channel
from adversary-controlled inputs to the model update.  This module makes
that proof obligation a machine-checked invariant: it marks every
adversary-controlled input of the traced production computation, runs the
``repro.verify.influence`` label engine over the jaxpr, and compares what
comes out against the registry's declared ``sanitization_point``.

Adversary-controlled sources (the taint roots):

* ``report``        — the stacked per-worker gradients, their compressed
                      wire payloads, AND the per-worker codec scales
                      (scales are derived from the reports inside the
                      traced encode, so they inherit the taint without
                      special-casing), plus buffered stale reports.
* ``age``           — per-worker arrival ages in the ``StalenessBuffer``
                      (an asynchronous adversary controls its own timing).
* ``attack_state``  — the attack schedule's carried memory.

Three check surfaces:

* **per-aggregator influence certificates** (RV301/RV303): the unsharded
  ``aggregate_reported`` path and the ``make_sharded_aggregate`` /
  ``shard_map`` path, per wire codec — the aggregator × codec × shard-mode
  matrix.  The classification never reads the declaration; it rediscovers
  the bounded-op family from dataflow and compares after.
* **the multi-round trainer** (RV301/RV302): ``make_run_rounds``'s scanned
  round body with a stateful attack schedule, a straggler arrival
  schedule, the int8 wire, and the staleness buffer — proving reports
  reach params/opt_state only BOUNDED and that report taint never steers
  cross-round control state (ages, bounds, metrics) outside the
  documented ``γ^age`` discount path of docs/ASYNC.md.

The declared↔discovered comparison (RV303) runs only on an aggregator's
*canonical* cell — its native codec (or ``none``) — because a foreign
codec can legitimately change the certificate: ``mean`` over sign-decoded
±1 values IS bounded (that's just an unnormalized sign vote), which says
nothing about ``mean``'s declaration.  RV301 (declared sanitizer bypassed
by a RAW path) applies to every cell.
"""

from __future__ import annotations

import dataclasses

from repro.verify import influence
from repro.verify.rules import Finding

ROUND_ANCHOR = "<round:make_run_rounds>"

# the round-trace harness configuration: every PR-8/PR-9 adversary surface
# at once — stateful attack memory, straggler arrivals feeding the
# staleness buffer, and the int8 wire with per-worker scales.
_ROUND_M = 6
_ROUND_Q = 1
_ROUND_K = 3
_ROUND_BOUND = 2
_ROUND_ROUNDS = 2


def _raw(source: str) -> influence.Label:
    return influence.raw(source)


def _labels_for(tree, label: influence.Label) -> list[influence.Label]:
    import jax
    return [label] * len(jax.tree.leaves(tree))


def _leaf_paths(tree) -> list[str]:
    import jax
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) or "<leaf>" for path, _leaf in flat]


@dataclasses.dataclass(frozen=True)
class TaintReport:
    """The influence certificate of one aggregator × codec × mode cell."""
    name: str
    codec: str
    mode: str
    leaves: tuple    # ((path, Label), ...) per output leaf

    @property
    def level(self) -> int:
        return max((l.level for _, l in self.leaves),
                   default=influence.CLEAN)

    @property
    def kinds(self) -> frozenset:
        out = frozenset()
        for _, l in self.leaves:
            out |= l.kinds
        return out

    @property
    def bounded(self) -> bool:
        return self.level < influence.RAW

    def raw_leaves(self):
        return [(p, l) for p, l in self.leaves
                if l.level == influence.RAW]


def classify_aggregator(name: str, *, codec: str | None = None,
                        mode: str = "unsharded", num_shards: int = 4,
                        seed: int = 0) -> TaintReport:
    """Trace one production cell and propagate report taint through it."""
    import jax
    from repro.core import aggregators
    from repro.verify import contracts

    agg = aggregators.get_aggregator(name)
    codec = codec or agg.native_codec or "none"
    if mode == "unsharded":
        jaxpr, out_shape, args = contracts.traced_flat(
            name, seed=seed, codec=codec)
    elif mode == "shard_map":
        jaxpr, out_shape, args = contracts.traced_shard_map(
            name, num_shards=num_shards, scale=1, seed=seed, codec=codec)
    else:
        raise ValueError(f"unknown taint mode {mode!r}")

    stacked, key = args
    in_labels = _labels_for(stacked, _raw("report")) + \
        _labels_for(key, influence.CLEAN_LABEL)
    out_labels = influence.run_jaxpr(jaxpr, in_labels)
    paths = _leaf_paths(out_shape)
    if len(paths) != len(out_labels):
        raise RuntimeError(
            f"taint engine returned {len(out_labels)} output labels for "
            f"{len(paths)} output leaves ({name} × {codec} × {mode})")
    return TaintReport(name=name, codec=codec, mode=mode,
                       leaves=tuple(zip(paths, out_labels)))


def check_aggregator_taint(name: str, *, codec: str | None = None,
                           mode: str = "unsharded", num_shards: int = 4,
                           seed: int = 0,
                           certify: bool = True) -> list[Finding]:
    """RV301/RV303 findings for one aggregator × codec × mode cell.

    ``certify=False`` (non-canonical codec cells of the full matrix) keeps
    only the RV301 sanitizer-bypass check — see the module docstring.
    """
    from repro.core import aggregators
    from repro.verify.contracts import _anchor

    agg = aggregators.get_aggregator(name)
    declared = agg.sanitization_point
    rep = classify_aggregator(name, codec=codec, mode=mode,
                              num_shards=num_shards, seed=seed)
    anchor = _anchor(name)
    findings: list[Finding] = []

    if declared is not None:
        for path, label in rep.raw_leaves():
            findings.append(Finding(
                rule="RV301", path=anchor, line=0, col=0,
                message=f"declares sanitization_point={declared!r} but "
                        f"output leaf {path} carries RAW worker-report "
                        f"influence ({label.describe()}) under codec "
                        f"{rep.codec!r} / {mode} — a report reaches the "
                        f"update path without passing the sanitizer"))

    if certify:
        if declared is None and rep.bounded:
            findings.append(Finding(
                rule="RV303", path=anchor, line=0, col=0,
                message=f"declares no sanitization_point but every "
                        f"report→output path is bounded by dataflow "
                        f"(discovered kinds: {sorted(rep.kinds)}) under "
                        f"codec {rep.codec!r} / {mode} — the declaration "
                        f"is stale: declare the sanitizer"))
        if declared is not None and rep.bounded and \
                declared not in rep.kinds:
            findings.append(Finding(
                rule="RV303", path=anchor, line=0, col=0,
                message=f"declared sanitization_point {declared!r} does "
                        f"not appear on the report→output dataflow "
                        f"(discovered bounded ops: {sorted(rep.kinds)}) "
                        f"under codec {rep.codec!r} / {mode} — stale or "
                        f"wrong declaration"))
    return findings


# --------------------------------------------------------------------------
# the multi-round trainer trace (RV301 + RV302)


def _round_harness(seed: int):
    """(closed_jaxpr, out_shape, in_labels) for a 2-round scanned run with
    every adversary surface live at once."""
    import jax
    import jax.numpy as jnp
    from repro.core import byzantine, staleness
    from repro.core.robust_train import RobustConfig, make_run_rounds
    from repro.optim.optimizers import sgd
    from repro.verify.contracts import _fill

    m, q, k = _ROUND_M, _ROUND_Q, _ROUND_K
    cfg = RobustConfig(
        num_workers=m, num_byzantine=q, num_batches=k,
        aggregator="int8_gmom", attack="sign_flip",
        compression="int8_stochastic",
        arrival="straggler_fixed", staleness_bound=_ROUND_BOUND,
        gmom_max_iters=4, gmom_tol=1e-6, round_backend="reference")
    schedule = byzantine.make_schedule(
        "stealth_then_strike", num_workers=m, num_byzantine=q)
    arrival = staleness.make_arrival(
        "straggler_fixed", num_workers=m, staleness_bound=_ROUND_BOUND)

    params = {"w": _fill((4,), 19)}

    def loss_fn(p, batch):
        pred = batch["x"] @ p["w"]
        return 0.5 * jnp.mean(
            jnp.square(pred - batch["y"]).astype(jnp.float32))

    worker_batches = {"x": _fill((m, 2, 4), 23), "y": _fill((m, 2), 29)}
    optimizer = sgd(0.1)
    opt_state = optimizer.init(params)
    astate = schedule.init_state()
    sbuf = staleness.init_buffer(params, m, _ROUND_BOUND)
    key = jax.random.PRNGKey(seed)

    run = make_run_rounds(loss_fn, optimizer, cfg, schedule=schedule,
                          arrival=arrival)

    def fn(p, o, b, kk, a, s):
        return run(p, o, b, kk, num_rounds=_ROUND_ROUNDS,
                   attack_state=a, stale_buffer=s)

    jaxpr, out_shape = jax.make_jaxpr(fn, return_shape=True)(
        params, opt_state, worker_batches, key, astate, sbuf)

    # taint roots: the attack schedule's memory, the buffered last reports,
    # and the per-worker ages.  Honest worker batches / params / keys stay
    # CLEAN — marking honest data would (correctly!) flag the loss metrics
    # and drown the adversary-specific signal.
    in_labels = (
        _labels_for(params, influence.CLEAN_LABEL)
        + _labels_for(opt_state, influence.CLEAN_LABEL)
        + _labels_for(worker_batches, influence.CLEAN_LABEL)
        + _labels_for(key, influence.CLEAN_LABEL)
        + _labels_for(astate, _raw("attack_state"))
        + _labels_for(sbuf.grads, _raw("report"))
        + _labels_for(sbuf.age, _raw("age"))
        + _labels_for(sbuf.bound, influence.CLEAN_LABEL)
    )
    return jaxpr, out_shape, in_labels


def classify_round(*, seed: int = 0):
    """[(section, leaf_path, Label), ...] over the round-trace outputs
    (params, opt_state, attack_state, stale_buffer, metrics)."""
    import jax
    jaxpr, out_shape, in_labels = _round_harness(seed)
    out_labels = influence.run_jaxpr(jaxpr, in_labels)

    p_sh, o_sh, a_sh, s_sh, m_sh = out_shape
    sections = [
        ("params", p_sh), ("opt_state", o_sh), ("attack_state", a_sh),
        ("stale_buffer.grads", s_sh.grads), ("stale_buffer.age", s_sh.age),
        ("stale_buffer.bound", s_sh.bound), ("metrics", m_sh),
    ]
    rows = []
    it = iter(out_labels)
    for section, sub in sections:
        paths = _leaf_paths(sub)
        for path in paths:
            rows.append((section, path, next(it)))
    leftover = sum(1 for _ in it)
    if leftover:
        raise RuntimeError(
            f"round-trace section split dropped {leftover} output labels")
    return rows


def check_round_taint(*, seed: int = 0) -> list[Finding]:
    """RV301/RV302 over the scanned multi-round trainer.

    * params / opt_state must never be RAW: reports reach the TrainState
      update only through the aggregator's bounded channel (RV301).
    * metrics outlive the round inside TrainState's history — RAW report
      influence there is the cross-iteration dependency §1.3 excludes
      (RV302).  BOUNDED is fine (byz/stale counts are capped by design).
    * the staleness ages and bound may depend on timing (``age``) and on
      attack scheduling (``attack_state`` — ``byzantine_max_stale``
      legitimately routes the byz mask into arrivals per docs/ASYNC.md),
      but never on report VALUES: a report steering its own future weight
      outside the γ^age discount is RV302.
    * attack_state and the buffered reports are adversary memory by
      definition — exempt.
    """
    findings: list[Finding] = []
    for section, path, label in classify_round(seed=seed):
        where = f"{section}{path}"
        if section in ("params", "opt_state"):
            if label.level == influence.RAW:
                findings.append(Finding(
                    rule="RV301", path=ROUND_ANCHOR, line=0, col=0,
                    message=f"{where} carries RAW adversary influence "
                            f"({label.describe()}) after a full round — "
                            f"reports must reach the TrainState update "
                            f"only through the sanitizing aggregator"))
        elif section == "metrics":
            if label.level == influence.RAW:
                findings.append(Finding(
                    rule="RV302", path=ROUND_ANCHOR, line=0, col=0,
                    message=f"{where} carries RAW adversary influence "
                            f"({label.describe()}) — metrics history "
                            f"outlives the round inside TrainState"))
        elif section in ("stale_buffer.age", "stale_buffer.bound"):
            if "report" in label.sources:
                findings.append(Finding(
                    rule="RV302", path=ROUND_ANCHOR, line=0, col=0,
                    message=f"{where} depends on report VALUES "
                            f"({label.describe()}) — ages/bounds may "
                            f"couple rounds only through arrival timing "
                            f"and attack scheduling (docs/ASYNC.md), "
                            f"never through what a worker sent"))
        # attack_state / stale_buffer.grads: adversary memory, exempt.
    return findings


# --------------------------------------------------------------------------
# CLI driver


def run_taint(*, aggregators_filter=None, full_matrix: bool = False,
              num_shards: int = 4, seed: int = 0,
              log=print) -> list[Finding]:
    """The Layer C pass: per-aggregator certificates (native codec in
    tier-1, the full codec matrix nightly) in both shard modes, then the
    multi-round trace."""
    from repro.core import aggregators as agg_mod

    names = [n for n in agg_mod.available() if not n.startswith("_")]
    if aggregators_filter:
        unknown = sorted(set(aggregators_filter) - set(agg_mod.available()))
        if unknown:
            raise SystemExit(f"unknown aggregator(s): {', '.join(unknown)}")
        names = [n for n in agg_mod.available() if n in aggregators_filter]

    all_codecs = ["none", "sign", "int8_stochastic"]
    findings: list[Finding] = []
    for name in names:
        native = agg_mod.get_aggregator(name).native_codec or "none"
        codecs = all_codecs if full_matrix else [native]
        for codec in codecs:
            for mode in ("unsharded", "shard_map"):
                log(f"[verify] layer C: {name} × {codec} × {mode}")
                findings.extend(check_aggregator_taint(
                    name, codec=codec, mode=mode, num_shards=num_shards,
                    seed=seed, certify=(codec == native)))
    log("[verify] layer C: round trace "
        "(scan × stealth attack × staleness × int8 wire)")
    findings.extend(check_round_taint(seed=seed))
    return findings

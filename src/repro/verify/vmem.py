"""Layer B, RV204: static VMEM-budget audit for the fused round kernel.

Three invariants, checked without running (or even tracing) the kernel:

1. ``VMEM_BUDGET_BYTES <= DEVICE_VMEM_BYTES`` — the provisioning budget
   must fit the declared per-core capacity.
2. The dispatcher's ``fits_vmem(m, k, d)`` and the kernel's own
   ``_check_vmem`` guard agree on a (m, k, d) grid spanning both sides of
   the budget boundary: ``fits_vmem`` True  ⟺  the guard does not raise,
   with the exact ``extra_bytes`` the round kernel passes.  The two
   formulas live ~40 lines apart and share only by convention — this is
   the drift gate.
3. The paper's own scale fits: m=50 workers, k ∈ {11, 25} batches
   (§4's q=5 / q=12 regimes at 2q+1 resp. the uneven split), d=100 — the
   fused path must cover every configuration the repro actually runs.
"""

from __future__ import annotations

import itertools

from repro.verify.rules import Finding

# grid spanning the budget boundary: with k=64 the (k+1)*d_pad term
# crosses 8 MiB between d=7680 and d=8192, so both guard outcomes occur.
GRID_M = (8, 50, 128)
GRID_K = (4, 11, 25, 64)
GRID_D = (100, 512, 4096, 7680, 8192, 32768, 131072)

PAPER_SHAPES = ((50, 11, 100), (50, 25, 100))

_PATH = "src/repro/kernels/geomed/round.py"


def _guard_ok(round_mod, m: int, k: int, d: int) -> bool:
    """Does the kernel's own _check_vmem accept this shape (with the exact
    extra_bytes round_aggregate_kernel passes)?"""
    tile_d = round_mod.TILE_D
    d_pad = -(-d // tile_d) * tile_d
    try:
        round_mod._check_vmem(k, d_pad,
                              extra_bytes=(m * tile_d + k * m) * 4)
        return True
    except ValueError:
        return False


def check_vmem_budget() -> list[Finding]:
    from repro.kernels.geomed import round as round_mod

    findings: list[Finding] = []
    budget = round_mod.VMEM_BUDGET_BYTES
    device = round_mod.DEVICE_VMEM_BYTES
    if budget > device:
        findings.append(Finding(
            rule="RV204", path=_PATH, line=0, col=0,
            message=f"VMEM_BUDGET_BYTES={budget} exceeds the declared "
                    f"DEVICE_VMEM_BYTES={device}"))

    for m, k, d in itertools.product(GRID_M, GRID_K, GRID_D):
        fits = round_mod.fits_vmem(m, k, d)
        guard = _guard_ok(round_mod, m, k, d)
        if fits != guard:
            findings.append(Finding(
                rule="RV204", path=_PATH, line=0, col=0,
                message=f"fits_vmem and _check_vmem disagree at "
                        f"(m={m}, k={k}, d={d}): dispatcher says "
                        f"{'fits' if fits else 'reject'}, kernel guard "
                        f"says {'fits' if guard else 'reject'} — the two "
                        f"formulas drifted"))

    for m, k, d in PAPER_SHAPES:
        if not round_mod.fits_vmem(m, k, d):
            findings.append(Finding(
                rule="RV204", path=_PATH, line=0, col=0,
                message=f"paper-scale shape (m={m}, k={k}, d={d}) no "
                        f"longer fits the fused-kernel VMEM budget "
                        f"({round_mod.round_resident_bytes(m, k, d)} B > "
                        f"{budget} B)"))
    return findings

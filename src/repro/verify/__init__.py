"""repro.verify — three-layer invariant checker.

Layer A: AST lint of ``src/`` against the RV1xx rules (no jax import —
safe anywhere).  Layer B: jaxpr/HLO contract analysis of every registered
aggregator plus the static VMEM audit (RV2xx; needs an 8-device host
mesh).  Layer C: Byzantine taint/influence analysis — every worker-report
input is marked adversary-controlled and propagated through the traced
aggregators and the production round step; RV3xx fires when taint reaches
TrainState without crossing a bounded-influence sanitizer.  Run as
``python -m repro.verify``; catalog and policy in docs/STATIC_ANALYSIS.md.
"""

from repro.verify.rules import (RULES, Finding, Rule,  # noqa: F401
                                SourceContext, apply_suppressions)
from repro.verify.ast_rules import lint_file, lint_paths  # noqa: F401

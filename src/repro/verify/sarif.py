"""SARIF 2.1.0 serialization of verifier findings.

GitHub code scanning ingests SARIF and renders each result as an inline
PR annotation — so an RV finding shows up on the offending line of the
diff instead of buried in a CI log.  Layer B/C findings anchor to
synthesized paths (``<aggregator:NAME>``, ``<round:...>``) rather than
source files; those are mapped to the registry source file with the
anchor preserved in the message, since SARIF locations must be real
artifact URIs for the annotation UI.
"""

from __future__ import annotations

import json
import os

from repro.verify.rules import RULES, Finding

_SCHEMA = ("https://raw.githubusercontent.com/oasis-tcs/sarif-spec/"
           "master/Schemata/sarif-schema-2.1.0.json")

# where synthesized (non-file) anchors point for annotation purposes: the
# registry whose declarations the Layer B/C analyses verify.
_ANCHOR_URI = "src/repro/core/aggregators.py"


def _uri(path: str) -> tuple[str, str]:
    """(artifact uri, message suffix) for a finding path."""
    if path.startswith("<"):
        return _ANCHOR_URI, f" [{path}]"
    cwd = os.getcwd()
    abspath = os.path.abspath(path)
    if abspath.startswith(cwd + os.sep):
        return os.path.relpath(abspath, cwd).replace(os.sep, "/"), ""
    return path.replace(os.sep, "/"), ""


def _result(f: Finding) -> dict:
    uri, suffix = _uri(f.path)
    region = {"startLine": max(f.line, 1),
              "startColumn": max(f.col + 1, 1)}
    if f.end_line:
        region["endLine"] = f.end_line
        region["endColumn"] = max(f.end_col + 1, 1)
    return {
        "ruleId": f.rule,
        "level": "error",
        "message": {"text": f.message + suffix},
        "locations": [{
            "physicalLocation": {
                "artifactLocation": {"uri": uri},
                "region": region,
            },
        }],
    }


def to_sarif(findings: list[Finding]) -> dict:
    used = sorted({f.rule for f in findings})
    rules = [{
        "id": rid,
        "name": rid,
        "shortDescription": {"text": RULES[rid].title},
        "fullDescription": {"text": RULES[rid].motivation},
        "defaultConfiguration": {"level": "error"},
    } for rid in used if rid in RULES]
    return {
        "$schema": _SCHEMA,
        "version": "2.1.0",
        "runs": [{
            "tool": {"driver": {
                "name": "repro.verify",
                "informationUri":
                    "docs/STATIC_ANALYSIS.md",
                "rules": rules,
            }},
            "results": [_result(f) for f in findings],
        }],
    }


def dump(findings: list[Finding], fp) -> None:
    json.dump(to_sarif(findings), fp, indent=2, ensure_ascii=False)
    fp.write("\n")

"""Rule framework shared by both verifier layers.

A *rule* is a named invariant with a stable ID (``RV1xx`` = Layer A source
lint, ``RV2xx`` = Layer B lowered-IR analysis, ``RV3xx`` = Layer C
Byzantine taint / influence analysis), a one-line title, and the
PR / bug class that motivated it.  A *finding* is one violation with a
precise source span (Layer A) or a synthesized anchor (Layer B, which
reports against the registration site of the offending aggregator).

Escape hatch (Layer A): a source line — or the line directly above it —
carrying::

    # repro: ignore[RV102] <justification>

suppresses that rule's findings on that line.  The justification text is
REQUIRED: an ignore with an empty justification (or naming an unknown rule
ID) still suppresses, but raises the meta-finding ``RV100`` so the build
fails anyway — there is no silent baseline-suppression path.

Module *markers* opt a file into scope for the scoped rules::

    # repro: bit-stable      — RV101 + RV105 (fixed-expression-tree modules)
    # repro: robust-stat     — RV105 only (robust-statistic accumulation)
    # repro: train-scan      — RV106 (training-scan carry discipline)

See docs/STATIC_ANALYSIS.md for the catalog and the policy discussion.
"""

from __future__ import annotations

import ast
import dataclasses
import re


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    title: str
    layer: str        # "A" (AST lint) | "B" (jaxpr/HLO) | "C" (taint)
    motivation: str   # the PR / bug class this rule encodes


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    end_line: int = 0     # 0 = single-line span
    end_col: int = 0

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} " \
               f"{self.message}"


RULES: dict[str, Rule] = {}


def _rule(id: str, title: str, layer: str, motivation: str) -> None:
    RULES[id] = Rule(id=id, title=title, layer=layer, motivation=motivation)


_rule("RV100", "suppression without justification / unknown rule ID", "A",
      "escape-hatch policy: every ignore[...] must say why (zero silent "
      "baseline suppressions — ISSUE 7)")
_rule("RV101", "jnp.sum/jnp.mean over the shard/member axis in a "
      "bit-stable module", "A",
      "PR 6: XLA reassociates short-axis reductions differently per fusion "
      "context (observed 1-ulp virtual-vs-shard_map drift); bit-stable "
      "modules must use the unrolled add-chain helpers of "
      "core/shard_aggregation.py")
_rule("RV102", "literal PRNGKey(<int>) outside tests/entry points", "A",
      "PR 5: random_select's PRNGKey(0) fallback silently downgraded the "
      "rule to a fixed deterministic selection every round")
_rule("RV103", "import-time os.environ / XLA_FLAGS mutation", "A",
      "PR 4: dryrun's import-time XLA_FLAGS write poisoned any process "
      "importing its helpers after their own jax backend init")
_rule("RV104", "aggregators.register call missing metadata "
      "(description / valid shard_contract)", "A",
      "PR 6/7: the Layer-B collective analyzer verifies the *declared* "
      "contract — an undeclared or invalid declaration voids the check")
_rule("RV105", "robust-statistic reduction without f32 accumulation", "A",
      "PR 6: bf16-accumulated means/dots feeding a median/trim/Weiszfeld "
      "stage lose the paper's concentration bounds; accumulate in f32, "
      "cast at the boundary")
_rule("RV106", "training-scan carry element not backed by a TrainState "
      "field", "A",
      "PR 2: bit-exact resume checkpoints exactly TrainState; state that "
      "rides the scan carry outside it silently breaks resume")
_rule("RV107", "StalenessBuffer with non-integer ages or not "
      "TrainState-resident", "A",
      "PR 9: a float age vector drifts under accumulated where/add "
      "rounding and breaks the exact age > τ drop rule; a buffer outside "
      "TrainState is the RV106 lost-carry bug class for the async path")
_rule("RV201", "coordinate_wise aggregator lowers with cross-shard "
      "collectives", "B",
      "PR 6 shard-local contract: coordinate-wise rules must be "
      "collective-free under a partitioned ShardSpec")
_rule("RV202", "norm-based aggregator collective is d-dependent or "
      "oversized", "B",
      "PAPER.md §Thm 3: server cost O(md + kd log³N) rests on partial "
      "reductions of (k,)/(m,)/(m,m) shape — never O(d) cross-shard "
      "traffic")
_rule("RV203", "shard-axis reduce op in a traced aggregator "
      "(bit-unstable across fusion)", "B",
      "PR 6: a jnp.sum over the shard-stack axis re-introduces the "
      "reassociation freedom the unrolled chain_sum removed")
_rule("RV204", "Pallas round-kernel VMEM budget inconsistent with the "
      "declared device limit", "B",
      "PR 3: the dispatcher's fits_vmem() and the kernel's _check_vmem() "
      "guard share a formula only by convention — and the budget must fit "
      "the declared per-core VMEM")
_rule("RV301", "adversary-tainted value reaches the params/opt_state "
      "update without passing the declared sanitization point", "C",
      "PAPER.md §1.3: Byzantine reports create 'arbitrary and unspecified "
      "dependency' — Thm 3 holds only because the geometric median of "
      "means is the SOLE channel from reports to θ; a tainted codec scale "
      "or buffered report added post-aggregation voids the guarantee")
_rule("RV302", "adversary-tainted value flows into control state that "
      "outlives the round outside the documented age-discount path", "C",
      "PR 9: staleness ages and attack timing legitimately couple rounds "
      "per docs/ASYNC.md, but a *report*-derived value steering ages, "
      "bounds, or metrics history re-opens the cross-iteration dependency "
      "the paper's proof excludes")
_rule("RV303", "aggregator influence certificate inconsistent with its "
      "declared sanitization_point", "C",
      "PR 5 soundness split, rediscovered from dataflow: every "
      "report→output path must cross a bounded-influence op (order "
      "statistic / rank selection / clip / sign vote / Weiszfeld) for "
      "ROBUST rules, while KNOWN-UNSOUND rules (mean, norm_select, "
      "norm_clip_mean) must certify unbounded — a stale or wrong "
      "declaration is itself a finding")


# --------------------------------------------------------------------------
# source context: markers + suppressions for one file

IGNORE_RE = re.compile(
    r"#\s*repro:\s*ignore\[([A-Za-z0-9_,\s]+)\]\s*(.*)$")
BIT_STABLE_RE = re.compile(r"#\s*repro:\s*bit-stable\b")
ROBUST_STAT_RE = re.compile(r"#\s*repro:\s*robust-stat\b")
TRAIN_SCAN_RE = re.compile(r"#\s*repro:\s*train-scan\b")


@dataclasses.dataclass
class Suppression:
    line: int
    rule_ids: tuple[str, ...]
    justification: str


class SourceContext:
    """One parsed source file plus its markers and suppressions."""

    def __init__(self, path: str, src: str):
        self.path = path
        self.src = src
        self.lines = src.splitlines()
        self.tree = ast.parse(src, filename=path)
        self.bit_stable = any(BIT_STABLE_RE.search(l) for l in self.lines)
        self.robust_stat = self.bit_stable or any(
            ROBUST_STAT_RE.search(l) for l in self.lines)
        self.train_scan = any(TRAIN_SCAN_RE.search(l) for l in self.lines)
        self.suppressions: list[Suppression] = []
        for i, line in enumerate(self.lines, start=1):
            m = IGNORE_RE.search(line)
            if m is None:
                continue
            ids = tuple(s.strip() for s in m.group(1).split(",") if s.strip())
            self.suppressions.append(
                Suppression(line=i, rule_ids=ids,
                            justification=m.group(2).strip()))

    def suppressed(self, rule: str, line: int) -> bool:
        """True when ``rule`` is ignored at ``line`` (same line or the
        comment line directly above)."""
        for sup in self.suppressions:
            if rule in sup.rule_ids and sup.line in (line, line - 1):
                return True
        return False


def apply_suppressions(findings: list[Finding],
                       ctx: SourceContext) -> list[Finding]:
    """Drop suppressed findings; append RV100 meta-findings for every
    suppression comment that lacks a justification or names an unknown
    rule ID (the suppression still takes effect — RV100 keeps the build
    red, so nothing is *silently* suppressed)."""
    kept = [f for f in findings
            if not ctx.suppressed(f.rule, f.line)]
    for sup in ctx.suppressions:
        unknown = [r for r in sup.rule_ids if r not in RULES]
        if unknown:
            kept.append(Finding(
                rule="RV100", path=ctx.path, line=sup.line, col=0,
                message=f"ignore[...] names unknown rule ID(s) "
                        f"{', '.join(unknown)} — see docs/STATIC_ANALYSIS.md "
                        "for the catalog"))
        if not sup.justification:
            kept.append(Finding(
                rule="RV100", path=ctx.path, line=sup.line, col=0,
                message="ignore[...] without a justification — state why "
                        "the invariant does not apply here "
                        "(docs/STATIC_ANALYSIS.md escape-hatch policy)"))
    return kept

"""Golden metric traces for registered scenarios.

A golden is a canonical-form JSON serialization of a scenario trace: floats
rounded to 6 significant digits, keys sorted, compact separators, trailing
newline — so two runs of the same scenario on the same machine produce
byte-identical files, and any regression in the training/aggregation/attack
stack shows up as a diff against the checked-in file.

Workflow:
    PYTHONPATH=src python -m repro.sim.goldens --check    # compare all
    PYTHONPATH=src python -m repro.sim.goldens --update   # re-record all

When a PR intentionally changes numerics (new aggregator default, different
grouping, ...), re-record and commit the new goldens alongside the change.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "goldens")
_SIG_DIGITS = 6


def canonicalize(obj):
    """Round all floats to 6 significant digits, recursively."""
    if isinstance(obj, bool):
        return obj
    if isinstance(obj, float):
        return float(f"{obj:.{_SIG_DIGITS}g}")
    if isinstance(obj, dict):
        return {k: canonicalize(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [canonicalize(v) for v in obj]
    return obj


def trace_bytes(trace: dict) -> bytes:
    return (json.dumps(canonicalize(trace), sort_keys=True,
                       separators=(",", ": "), indent=0) + "\n").encode()


def golden_path(name: str) -> str:
    return os.path.join(GOLDEN_DIR, name.replace("/", "__") + ".json")


def save_golden(name: str, trace: dict) -> str:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    path = golden_path(name)
    with open(path, "wb") as f:
        f.write(trace_bytes(trace))
    return path


def load_golden(name: str) -> dict:
    with open(golden_path(name), "rb") as f:
        return json.load(f)


def compare_traces(trace: dict, golden: dict, *, rtol: float = 1e-3,
                   atol: float = 1e-6, _path: str = "") -> list[str]:
    """Structural comparison with float tolerance; returns mismatch list
    (empty == match)."""
    trace = canonicalize(trace)
    golden = canonicalize(golden)

    def walk(a, b, path):
        if isinstance(a, dict) and isinstance(b, dict):
            for k in sorted(set(a) | set(b)):
                if k not in a or k not in b:
                    yield f"{path}.{k}: present in only one trace"
                else:
                    yield from walk(a[k], b[k], f"{path}.{k}")
        elif isinstance(a, list) and isinstance(b, list):
            if len(a) != len(b):
                yield f"{path}: length {len(a)} vs {len(b)}"
            else:
                for i, (x, y) in enumerate(zip(a, b)):
                    yield from walk(x, y, f"{path}[{i}]")
        elif isinstance(a, (int, float)) and isinstance(b, (int, float)) \
                and not isinstance(a, bool) and not isinstance(b, bool):
            if abs(a - b) > atol + rtol * max(abs(a), abs(b)):
                yield f"{path}: {a} != {b}"
        elif a != b:
            yield f"{path}: {a!r} != {b!r}"

    return list(walk(trace, golden, _path or "trace"))


def record_all(*, update: bool = False) -> dict[str, list[str]]:
    """Run every golden scenario; compare (or overwrite) its golden file.

    Returns {scenario name: mismatches} — all-empty values mean green.
    """
    from repro.sim.engine import run_scenario
    from repro.sim.scenarios import golden_scenarios

    results: dict[str, list[str]] = {}
    for sc in golden_scenarios():
        trace = run_scenario(sc)
        if update:
            save_golden(sc.name, trace)
            results[sc.name] = []
        elif not os.path.exists(golden_path(sc.name)):
            # check mode must not mutate the tree or green-light a
            # scenario that has no checked-in reference
            results[sc.name] = ["golden file missing — record it with "
                                "`python -m repro.sim.goldens --update`"]
        else:
            results[sc.name] = compare_traces(trace, load_golden(sc.name))
    return results


# The interrupted-resume probe runs a STATEFUL schedule: its adversary
# memory (EMA / latch) is exactly what a params-only resume would lose.
RESUME_CHECK_SCENARIO = "linreg/gmom/sign_flip/stealth_then_strike"


def check_resume_replay(name: str = RESUME_CHECK_SCENARIO) -> list[str]:
    """Interrupt a checkpointed replay mid-run, resume it from the saved
    TrainState, and compare the stitched trace against the golden.

    Any state the checkpoint fails to carry (optimizer moments, attack
    state, PRNG key, metrics history) shows up as a trace mismatch.
    Returns the mismatch list (empty == bit-exact resume).
    """
    from repro.sim.engine import replay_scenario
    from repro.sim.scenarios import get_scenario

    sc = get_scenario(name)
    half = max(1, sc.rounds // 2)
    with tempfile.TemporaryDirectory(prefix="golden_resume_") as ckpt_dir:
        replay_scenario(sc, ckpt_dir, rounds=half, ckpt_every=5)   # "crash"
        trace = replay_scenario(sc, ckpt_dir, ckpt_every=5)        # resume
    return compare_traces(trace, load_golden(name))


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--update", action="store_true",
                   help="re-record all golden traces")
    p.add_argument("--check", action="store_true",
                   help="compare current traces against checked-in goldens "
                        "(also replays one interrupted-resume run)")
    p.add_argument("--list", action="store_true",
                   help="list golden scenarios and exit")
    args = p.parse_args(argv)
    if args.list:
        from repro.sim.scenarios import golden_scenarios
        for sc in golden_scenarios():
            print(sc.name, "->", golden_path(sc.name))
        return 0
    results = record_all(update=args.update)
    if args.check:
        results[f"resume-replay({RESUME_CHECK_SCENARIO})"] = \
            check_resume_replay()
    bad = {k: v for k, v in results.items() if v}
    for name in results:
        status = "MISMATCH" if name in bad else \
            ("updated" if args.update else "ok")
        print(f"[goldens] {name}: {status}")
        for line in bad.get(name, [])[:8]:
            print(f"    {line}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())

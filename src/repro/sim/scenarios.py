"""Adversarial scenario registry.

A ``Scenario`` pins every degree of freedom of one multi-round Byzantine
campaign on the paper's linear-regression testbed (§4): the aggregator, the
attack, the multi-round ``AttackSchedule``, the (m, q, k) fault geometry, the
data dimensions, and a deterministic seed.  The registry enumerates the
attack × schedule × aggregator matrix the test suite and benchmarks sweep;
``golden=True`` scenarios additionally have compact metric traces checked in
under ``sim/goldens/`` (see repro.sim.goldens) so any future perf/scale PR
regression-tests against byte-stable trajectories.

Add a scenario by calling ``register(Scenario(...))`` here (or from a test);
add a new attack/schedule in core/byzantine.py and it can be referenced by
name immediately.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    # which substrate realizes the scenario: "linreg" (the paper's §4
    # testbed, run end-to-end by repro.sim.engine) or any architecture id
    # from repro.configs.ARCHITECTURES.  Production architectures are
    # exercised through the dry-run pod sweep (repro.sim.sweep.PodScenario
    # binds the same attack/schedule/aggregator axes to an (arch, shape,
    # mesh) triple); engine.run_scenario rejects them until the LM-substrate
    # golden workflow lands (ROADMAP "Scenario engine on LM substrates").
    arch: str = "linreg"
    aggregator: str = "gmom"
    attack: str = "sign_flip"
    schedule: str = "rotating"
    attack_kwargs: tuple = ()        # tuple of (key, value) — hashable
    schedule_kwargs: tuple = ()
    # wire codec applied to worker reports before aggregation (a registered
    # name from core/compression.py); "none" keeps the uncompressed float
    # path every pre-existing scenario was recorded on.
    compression: str = "none"
    # arrival model + staleness bound (core/staleness.py, docs/ASYNC.md);
    # "all_sync"/0 is the synchronous path every pre-existing scenario was
    # recorded on (identical HLO — no buffer in the carry).
    arrival: str = "all_sync"
    staleness_bound: int = 0
    arrival_kwargs: tuple = ()       # tuple of (key, value) — hashable
    num_workers: int = 20            # m
    num_byzantine: int = 3           # q
    num_batches: int | None = 10     # k (None => paper's canonical choice)
    dim: int = 20                    # d
    total_samples: int = 4000        # N
    noise_std: float = 1.0
    rounds: int = 40                 # O(log N) per the paper
    step_size: float = 0.5           # eta = L/(2M^2) = 1/2 for linreg
    seed: int = 0
    golden: bool = False             # trace checked in under sim/goldens/

    @property
    def paper_floor(self) -> float:
        """The paper's headline error scale sqrt(d (2q+1) / N)."""
        return math.sqrt(self.dim * (2 * self.num_byzantine + 1)
                         / self.total_samples)


_REGISTRY: dict[str, Scenario] = {}


def register(sc: Scenario) -> Scenario:
    if sc.name in _REGISTRY:
        raise ValueError(f"scenario {sc.name!r} already registered")
    _REGISTRY[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    if name not in _REGISTRY:
        raise KeyError(f"unknown scenario {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available() -> list[str]:
    return sorted(_REGISTRY)


def golden_scenarios() -> list[Scenario]:
    return [sc for _, sc in sorted(_REGISTRY.items()) if sc.golden]


def _n(agg, attack, schedule) -> str:
    return f"linreg/{agg}/{attack}/{schedule}"


# ---------------------------------------------------------------------------
# the registry

# Headline claim (Theorem 1 / Corollary 1): GMoM converges under EVERY
# attack × schedule while 2(1+eps)q <= k — the adversary's round-to-round
# adaptivity ("arbitrary and unspecified dependency among the iterations")
# buys it nothing.
for _attack in ("sign_flip", "zero", "random_noise", "inner_product",
                "mean_shift", "alie", "norm_stealth"):
    for _schedule in ("static", "rotating"):
        register(Scenario(name=_n("gmom", _attack, _schedule),
                          attack=_attack, schedule=_schedule))

for _schedule in ("ramp_up", "coordinated_switch", "stealth_then_strike"):
    register(Scenario(name=_n("gmom", "sign_flip", _schedule),
                      schedule=_schedule))

# Algorithm 1 (mean) baseline: breaks under a single adversarial round,
# converges failure-free.
register(Scenario(name=_n("mean", "sign_flip", "rotating"),
                  aggregator="mean"))
register(Scenario(name=_n("mean", "none", "static"), aggregator="mean",
                  attack="none", schedule="static", num_byzantine=0,
                  num_batches=1))

# Related-work baselines (Yin et al. '18 trimmed mean; BMGS17 Krum; k=m
# geomed) against both a classic large-norm attack and the small-norm ALIE.
for _agg in ("trimmed_mean", "coordinate_median", "krum", "geomed"):
    for _attack in ("sign_flip", "alie"):
        register(Scenario(name=_n(_agg, _attack, "rotating"),
                          aggregator=_agg, attack=_attack))

# Communication-compressed campaign (Jin et al. '19 signSGD majority vote):
# workers report 1-bit packed sign words and the server votes on the wire
# payload without ever reconstructing float gradients.  Sign steps have unit
# per-coordinate magnitude regardless of the gradient scale, so the step
# size drops to keep the sign-descent error floor (~ eta * sqrt(d)) well
# under the estimation scale of the testbed.
register(Scenario(name="linreg/sign_majority_static",
                  aggregator="sign_sgd_majority", attack="sign_flip",
                  schedule="static", compression="sign",
                  step_size=0.05, golden=True))

# Bounded-staleness campaign (docs/ASYNC.md): a rotating random straggler
# pair delivers up to τ=2-round-old buffered gradients while the rotating
# sign_flip colluders stay live — GMoM under asynchrony + attack at once.
# Golden: the trace (incl. per-round stale_count) is byte-stable and replays
# bit-exactly through interrupted resume with a non-empty buffer.
register(Scenario(name=_n("gmom", "sign_flip", "rotating") + "/stale",
                  arrival="straggler_rotating", staleness_bound=2,
                  golden=True))

# Checked-in golden traces: one per schedule family plus the mean baselines
# and one related-work aggregator — compact but covers every code path.
_GOLDEN = (
    _n("gmom", "sign_flip", "rotating"),
    _n("gmom", "alie", "static"),
    _n("gmom", "norm_stealth", "rotating"),
    _n("gmom", "sign_flip", "ramp_up"),
    _n("gmom", "sign_flip", "coordinated_switch"),
    _n("gmom", "sign_flip", "stealth_then_strike"),
    _n("mean", "sign_flip", "rotating"),
    _n("mean", "none", "static"),
    _n("trimmed_mean", "alie", "rotating"),
)
for _name in _GOLDEN:
    _REGISTRY[_name] = dataclasses.replace(_REGISTRY[_name], golden=True)

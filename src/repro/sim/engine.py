"""Scenario execution: one registry entry -> one scan-compiled run -> trace.

``run_scenario`` realizes a Scenario on the paper's linear-regression data
model, rolls all rounds into a single ``make_run_rounds`` scan, and returns a
compact metrics trace (estimation error vs the true θ*, aggregate-gradient
norm and loss per round) suitable for golden comparison (repro.sim.goldens).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import RobustConfig, byzantine, make_run_rounds
from repro.data import regression
from repro.sim.scenarios import Scenario, get_scenario


def build_schedule(sc: Scenario) -> byzantine.AttackSchedule:
    return byzantine.make_schedule(
        sc.schedule, num_workers=sc.num_workers,
        num_byzantine=sc.num_byzantine, attack=sc.attack,
        attack_kwargs=sc.attack_kwargs, **dict(sc.schedule_kwargs))


def run_scenario(sc: Scenario | str, *, rounds: int | None = None) -> dict:
    """Run one scenario end to end; returns a JSON-ready trace dict."""
    if isinstance(sc, str):
        sc = get_scenario(sc)
    rounds = sc.rounds if rounds is None else rounds

    key = jax.random.PRNGKey(sc.seed)
    ds = regression.generate(key, dim=sc.dim, total_samples=sc.total_samples,
                             num_workers=sc.num_workers,
                             noise_std=sc.noise_std)
    rc = RobustConfig(num_workers=sc.num_workers,
                      num_byzantine=sc.num_byzantine,
                      num_batches=sc.num_batches,
                      aggregator=sc.aggregator, attack=sc.attack,
                      attack_kwargs=sc.attack_kwargs)
    opt = optim.sgd(sc.step_size)
    theta_star = ds.theta_star

    def extra_metrics(params, agg_grad):
        del agg_grad
        return {"est_error": jnp.linalg.norm(params - theta_star)}

    run = make_run_rounds(regression.squared_loss, opt, rc,
                          schedule=build_schedule(sc),
                          extra_metrics=extra_metrics)
    theta0 = jnp.zeros((sc.dim,))
    theta, _, _, metrics = run(theta0, opt.init(theta0),
                               regression.worker_batches(ds),
                               jax.random.fold_in(key, 999),
                               num_rounds=rounds)

    return {
        "scenario": sc.name,
        "aggregator": sc.aggregator,
        "attack": sc.attack,
        "schedule": sc.schedule,
        "num_workers": sc.num_workers,
        "num_byzantine": sc.num_byzantine,
        "num_batches": rc.resolved_num_batches(),
        "dim": sc.dim,
        "total_samples": sc.total_samples,
        "rounds": rounds,
        "seed": sc.seed,
        "paper_floor": sc.paper_floor,
        "final_est_error": float(metrics["est_error"][-1]),
        "final_loss_median": float(metrics["loss_median"][-1]),
        "est_error": [float(v) for v in metrics["est_error"]],
        "agg_grad_norm": [float(v) for v in metrics["agg_grad_norm"]],
        "loss_median": [float(v) for v in metrics["loss_median"]],
        "byz_count": [int(v) for v in metrics["byz_count"]],
    }

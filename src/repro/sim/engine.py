"""Scenario execution: one registry entry -> one scan-compiled run -> trace.

``run_scenario`` realizes a Scenario on the paper's linear-regression data
model, rolls all rounds into a single ``make_run_rounds`` scan, and returns a
compact metrics trace (estimation error vs the true θ*, aggregate-gradient
norm and loss per round) suitable for golden comparison (repro.sim.goldens).

``replay_scenario`` is the checkpointed twin: it runs the same scenario in
chunks, saving the full ``TrainState`` (params + opt_state + attack_state +
round + key + metrics history) at every chunk boundary, and — when the
checkpoint directory already holds state — resumes from the latest
checkpoint instead of round zero.  Chunked/interrupted/resumed execution is
bit-identical to the single-scan run, so goldens can be replayed from any
intermediate checkpoint (``python -m repro.sim.goldens --check`` exercises
one interrupted resume on every invocation).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import (RobustConfig, byzantine, init_train_state,
                        make_run_rounds, restore_train_state,
                        save_train_state, staleness)
from repro.core.train_state import TrainState, advance
from repro.data import regression
from repro.sim.scenarios import Scenario, get_scenario


def build_schedule(sc: Scenario) -> byzantine.AttackSchedule:
    return byzantine.make_schedule(
        sc.schedule, num_workers=sc.num_workers,
        num_byzantine=sc.num_byzantine, attack=sc.attack,
        attack_kwargs=sc.attack_kwargs, **dict(sc.schedule_kwargs))


def _build_run(sc: Scenario, *, round_backend: str = "auto"):
    """Shared setup: (runner, round-zero TrainState, worker_batches, rc).

    ``round_backend`` selects the gmom hot-path lowering (see
    ``core.aggregators``): the default ``auto`` resolves to the jnp
    reference pipeline on CPU — the path every golden trace is recorded
    on — and the fused Pallas round kernel on TPU; tests force
    ``fused_interpret`` to replay goldens through the kernel."""
    if sc.arch != "linreg":
        raise NotImplementedError(
            f"scenario {sc.name!r} targets arch {sc.arch!r}: the end-to-end "
            "engine only runs the linreg substrate; production architectures "
            "go through the dry-run pod sweep (repro.sim.sweep)")
    key = jax.random.PRNGKey(sc.seed)
    ds = regression.generate(key, dim=sc.dim, total_samples=sc.total_samples,
                             num_workers=sc.num_workers,
                             noise_std=sc.noise_std)
    rc = RobustConfig(num_workers=sc.num_workers,
                      num_byzantine=sc.num_byzantine,
                      num_batches=sc.num_batches,
                      aggregator=sc.aggregator, attack=sc.attack,
                      attack_kwargs=sc.attack_kwargs,
                      round_backend=round_backend,
                      compression=sc.compression,
                      arrival=sc.arrival,
                      staleness_bound=sc.staleness_bound,
                      arrival_kwargs=sc.arrival_kwargs)
    opt = optim.sgd(sc.step_size)
    theta_star = ds.theta_star

    def extra_metrics(params, agg_grad):
        del agg_grad
        return {"est_error": jnp.linalg.norm(params - theta_star)}

    schedule = build_schedule(sc)
    arrival = staleness.arrival_from_config(rc)
    run = make_run_rounds(regression.squared_loss, opt, rc,
                          schedule=schedule, extra_metrics=extra_metrics,
                          arrival=arrival)
    theta0 = jnp.zeros((sc.dim,))
    state = init_train_state(theta0, opt.init(theta0),
                             jax.random.fold_in(key, 999),
                             schedule=schedule, arrival=arrival)
    return run, state, regression.worker_batches(ds), rc, schedule, arrival


def _trace(sc: Scenario, rc: RobustConfig, rounds: int, metrics) -> dict:
    trace = {
        "scenario": sc.name,
        "aggregator": sc.aggregator,
        "attack": sc.attack,
        "schedule": sc.schedule,
        "num_workers": sc.num_workers,
        "num_byzantine": sc.num_byzantine,
        "num_batches": rc.resolved_num_batches(),
        "dim": sc.dim,
        "total_samples": sc.total_samples,
        "rounds": rounds,
        "seed": sc.seed,
        "paper_floor": sc.paper_floor,
        "final_est_error": float(metrics["est_error"][-1]),
        "final_loss_median": float(metrics["loss_median"][-1]),
        "est_error": [float(v) for v in metrics["est_error"]],
        "agg_grad_norm": [float(v) for v in metrics["agg_grad_norm"]],
        "loss_median": [float(v) for v in metrics["loss_median"]],
        "byz_count": [int(v) for v in metrics["byz_count"]],
    }
    # only compressed scenarios carry the codec key: adding it
    # unconditionally would invalidate every pre-existing golden file
    # (compare_traces flags keys present in only one trace)
    if sc.compression != "none":
        trace["compression"] = sc.compression
    # same discipline for the async path: only staleness-enabled scenarios
    # carry the arrival keys and the per-round stale_count
    if sc.arrival != "all_sync" or sc.staleness_bound > 0:
        trace["arrival"] = sc.arrival
        trace["staleness_bound"] = sc.staleness_bound
        trace["stale_count"] = [int(v) for v in metrics["stale_count"]]
    return trace


def run_scenario(sc: Scenario | str, *, rounds: int | None = None,
                 round_backend: str = "auto") -> dict:
    """Run one scenario end to end; returns a JSON-ready trace dict."""
    if isinstance(sc, str):
        sc = get_scenario(sc)
    rounds = sc.rounds if rounds is None else rounds
    run, state, batches, rc, _, _ = _build_run(sc, round_backend=round_backend)
    state, _ = advance(run, state, batches, num_rounds=rounds)
    return _trace(sc, rc, rounds, state.history)


def replay_scenario(sc: Scenario | str, ckpt_dir: str, *,
                    rounds: int | None = None, ckpt_every: int = 10,
                    resume: bool = True, keep: int | None = 3) -> dict:
    """Checkpointed scenario run, resumable from any chunk boundary.

    Saves the full TrainState under ``ckpt_dir`` every ``ckpt_every``
    rounds.  With ``resume=True`` (default) an existing checkpoint is
    restored — dtype-strict — and the run continues from its round; the
    resulting trace is bit-identical to ``run_scenario``'s single scan.
    Stopping early (smaller ``rounds``) and calling again with the full
    count is exactly an interrupted-then-resumed run.
    """
    from repro import checkpoint
    if isinstance(sc, str):
        sc = get_scenario(sc)
    rounds = sc.rounds if rounds is None else rounds
    run, state, batches, rc, schedule, arrival = _build_run(sc)
    if resume:
        step = checkpoint.latest_step(ckpt_dir)
        if step is not None:
            state = restore_train_state(ckpt_dir, step, state.params,
                                        state.opt_state, schedule=schedule,
                                        arrival=arrival)
    while int(state.round_index) < rounds:
        n = min(ckpt_every, rounds - int(state.round_index))
        state, _ = advance(run, state, batches, num_rounds=n)
        save_train_state(ckpt_dir, state, keep=keep)
    if int(state.round_index) != rounds or not state.history:
        raise ValueError(
            f"checkpoint in {ckpt_dir!r} is at round "
            f"{int(state.round_index)}, beyond the requested {rounds} — "
            "refusing to truncate; use a fresh ckpt_dir or resume=False")
    return _trace(sc, rc, rounds, state.history)


def restore_scenario_state(sc: Scenario | str, ckpt_dir: str,
                           step: int | None = None) -> TrainState:
    """Load a replay checkpoint (latest by default) for inspection."""
    from repro import checkpoint
    if isinstance(sc, str):
        sc = get_scenario(sc)
    _, state, _, _, schedule, arrival = _build_run(sc)
    if step is None:
        step = checkpoint.latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
    return restore_train_state(ckpt_dir, step, state.params,
                               state.opt_state, schedule=schedule,
                               arrival=arrival)

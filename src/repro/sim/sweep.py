"""Multi-pod scenario sweep: attack × schedule × aggregator on the
production meshes, as a collective-cost regression gate.

The linreg scenario engine (``repro.sim.engine``) answers "does the
*statistics* hold up" — convergence under every adversary campaign.  This
module answers the systems half of the ROADMAP item: the group-mode
production train step that actually implements the geometric-median-of-means
path (paper Algorithm 1/2, §5 cost model) is lowered + compiled through
``repro.launch.dryrun.lower_pair`` for every cell of the
attack × schedule × aggregator matrix on the 16×16 (256-chip) and 2×16×16
(512-chip) meshes, and the per-scenario **collective bytes / per-collective
breakdown / compiled peak memory** (extracted by the roofline machinery from
the partitioned HLO — no real training runs) are recorded in a checked-in
``benchmarks/BENCH_pod_sweeps.json``.

A :class:`PodScenario` is the production-mesh generalization of
``repro.sim.scenarios.Scenario``: instead of pinning the linreg testbed it
binds (attack, schedule, aggregator, round_backend) to an *(arch, shape,
mesh)* triple — any architecture config from ``repro.configs``.

Usage::

    # sweep every registered scenario (both meshes) and write the
    # checked-in record benchmarks/BENCH_pod_sweeps.json
    PYTHONPATH=src python -m repro.sim.sweep --all

    # regression gate (the CI slow lane): re-lower everything and fail
    # when any scenario's collective bytes or compiled memory regressed
    # beyond tolerance vs the checked-in record
    PYTHONPATH=src python -m repro.sim.sweep --check

    # one cell, verbose
    PYTHONPATH=src python -m repro.sim.sweep \\
        --scenario pod/16x16/minitron-4b/gmom/alie/rotating

``--check`` exits non-zero on: a regression beyond tolerance, a registered
scenario missing from the record, or a stale record entry whose scenario is
no longer registered.  Improvements beyond tolerance are reported as notes
(re-record with ``--all`` to ratchet the gate down).  ``scripts/check_docs.py``
separately fails tier-1 when a registered scenario or mesh is missing from
the checked-in record, so the registry and the record cannot drift apart
silently.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

from repro.core import RobustConfig, byzantine

# ---------------------------------------------------------------------------
# the matrix

POD_ATTACKS = ("sign_flip", "alie", "norm_stealth")
POD_SCHEDULES = ("static", "rotating", "stealth_then_strike")
# krum (ROADMAP PR 4 follow-up: its O(k²) distance matrix must lower
# acceptably at model scale — the record keeps its collective/memory cells)
# and norm_filter_gmom (the sound §6 combined rule) joined the axis when
# the defense gap closed.
POD_AGGREGATORS = ("gmom", "mean", "trimmed_mean", "krum",
                   "norm_filter_gmom")
POD_MESHES = ("16x16", "2x16x16")

#: mesh name -> multi_pod flag for launch.mesh.make_production_mesh
MESH_MULTI_POD = {"16x16": False, "2x16x16": True}

DEFAULT_ARCH = "minitron-4b"    # smallest dense production config: the
DEFAULT_SHAPE = "train_4k"      # cheapest full-size compile per cell

REPO_ROOT = os.path.abspath(os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "..", ".."))
BENCH_PATH = os.path.join(REPO_ROOT, "benchmarks", "BENCH_pod_sweeps.json")

RTOL_COLLECTIVE = 0.05   # collective bytes are deterministic per jax version
RTOL_MEMORY = 0.25       # memory_analysis drifts more across XLA versions
ATOL_BYTES = 4096        # ignore sub-page jitter


@dataclasses.dataclass(frozen=True)
class PodScenario:
    """One cell of the production-mesh sweep.

    Binds the adversarial degrees of freedom (attack, schedule, aggregator,
    round_backend, fault geometry) to an (arch, shape, mesh) triple.  The
    Byzantine granularity is the batch-group mean — exactly the quantity the
    paper's analysis bounds (at most q of k batches contaminated; see
    ``launch.steps`` group mode).
    """
    name: str
    aggregator: str = "gmom"
    attack: str = "sign_flip"
    schedule: str = "rotating"
    mesh: str = "16x16"
    arch: str = DEFAULT_ARCH
    shape: str = DEFAULT_SHAPE
    round_backend: str = "auto"
    num_groups: int = 4          # k — batch-group count
    num_byzantine: int = 1       # q — contaminated batch means per round
    microbatches: int = 1
    # "sharded" keeps the stacked gradients partitioned over the model axis
    # end-to-end (the shard-local contract — O(d/shards) server memory);
    # "gathered" constrains them fully replicated before aggregation (the
    # dense O(d) baseline the big-model gate compares against).
    grad_mode: str = "sharded"
    # wire codec for the worker reports (core/compression.py): threads into
    # the lowered step's RobustConfig, so the encode/decode (or native
    # payload consumption) traces into the compiled module.
    compression: str = "none"
    # True lowers the isolated report-wire microcell (lower_wire_scenario)
    # instead of the full train step — the full step is fwd/bwd-dominated
    # at production scale, so the codec saving is only measurable on the
    # report path itself.
    wire: bool = False
    # arrival model + staleness bound (core/staleness.py, docs/ASYNC.md);
    # with stale=True the cell lowers the isolated staleness-merge microcell
    # (lower_stale_scenario): buffer merge + age weighting + aggregation on
    # the report block, same isolation rationale as the wire cells.
    arrival: str = "all_sync"
    staleness_bound: int = 0
    stale: bool = False

    def robust_config(self) -> RobustConfig:
        """The injected aggregation pipeline config (num_batches == k: each
        batch-group gradient is its own batch mean)."""
        return RobustConfig(
            num_workers=self.num_groups, num_byzantine=self.num_byzantine,
            num_batches=self.num_groups, aggregator=self.aggregator,
            attack=self.attack, round_backend=self.round_backend,
            gmom_max_iters=8, compression=self.compression,
            arrival=self.arrival, staleness_bound=self.staleness_bound)

    def build_schedule(self) -> byzantine.AttackSchedule:
        return byzantine.make_schedule(
            self.schedule, num_workers=self.num_groups,
            num_byzantine=self.num_byzantine, attack=self.attack)


_REGISTRY: dict[str, PodScenario] = {}


def register(ps: PodScenario) -> PodScenario:
    if ps.name in _REGISTRY:
        raise ValueError(f"pod scenario {ps.name!r} already registered")
    if ps.mesh not in MESH_MULTI_POD:
        raise ValueError(f"unknown mesh {ps.mesh!r}; have "
                         f"{sorted(MESH_MULTI_POD)}")
    _REGISTRY[ps.name] = ps
    return ps


def get_pod_scenario(name: str) -> PodScenario:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown pod scenario {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available() -> list[str]:
    return sorted(_REGISTRY)


def _n(mesh: str, arch: str, agg: str, attack: str, schedule: str) -> str:
    return f"pod/{mesh}/{arch}/{agg}/{attack}/{schedule}"


# The full matrix, both meshes.  Every cell lowers the REAL group-mode train
# step — the attack and schedule trace into the compiled module (alie's
# honest-statistics reads, stealth_then_strike's lax.cond on its EMA state),
# and the aggregator decides the collective schedule the gate watches.
for _mesh in POD_MESHES:
    for _agg in POD_AGGREGATORS:
        for _attack in POD_ATTACKS:
            for _schedule in POD_SCHEDULES:
                register(PodScenario(
                    name=_n(_mesh, DEFAULT_ARCH, _agg, _attack, _schedule),
                    aggregator=_agg, attack=_attack, schedule=_schedule,
                    mesh=_mesh))


# ---------------------------------------------------------------------------
# big-model cells: the O(d/shards) server-memory claim, made a gate.
#
# qwen2-72b is the smallest registered config where a gathered (k, d)
# stacked-gradient block cannot fit one chip (d ≈ 72e9 params); these cells
# lower the SAME group-mode train step at that scale with the gradients
# kept partitioned (grad_mode="sharded", the default) and — for gmom — once
# more with the dense gathered baseline, so the checked-in record holds
# both peak-memory numbers side by side and ``shard_scaling_problems``
# gates their ratio.  krum rides along because PR 5 recorded its flattened
# distance accumulation as the ~4.5× peak-memory outlier — the gram-
# expansion rewrite must keep it within KRUM_PEAK_MAX_RATIO of gmom here.

BIG_MODEL_ARCH = "qwen2-72b"

register(PodScenario(
    name=_n("16x16", BIG_MODEL_ARCH, "gmom", "sign_flip", "static"),
    aggregator="gmom", attack="sign_flip", schedule="static",
    mesh="16x16", arch=BIG_MODEL_ARCH))
register(PodScenario(
    name=_n("16x16", BIG_MODEL_ARCH, "krum", "sign_flip", "static"),
    aggregator="krum", attack="sign_flip", schedule="static",
    mesh="16x16", arch=BIG_MODEL_ARCH))
register(PodScenario(
    name=_n("16x16", BIG_MODEL_ARCH, "coord_median", "sign_flip", "static"),
    aggregator="coord_median", attack="sign_flip", schedule="static",
    mesh="16x16", arch=BIG_MODEL_ARCH))
register(PodScenario(
    name=_n("16x16", BIG_MODEL_ARCH, "gmom", "sign_flip", "static")
    + "/gathered",
    aggregator="gmom", attack="sign_flip", schedule="static",
    mesh="16x16", arch=BIG_MODEL_ARCH, grad_mode="gathered"))

#: the big-model cells (outside the full minitron matrix product)
BIG_MODEL_SCENARIOS = (
    _n("16x16", BIG_MODEL_ARCH, "gmom", "sign_flip", "static"),
    _n("16x16", BIG_MODEL_ARCH, "krum", "sign_flip", "static"),
    _n("16x16", BIG_MODEL_ARCH, "coord_median", "sign_flip", "static"),
    _n("16x16", BIG_MODEL_ARCH, "gmom", "sign_flip", "static") + "/gathered",
)


# ---------------------------------------------------------------------------
# communication-compressed cells: the §1.4 / Jin et al. '19 wire-cost claim,
# made a gate.
#
# Two FULL train-step cells lower the compressed aggregation end to end at
# minitron-4b scale (sign_sgd_majority consuming the packed 1-bit wire under
# the vote-targeting adversary; int8_gmom dequantize-then-GMoM) — they prove
# the compressed path compiles and keep its collective/memory cells in the
# record.  The full step is forward/backward-dominated (~4e11 collective
# B/device either way), so three additional REPORT-WIRE microcells isolate
# exactly the worker -> server report traffic the codecs shrink:
# ``compression_wire_problems`` gates sign at >= 25x below the f32 baseline
# and int8 at >= 3.5x (32 bits -> 1 and -> 8 + per-worker scales).

WIRE_REDUCTION_MIN_SIGN = 25.0
WIRE_REDUCTION_MIN_INT8 = 3.5
WIRE_RTOL = 0.05

WIRE_BASELINE_SCENARIO = \
    _n("16x16", DEFAULT_ARCH, "gmom", "sign_flip", "static") + "/wire"
WIRE_SIGN_SCENARIO = \
    _n("16x16", DEFAULT_ARCH, "sign_sgd_majority", "sign_flip", "static") \
    + "/wire"
WIRE_INT8_SCENARIO = \
    _n("16x16", DEFAULT_ARCH, "int8_gmom", "sign_flip", "static") + "/wire"

register(PodScenario(
    name=_n("16x16", DEFAULT_ARCH, "sign_sgd_majority",
            "sign_flip_targeted", "static"),
    aggregator="sign_sgd_majority", attack="sign_flip_targeted",
    schedule="static", mesh="16x16", compression="sign"))
register(PodScenario(
    name=_n("16x16", DEFAULT_ARCH, "int8_gmom", "sign_flip", "static"),
    aggregator="int8_gmom", attack="sign_flip", schedule="static",
    mesh="16x16", compression="int8_stochastic"))
register(PodScenario(
    name=WIRE_BASELINE_SCENARIO, aggregator="gmom", attack="sign_flip",
    schedule="static", mesh="16x16", compression="none", wire=True))
register(PodScenario(
    name=WIRE_SIGN_SCENARIO, aggregator="sign_sgd_majority",
    attack="sign_flip", schedule="static", mesh="16x16", compression="sign",
    wire=True))
register(PodScenario(
    name=WIRE_INT8_SCENARIO, aggregator="int8_gmom", attack="sign_flip",
    schedule="static", mesh="16x16", compression="int8_stochastic",
    wire=True))

#: the compression cells (outside the full minitron matrix product)
COMPRESSION_SCENARIOS = (
    _n("16x16", DEFAULT_ARCH, "sign_sgd_majority", "sign_flip_targeted",
       "static"),
    _n("16x16", DEFAULT_ARCH, "int8_gmom", "sign_flip", "static"),
    WIRE_BASELINE_SCENARIO,
    WIRE_SIGN_SCENARIO,
    WIRE_INT8_SCENARIO,
)


# ---------------------------------------------------------------------------
# bounded-staleness cells: the docs/ASYNC.md async path, priced at scale.
#
# Two STALENESS-MERGE microcells (same isolation rationale as the wire
# cells: the full step is fwd/bwd-dominated) lower the per-round async
# server work at minitron-4b/16×16 — buffer merge (where-select over the
# report block), int32 age update, normalized discount**age weighting, and
# the gmom aggregation of the merged rows — under the rotating-straggler
# arrival with the paper-scale bound τ=2.  One cell keeps the buffer
# partitioned over the model axis (the shard-local layout, O(d/shards)
# buffer memory per chip); the paired /gathered cell replicates it — the
# dense baseline — so the record holds both peak-memory numbers and the
# --check gate pins their collective/memory cells like every other cell.

STALE_ARRIVAL = "straggler_rotating"
STALE_BOUND = 2

STALE_SHARDED_SCENARIO = \
    _n("16x16", DEFAULT_ARCH, "gmom", "sign_flip", "rotating") + "/stale"
STALE_GATHERED_SCENARIO = STALE_SHARDED_SCENARIO + "/gathered"

register(PodScenario(
    name=STALE_SHARDED_SCENARIO, aggregator="gmom", attack="sign_flip",
    schedule="rotating", mesh="16x16", arrival=STALE_ARRIVAL,
    staleness_bound=STALE_BOUND, stale=True))
register(PodScenario(
    name=STALE_GATHERED_SCENARIO, aggregator="gmom", attack="sign_flip",
    schedule="rotating", mesh="16x16", arrival=STALE_ARRIVAL,
    staleness_bound=STALE_BOUND, stale=True, grad_mode="gathered"))

#: the staleness cells (outside the full minitron matrix product)
STALE_SCENARIOS = (
    STALE_SHARDED_SCENARIO,
    STALE_GATHERED_SCENARIO,
)


# ---------------------------------------------------------------------------
# lowering one cell

def lower_scenario(ps: PodScenario, *, mesh=None, cfg=None, shape=None,
                   verbose: bool = False) -> dict:
    """Lower + compile one PodScenario; returns its sweep record entry.

    ``mesh``/``cfg``/``shape`` inject a small host-device mesh, a reduced
    config, and a small registered input shape (the tier-1 test path); by
    default the scenario's production mesh and full-size architecture are
    used — the caller is responsible for arming enough host devices first
    (``main`` does).
    """
    from repro.launch import dryrun
    from repro.roofline import analysis

    art = dryrun.lower_pair(
        cfg if cfg is not None else ps.arch, shape or ps.shape,
        multi_pod=MESH_MULTI_POD[ps.mesh], mesh=mesh,
        num_groups=ps.num_groups, microbatches=ps.microbatches,
        rc=ps.robust_config(), schedule=ps.build_schedule(),
        gather_grads=(ps.grad_mode == "gathered"),
        verbose=verbose)
    entry = analysis.sweep_entry(art.record, scenario=ps.name)
    entry.update(
        aggregator=ps.aggregator, attack=ps.attack, schedule=ps.schedule,
        round_backend=ps.round_backend, num_groups=ps.num_groups,
        num_byzantine=ps.num_byzantine, grad_mode=ps.grad_mode,
        compression=ps.compression,
        compile_seconds=round(art.compile_seconds, 2))
    return entry


def lower_wire_scenario(ps: PodScenario, *, mesh=None, cfg=None, shape=None,
                        verbose: bool = False) -> dict:
    """Lower + compile the isolated REPORT WIRE of one compressed cell.

    The full train-step cells are forward/backward-dominated at production
    scale, so a 25× codec saving on the report would drown in activation
    traffic.  This lowering prices exactly the worker → server report path
    of the paper's §5 cost model: each group's report starts partitioned
    over the mesh ``model`` axis (shard-local encode), the encoded payload
    is explicitly replicated — that all-gather IS the wire — and the server
    consumes it fully replicated (decode + aggregate, or the aggregator's
    native payload path), adding no further collectives.  The report is the
    flattened (k, param_count) gradient block: wire bytes depend only on
    the coordinate count, never the parameter-tree structure, and the flat
    layout keeps the cell's compile cheap.  The attack is upstream of the
    report and does not trace here (the cell name keeps the axis labels for
    the record schema only).
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import aggregators, compression
    from repro.launch import mesh as mesh_lib, steps
    from repro.roofline import analysis

    if mesh is None:
        mesh = mesh_lib.make_production_mesh(
            multi_pod=MESH_MULTI_POD[ps.mesh])
    cfg_, shape_, _ = steps.input_specs(
        cfg if cfg is not None else ps.arch, shape or ps.shape,
        num_groups=ps.num_groups)
    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    model_n = mesh.shape["model"]
    m = ps.num_groups
    # pad the coordinate count so the model-axis split and the 8-per-word
    # sign packing both stay even (relative overcount < 1e-6 at 4B params)
    quantum = model_n * 8
    d_pad = -(-cfg_.param_count() // quantum) * quantum
    stacked_s = jax.ShapeDtypeStruct((m, d_pad), jnp.float32)
    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)

    codec = compression.get_codec(ps.compression)
    agg = aggregators.get_aggregator(ps.aggregator)
    rc = ps.robust_config()
    part = NamedSharding(mesh, P(None, "model"))
    rep = NamedSharding(mesh, P())

    def _local(x):
        if x.ndim >= 2 and x.shape[-1] % model_n == 0:
            return NamedSharding(
                mesh, P(*((None,) * (x.ndim - 1) + ("model",))))
        return rep        # per-worker scales: (m,) — negligible wire weight

    def _pin(tree, spec_of):
        return jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(x, spec_of(x)), tree)

    def _consume(reports, key, like=None):
        # mirrors aggregate_reported's metadata-driven kwarg dispatch (the
        # wire boundary sits between encode and consume, so the one-call
        # path through aggregate_reported cannot be pinned from outside)
        kwargs = {}
        if like is not None:
            kwargs["like"] = like
        if agg.needs_num_byzantine:
            kwargs["num_byzantine"] = rc.num_byzantine
        if agg.needs_key:
            kwargs["key"] = jax.random.fold_in(key, 13)
        if agg.needs_grouping:
            kwargs.update(num_batches=rc.resolved_num_batches(),
                          epsilon=rc.epsilon,
                          grouping_scheme=rc.grouping_scheme,
                          trim_multiplier=rc.trim_multiplier,
                          max_iters=rc.gmom_max_iters, tol=rc.gmom_tol,
                          round_backend=rc.round_backend)
        return agg(reports, **kwargs)

    def wire_step(stacked, key):
        stacked = jax.lax.with_sharding_constraint(stacked, part)
        ckey = jax.random.fold_in(key, 29) if codec.needs_key else None
        payload = codec.encode(stacked, key=ckey)
        payload = _pin(payload, _local)          # encode is shard-local
        payload = _pin(payload, lambda x: rep)   # the wire: gather reports
        if ps.compression != "none" and agg.native_codec == ps.compression:
            return _consume(payload, key, like=stacked)
        if ps.compression != "none":
            payload = codec.decode(payload, stacked)
        return _consume(payload, key)

    t0 = time.time()
    compiled = jax.jit(
        wire_step, in_shardings=(part, rep)).lower(stacked_s, key_s).compile()
    elapsed = time.time() - t0
    record = analysis.build_record(
        arch=ps.arch if cfg is None else cfg_.name, shape=shape_, cfg=cfg_,
        mesh_name=mesh_name, num_chips=mesh.size, step="report_wire",
        compiled=compiled)
    entry = analysis.sweep_entry(record, scenario=ps.name)
    entry.update(
        aggregator=ps.aggregator, attack=ps.attack, schedule=ps.schedule,
        round_backend=ps.round_backend, num_groups=ps.num_groups,
        num_byzantine=ps.num_byzantine, grad_mode=ps.grad_mode,
        compression=ps.compression, compile_seconds=round(elapsed, 2))
    if verbose:
        print(f"[wire] {ps.name}: "
              f"{entry['collective_bytes_per_device']:.3e} B/dev "
              f"({elapsed:.1f}s)", flush=True)
    return entry


def lower_stale_scenario(ps: PodScenario, *, mesh=None, cfg=None, shape=None,
                         verbose: bool = False) -> dict:
    """Lower + compile the isolated STALENESS MERGE of one async cell.

    Prices exactly the per-round server work the bounded-staleness path
    adds (docs/ASYNC.md): merge the fresh reports into the buffer
    (where-select over the (m, d) report block), update the int32 ages,
    weight the merged rows by their normalized ``discount**age``, and run
    the aggregator on the result.  The report block and the buffer share
    the flattened (m, param_count) layout of the wire cells; grad_mode
    decides whether the buffer lives partitioned over the ``model`` axis
    (shard-local — O(d/shards) buffer bytes per chip) or replicated (the
    dense baseline).  The arrival mask derives from the round index and the
    per-round key only, so the whole cell is one jit with no host state.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import staleness as staleness_lib
    from repro.core.robust_train import aggregate_reported
    from repro.launch import mesh as mesh_lib, steps
    from repro.roofline import analysis

    if mesh is None:
        mesh = mesh_lib.make_production_mesh(
            multi_pod=MESH_MULTI_POD[ps.mesh])
    cfg_, shape_, _ = steps.input_specs(
        cfg if cfg is not None else ps.arch, shape or ps.shape,
        num_groups=ps.num_groups)
    mesh_name = "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
    model_n = mesh.shape["model"]
    m = ps.num_groups
    quantum = model_n * 8
    d_pad = -(-cfg_.param_count() // quantum) * quantum
    stacked_s = jax.ShapeDtypeStruct((m, d_pad), jnp.float32)
    age_s = jax.ShapeDtypeStruct((m,), jnp.int32)
    step_s = jax.ShapeDtypeStruct((), jnp.int32)
    key_s = jax.ShapeDtypeStruct((2,), jnp.uint32)

    rc = ps.robust_config()
    arrival = staleness_lib.make_arrival(
        ps.arrival, num_workers=m, staleness_bound=ps.staleness_bound)
    part = NamedSharding(mesh, P(None, "model"))
    rep = NamedSharding(mesh, P())
    buf_sharding = part if ps.grad_mode == "sharded" else rep

    def stale_step(stacked, buf_grads, age, t, key):
        stacked = jax.lax.with_sharding_constraint(stacked, part)
        buf_grads = jax.lax.with_sharding_constraint(buf_grads, buf_sharding)
        buf = staleness_lib.StalenessBuffer(
            grads=buf_grads, age=age.astype(jnp.int32),
            bound=jnp.asarray(ps.staleness_bound, jnp.int32))
        fresh = arrival.arrive(key, t, jnp.zeros((m,), bool))
        merged, buf = staleness_lib.merge_reports(buf, stacked, fresh)
        agg = aggregate_reported(
            merged, rc, key=key,
            staleness=(buf.age, buf.bound, rc.staleness_discount))
        new_grads = jax.lax.with_sharding_constraint(buf.grads, buf_sharding)
        return agg, new_grads, buf.age

    t0 = time.time()
    compiled = jax.jit(
        stale_step,
        in_shardings=(part, buf_sharding, rep, rep, rep),
    ).lower(stacked_s, stacked_s, age_s, step_s, key_s).compile()
    elapsed = time.time() - t0
    record = analysis.build_record(
        arch=ps.arch if cfg is None else cfg_.name, shape=shape_, cfg=cfg_,
        mesh_name=mesh_name, num_chips=mesh.size, step="stale_report",
        compiled=compiled)
    entry = analysis.sweep_entry(record, scenario=ps.name)
    entry.update(
        aggregator=ps.aggregator, attack=ps.attack, schedule=ps.schedule,
        round_backend=ps.round_backend, num_groups=ps.num_groups,
        num_byzantine=ps.num_byzantine, grad_mode=ps.grad_mode,
        compression=ps.compression, arrival=ps.arrival,
        staleness_bound=ps.staleness_bound,
        compile_seconds=round(elapsed, 2))
    if verbose:
        print(f"[stale] {ps.name}: "
              f"{entry['collective_bytes_per_device']:.3e} B/dev "
              f"({elapsed:.1f}s)", flush=True)
    return entry


def run_sweep(names: list[str] | None = None, *,
              verbose: bool = True) -> dict:
    """Lower every named (default: all registered) scenario; returns the
    sweep payload (the BENCH record body)."""
    names = available() if names is None else list(names)
    scenarios: dict[str, dict] = {}
    t0 = time.time()
    for i, name in enumerate(names):
        ps = get_pod_scenario(name)
        if ps.wire:
            entry = lower_wire_scenario(ps)
        elif ps.stale:
            entry = lower_stale_scenario(ps)
        else:
            entry = lower_scenario(ps)
        scenarios[name] = entry
        if verbose:
            print(f"[sweep {i + 1}/{len(names)}] {name}: "
                  f"coll={entry['collective_bytes_per_device']:.3e} B "
                  f"peak={entry['peak_memory_bytes'] or 0:.3e} B "
                  f"({entry['compile_seconds']:.1f}s)", flush=True)
    payload = {
        "matrix": {
            "attacks": list(POD_ATTACKS),
            "schedules": list(POD_SCHEDULES),
            "aggregators": list(POD_AGGREGATORS),
            "meshes": list(POD_MESHES),
        },
        "default_arch": DEFAULT_ARCH,
        "default_shape": DEFAULT_SHAPE,
        "big_model": {
            "arch": BIG_MODEL_ARCH,
            "scenarios": list(BIG_MODEL_SCENARIOS),
        },
        "compression": {
            "scenarios": list(COMPRESSION_SCENARIOS),
            "wire_reduction_min_sign": WIRE_REDUCTION_MIN_SIGN,
            "wire_reduction_min_int8": WIRE_REDUCTION_MIN_INT8,
        },
        "staleness": {
            "scenarios": list(STALE_SCENARIOS),
            "arrival": STALE_ARRIVAL,
            "staleness_bound": STALE_BOUND,
        },
        "sweep_seconds": round(time.time() - t0, 1),
        "scenarios": scenarios,
    }
    return payload


# ---------------------------------------------------------------------------
# the regression gate

def _rel_over(new: float, old: float, rtol: float, atol: float) -> bool:
    return new > old * (1.0 + rtol) + atol


def compare_payloads(record: dict, fresh: dict, *,
                     rtol_collective: float = RTOL_COLLECTIVE,
                     rtol_memory: float = RTOL_MEMORY,
                     atol: float = ATOL_BYTES) -> tuple[list[str], list[str]]:
    """Gate a fresh sweep against the checked-in record.

    Returns ``(problems, notes)``: problems fail the gate (collective-bytes
    or peak-memory regression beyond tolerance, registered scenario missing
    from the record, stale record entry); notes are informational
    (improvements beyond tolerance — re-record to ratchet — and per-op
    breakdown drift inside the total tolerance).
    """
    problems: list[str] = []
    notes: list[str] = []
    old_s = record.get("scenarios", {})
    new_s = fresh.get("scenarios", {})

    for name in sorted(new_s):
        if name not in old_s:
            problems.append(
                f"{name}: not in the checked-in record — re-record with "
                "`python -m repro.sim.sweep --all` and commit the diff")
            continue
        old, new = old_s[name], new_s[name]
        for field, rtol, label in (
                ("collective_bytes_per_device", rtol_collective,
                 "collective bytes"),
                ("peak_memory_bytes", rtol_memory, "compiled peak memory")):
            o, n = old.get(field), new.get(field)
            if o is None or n is None:
                continue
            if _rel_over(n, o, rtol, atol):
                problems.append(
                    f"{name}: {label} regressed {o:.4e} -> {n:.4e} "
                    f"(+{(n - o) / max(o, 1.0):.1%} > rtol {rtol:.0%})")
            elif _rel_over(o, n, rtol, atol):
                notes.append(
                    f"{name}: {label} improved {o:.4e} -> {n:.4e} — "
                    "re-record (--all) to ratchet the gate")
        ob = old.get("collective_breakdown", {})
        nb = new.get("collective_breakdown", {})
        for op in sorted(set(ob) | set(nb)):
            o, n = float(ob.get(op, 0.0)), float(nb.get(op, 0.0))
            if _rel_over(n, o, rtol_collective, atol) or \
                    _rel_over(o, n, rtol_collective, atol):
                notes.append(
                    f"{name}: {op} bytes moved {o:.4e} -> {n:.4e} "
                    "(total within tolerance)")

    for name in sorted(set(old_s) - set(new_s)):
        problems.append(
            f"{name}: stale record entry (scenario no longer swept) — "
            "re-record with `python -m repro.sim.sweep --all`")
    return problems, notes


#: gathered-baseline gmom peak memory must exceed the sharded cell's by at
#: least this factor on the big-model mesh — the recorded, gated form of
#: "server peak memory drops from O(d) to O(d/shards)".  The 16×16 mesh has
#: |model| = 16 shards; 4× leaves generous headroom for the activations,
#: params, and optimizer state both lowerings share.
SHARD_MEMORY_MIN_RATIO = 4.0

#: krum's sharded peak must stay within this factor of sharded gmom's —
#: the gram-expansion rewrite's regression bound (PR 5 recorded the old
#: flattened f32 accumulation at ~3.7-4.5× gmom's peak).
KRUM_PEAK_MAX_RATIO = 1.5


def shard_scaling_problems(scenarios: dict) -> list[str]:
    """Gate the big-model shard-local claims on a fresh sweep payload.

    * the gathered gmom cell's compiled peak memory must be at least
      ``SHARD_MEMORY_MIN_RATIO`` × the sharded cell's (O(d) vs O(d/shards));
    * sharded krum's peak must stay within ``KRUM_PEAK_MAX_RATIO`` × sharded
      gmom's (no return of the flattened-copy blowup).

    Cells absent from the payload are skipped (filtered --check runs and
    the --fresh-from CLI wiring tests sweep subsets); the registry/record
    completeness check in :func:`compare_payloads` and check_docs.py keeps
    the cells from disappearing silently.
    """
    problems: list[str] = []

    def peak(name):
        e = scenarios.get(name)
        return e.get("peak_memory_bytes") if e else None

    base = _n("16x16", BIG_MODEL_ARCH, "gmom", "sign_flip", "static")
    g_sharded = peak(base)
    g_gathered = peak(base + "/gathered")
    k_sharded = peak(_n("16x16", BIG_MODEL_ARCH, "krum", "sign_flip",
                        "static"))

    if g_sharded and g_gathered:
        ratio = g_gathered / g_sharded
        if ratio < SHARD_MEMORY_MIN_RATIO:
            problems.append(
                f"big-model shard scaling: gathered gmom peak "
                f"{g_gathered:.3e} B is only {ratio:.2f}× the sharded "
                f"{g_sharded:.3e} B (< {SHARD_MEMORY_MIN_RATIO:.1f}×) — "
                "the O(d/shards) server-memory claim regressed")
    if g_sharded and k_sharded:
        ratio = k_sharded / g_sharded
        if ratio > KRUM_PEAK_MAX_RATIO:
            problems.append(
                f"big-model krum peak {k_sharded:.3e} B is {ratio:.2f}× "
                f"sharded gmom's {g_sharded:.3e} B "
                f"(> {KRUM_PEAK_MAX_RATIO:.1f}×) — the flattened-copy "
                "blowup is back")
    return problems


def compression_wire_problems(scenarios: dict) -> list[str]:
    """Gate the report-wire compression claims on a fresh sweep payload.

    The sign wire cell's collective bytes must be at least
    ``WIRE_REDUCTION_MIN_SIGN`` × below the f32 baseline wire cell's
    (32-bit floats → 1 packed bit/coordinate), and the int8 cell at least
    ``WIRE_REDUCTION_MIN_INT8`` × below (→ 8 bits + per-worker scales) —
    each with ``WIRE_RTOL`` slack for padding/partitioner jitter.  Cells
    absent from the payload are skipped (filtered --check runs), same as
    :func:`shard_scaling_problems`.
    """
    problems: list[str] = []
    base = scenarios.get(WIRE_BASELINE_SCENARIO)
    b = base.get("collective_bytes_per_device") if base else None
    if not b:
        return problems
    for name, floor, codec in (
            (WIRE_SIGN_SCENARIO, WIRE_REDUCTION_MIN_SIGN, "sign"),
            (WIRE_INT8_SCENARIO, WIRE_REDUCTION_MIN_INT8,
             "int8_stochastic")):
        e = scenarios.get(name)
        if not e:
            continue
        n = e.get("collective_bytes_per_device")
        if not n:
            problems.append(
                f"{name}: report-wire cell recorded zero collective bytes "
                "— the wire all-gather was optimized away; the cell no "
                "longer measures the report path")
            continue
        ratio = b / n
        if ratio < floor * (1.0 - WIRE_RTOL):
            problems.append(
                f"compression wire: {codec} report moves {n:.3e} B/device "
                f"vs the f32 baseline's {b:.3e} — only {ratio:.1f}× "
                f"reduction (< {floor:.1f}× floor) — the wire-cost claim "
                "regressed")
    return problems


def load_record(path: str = BENCH_PATH) -> dict:
    with open(path) as f:
        return json.load(f)


def _save_bench(payload: dict, path: str = BENCH_PATH) -> str:
    """Write the sweep record, stamped with backend/jax-version metadata
    (collective bytes are only comparable within a jax version).

    The canonical checked-in path goes through benchmarks.common.save_bench;
    a custom ``path`` (``--record-path``) gets the same record shape without
    touching the committed file."""
    if os.path.abspath(path) != BENCH_PATH:
        import jax
        record = {
            "bench": "pod_sweeps",
            "recorded_unix": int(time.time()),
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "cpu_count": os.cpu_count(),
            **payload,
        }
        with open(path, "w") as f:
            json.dump(record, f, indent=1)
            f.write("\n")
        return path
    try:
        from benchmarks.common import save_bench
    except ImportError:
        sys.path.insert(0, REPO_ROOT)
        from benchmarks.common import save_bench
    return save_bench("pod_sweeps", payload)


def _format_entries(scenarios: dict[str, dict]) -> str:
    rows = ["| scenario | mesh | collective B/dev | peak B/chip | "
            "bottleneck |", "|---|---|---|---|---|"]
    for name in sorted(scenarios):
        e = scenarios[name]
        peak = (f"{e['peak_memory_bytes']:.3e}"
                if e.get("peak_memory_bytes") else "n/a")
        rows.append(
            f"| {name} | {e['mesh']} "
            f"| {e['collective_bytes_per_device']:.3e} | {peak} "
            f"| {e['bottleneck']} |")
    return "\n".join(rows)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    p.add_argument("--all", action="store_true",
                   help="sweep every registered scenario and write the "
                        "checked-in benchmarks/BENCH_pod_sweeps.json")
    p.add_argument("--scenario", action="append", default=[],
                   help="sweep one named scenario (repeatable)")
    p.add_argument("--multi-pod", action="store_true",
                   help="restrict to the 2x16x16 mesh half of the matrix")
    p.add_argument("--single-pod", action="store_true",
                   help="restrict to the 16x16 mesh half of the matrix")
    p.add_argument("--check", action="store_true",
                   help="re-sweep and fail on regressions vs the checked-in "
                        "record (the CI slow-lane gate)")
    p.add_argument("--record-path", default=BENCH_PATH,
                   help="checked-in record to gate against / write")
    p.add_argument("--fresh-from", default=None,
                   help="with --check: read the fresh sweep payload from "
                        "this JSON instead of lowering (CI wiring tests / "
                        "split run-vs-gate)")
    p.add_argument("--out", default=None,
                   help="write the fresh sweep payload (scratch JSON)")
    p.add_argument("--rtol-collective", type=float, default=RTOL_COLLECTIVE)
    p.add_argument("--rtol-memory", type=float, default=RTOL_MEMORY)
    args = p.parse_args(argv)

    names = available()
    if args.multi_pod:
        names = [n for n in names if get_pod_scenario(n).mesh == "2x16x16"]
    if args.single_pod:
        names = [n for n in names if get_pod_scenario(n).mesh == "16x16"]
    if args.scenario:
        for n in args.scenario:
            get_pod_scenario(n)   # fail fast on typos
        names = args.scenario
    elif not (args.all or args.check):
        p.error("pass --all, --check, or --scenario NAME")
    filtered = bool(args.multi_pod or args.single_pod or args.scenario)

    if args.fresh_from:
        with open(args.fresh_from) as f:
            fresh = json.load(f)
    else:
        # the production meshes need 512 host devices; arm the flag before
        # jax's backend initializes (entry-point guard, NOT import-time).
        from repro.launch import dryrun
        dryrun.force_host_device_count(512)
        fresh = run_sweep(names)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(fresh, f, indent=1)
        print(f"wrote fresh sweep payload to {args.out}")

    if args.check:
        if not os.path.exists(args.record_path):
            print(f"sweep --check: no record at {args.record_path} — "
                  "record one with `python -m repro.sim.sweep --all`")
            return 2
        record = load_record(args.record_path)
        if filtered:
            # a filtered gate run (--single-pod / --multi-pod / --scenario)
            # only compares the swept subset: record entries outside the
            # filter are out of scope, not stale.  Registry/record drift is
            # enforced by unfiltered --check (and by check_docs in tier-1).
            swept = set(names)
            record = dict(record)
            record["scenarios"] = {
                n: e for n, e in record.get("scenarios", {}).items()
                if n in swept}
        problems, notes = compare_payloads(
            record, fresh,
            rtol_collective=args.rtol_collective,
            rtol_memory=args.rtol_memory)
        problems += shard_scaling_problems(fresh.get("scenarios", {}))
        problems += compression_wire_problems(fresh.get("scenarios", {}))
        for n in notes:
            print(f"sweep note: {n}")
        for pr in problems:
            print(f"sweep REGRESSION: {pr}")
        if problems:
            print(f"sweep --check: FAILED ({len(problems)} problem(s))")
            return 1
        print(f"sweep --check: ok — {len(fresh.get('scenarios', {}))} "
              "scenario(s) within tolerance of the checked-in record")
        return 0

    print()
    print(_format_entries(fresh["scenarios"]))
    if args.all:
        path = _save_bench(fresh, args.record_path)
        if os.path.abspath(args.record_path) == BENCH_PATH:
            print(f"\nwrote checked-in record {path} — commit it with "
                  "the PR")
        else:
            print(f"\nwrote record {path} (scratch — the checked-in gate "
                  f"record stays {BENCH_PATH})")
    return 0


if __name__ == "__main__":
    sys.exit(main())

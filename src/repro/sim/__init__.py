"""Adversarial scenario engine.

The multi-round counterpart of core/byzantine.py's single-round attack zoo:
named scenarios (attack × schedule × aggregator on the paper's linear-
regression testbed) with deterministic seeds, a scan-compiled runner, and
checked-in golden metric traces for regression testing.

    from repro import sim
    trace = sim.run_scenario("linreg/gmom/sign_flip/stealth_then_strike")

The production-mesh counterpart is ``repro.sim.sweep``: the same
attack × schedule × aggregator matrix bound to (arch, shape, mesh) triples
(``PodScenario``), dry-run-lowered on the 16×16 / 2×16×16 meshes, with
per-scenario collective costs gated against benchmarks/BENCH_pod_sweeps.json
(``python -m repro.sim.sweep --check``).
"""

from repro.sim.engine import (  # noqa: F401
    build_schedule,
    replay_scenario,
    restore_scenario_state,
    run_scenario,
)
from repro.sim.scenarios import (  # noqa: F401
    Scenario,
    available,
    get_scenario,
    golden_scenarios,
    register,
)
from repro.sim import goldens, sweep  # noqa: F401

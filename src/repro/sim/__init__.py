"""Adversarial scenario engine.

The multi-round counterpart of core/byzantine.py's single-round attack zoo:
named scenarios (attack × schedule × aggregator on the paper's linear-
regression testbed) with deterministic seeds, a scan-compiled runner, and
checked-in golden metric traces for regression testing.

    from repro import sim
    trace = sim.run_scenario("linreg/gmom/sign_flip/stealth_then_strike")
"""

from repro.sim.engine import (  # noqa: F401
    build_schedule,
    replay_scenario,
    restore_scenario_state,
    run_scenario,
)
from repro.sim.scenarios import (  # noqa: F401
    Scenario,
    available,
    get_scenario,
    golden_scenarios,
    register,
)
from repro.sim import goldens  # noqa: F401

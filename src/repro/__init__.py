"""repro — Byzantine Gradient Descent (Chen, Su, Xu 2017) as a production
multi-pod JAX/TPU training & serving framework.

Subpackages:
    core        the paper's algorithm (geomed, aggregators, attacks, steps)
    models      the 10-assigned-architecture model zoo
    kernels     Pallas TPU kernels (geomed Weiszfeld, flash attention)
    data        synthetic deterministic pipelines (+ the paper's linreg)
    optim       SGD (paper) / AdamW
    checkpoint  msgpack pytree checkpoints
    configs     architecture + input-shape registry
    launch      meshes, sharding rules, dry-run, train/serve drivers
    roofline    compiled-HLO roofline analysis
"""

__version__ = "1.0.0"

"""InternVL2-26B [arXiv:2404.16821] — InternViT (stubbed: precomputed patch
embeddings per the assignment carve-out) + InternLM2 20B-class decoder."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    frontend="vision",
    num_patches=256,
    rope_theta=1e6,
)

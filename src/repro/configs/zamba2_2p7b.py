"""Zamba2-2.7B [arXiv:2411.15242] — Mamba2 backbone + shared attention
blocks (hybrid)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    source="arXiv:2411.15242",
    num_layers=54,          # mamba2 blocks
    d_model=2560,
    num_heads=32,           # shared attention block
    num_kv_heads=32,
    d_ff=10240,             # shared block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    shared_attn_every=6,
    rope_theta=1e4,
)

"""SeamlessM4T-medium [arXiv:2308.11596] — encoder-decoder, audio frontend
stubbed (precomputed conv/mel frame embeddings per the assignment carve-out)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    source="arXiv:2308.11596",
    num_layers=12,          # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    frontend="audio",
    rope_theta=1e4,
    encoder_seq_divisor=4,
)

"""The paper's own experiment: linear regression (paper §4, Corollary 1).

Not one of the 10 assigned architectures — this is the paper-faithful
validation target with known L = M = 1 (=> eta = 1/2)."""

import dataclasses


@dataclasses.dataclass(frozen=True)
class LinRegConfig:
    name: str = "linreg-paper"
    dim: int = 100               # d
    total_samples: int = 50_000  # N
    num_workers: int = 50        # m
    num_byzantine: int = 4       # q
    noise_std: float = 1.0
    rounds: int = 60             # O(log N)
    seed: int = 0


CONFIG = LinRegConfig()

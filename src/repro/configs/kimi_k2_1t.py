"""Kimi K2 1T-A32B [arXiv:2501.kimi2] — trillion-parameter MoE, 384 experts
top-8 (paper-table scale; the stress test for sharded GMoM)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    source="arXiv:2501.kimi2",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,              # per-expert hidden
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    rope_theta=1e6,
    moe_capacity_factor=1.25,
)

"""Unified model/run configuration.

One ``ModelConfig`` covers all six architecture families (dense / moe / ssm /
hybrid / audio / vlm); family-specific fields are ignored by the others.
``reduced()`` produces the CPU-smoke variant (<=2 layers, d_model<=512,
<=4 experts) required per assigned architecture.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    source: str                    # citation per the assignment table
    num_layers: int
    d_model: int
    vocab_size: int
    d_ff: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0              # 0 => d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    sliding_window: int | None = None
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    # --- SSM (rwkv / mamba) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    # --- hybrid (zamba2): one shared attn+mlp block every N mamba blocks ---
    shared_attn_every: int = 0
    # --- enc-dec (seamless) ---
    encoder_layers: int = 0
    encoder_seq_divisor: int = 4   # encoder frames = seq_len // divisor
    # --- modality frontend stubs ---
    frontend: str | None = None    # None | "audio" | "vision"
    num_patches: int = 256         # vision prefix length
    # --- numerics / memory ---
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.bfloat16
    remat: bool = True
    loss_chunk: int = 512

    def __post_init__(self):
        if self.num_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ModelConfig":
        """CPU-smoke variant: same family/wiring, tiny dims."""
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = min(self.num_kv_heads, max(heads // 2, 1)) if heads else 0
        d_model = min(self.d_model, 256)
        hd = d_model // heads if heads else 0
        return self.with_(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2),
            d_model=d_model,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            sliding_window=(min(self.sliding_window, 64)
                            if self.sliding_window else None),
            encoder_layers=min(self.encoder_layers, 2),
            shared_attn_every=(2 if self.shared_attn_every else 0),
            num_patches=min(self.num_patches, 16),
            ssm_chunk=16,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=min(self.ssm_head_dim, 32),
            dtype=jnp.float32,
            param_dtype=jnp.float32,
            remat=False,
            loss_chunk=0,
        )

    # approximate parameter counts (used by roofline MODEL_FLOPS)
    def param_count(self) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family in ("dense", "vlm"):
            attn = D * self.num_heads * self.head_dim * 2 \
                + D * self.num_kv_heads * self.head_dim * 2
            mlp = 3 * D * F
            return emb + L * (attn + mlp)
        if self.family == "moe":
            attn = D * self.num_heads * self.head_dim * 2 \
                + D * self.num_kv_heads * self.head_dim * 2
            moe = self.num_experts * 3 * D * F + D * self.num_experts
            return emb + L * (attn + moe)
        if self.family == "ssm":       # rwkv6
            tm = 5 * D * D + D * 64 + 64 * D    # r,k,v,g,o + decay lora
            cm = 2 * D * F // 1 if F else 0
            cm = D * F * 2 + D * D
            return emb + L * (tm + cm)
        if self.family == "hybrid":    # zamba2
            din = 2 * D
            mamba = D * (2 * din + 2 * self.ssm_state
                         + din // self.ssm_head_dim) + din * D
            n_shared = 1
            attn = D * self.num_heads * self.head_dim * 2 \
                + D * self.num_kv_heads * self.head_dim * 2 + 3 * D * F
            return emb + L * mamba + n_shared * attn
        if self.family == "audio":     # enc-dec
            attn = D * self.num_heads * self.head_dim * 2 \
                + D * self.num_kv_heads * self.head_dim * 2
            mlp = 3 * D * F
            enc = self.encoder_layers * (attn + mlp)
            dec = self.num_layers * (2 * attn + mlp)  # self + cross
            return emb + enc + dec
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        D, F, L = self.d_model, self.d_ff, self.num_layers
        attn = D * self.num_heads * self.head_dim * 2 \
            + D * self.num_kv_heads * self.head_dim * 2
        act_moe = self.experts_per_token * 3 * D * F + D * self.num_experts
        emb = self.vocab_size * D * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + act_moe)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"

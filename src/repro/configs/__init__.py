"""Architecture registry: ``get_config("<arch-id>")`` and the shape table."""

from __future__ import annotations

import importlib

from repro.configs.base import InputShape, ModelConfig  # noqa: F401
from repro.configs.shapes import SHAPES, get_shape  # noqa: F401

_ARCH_MODULES = {
    "qwen2-72b": "repro.configs.qwen2_72b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "qwen3-14b": "repro.configs.qwen3_14b",
    "seamless-m4t-medium": "repro.configs.seamless_m4t_medium",
    "granite-moe-1b-a400m": "repro.configs.granite_moe_1b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t",
    "zamba2-2.7b": "repro.configs.zamba2_2p7b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "minitron-4b": "repro.configs.minitron_4b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube3_4b",
}

ARCHITECTURES = tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {list(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def supports_long_context(cfg: ModelConfig) -> bool:
    """Whether the arch runs long_500k *natively* (sub-quadratic without a
    variant toggle).  Others get the explicit SWA variant (DESIGN.md §5)."""
    return cfg.family in ("ssm", "hybrid") or cfg.sliding_window is not None


def long_context_variant(cfg: ModelConfig) -> ModelConfig:
    """The long_500k-ready variant: identity for native sub-quadratic archs,
    sliding-window (4096) toggle for full-attention archs."""
    if supports_long_context(cfg):
        return cfg
    return cfg.with_(name=cfg.name + "+swa4k", sliding_window=4096)

"""RWKV6 "Finch" 7B [arXiv:2404.05892] — attention-free SSM with
data-dependent decay."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    ssm_head_dim=64,
)

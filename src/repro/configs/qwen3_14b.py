"""Qwen3-14B [hf:Qwen/Qwen3-8B family] — dense GQA decoder with qk_norm."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1e6,
)

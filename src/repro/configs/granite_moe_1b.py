"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base] —
32-expert top-8 MoE."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,               # per-expert hidden
    vocab_size=49155,
    num_experts=32,
    experts_per_token=8,
    rope_theta=1e4,
)

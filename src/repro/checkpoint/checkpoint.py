"""Msgpack-based pytree checkpointing (no orbax dependency).

Layout: ``<dir>/step_<n>/ {manifest.msgpack, arrays.npz}``.  The manifest
records the treedef (as a nested token structure), dtypes, and shapes; arrays
are stored in a single compressed ``.npz``.  Atomic via write-to-tmp+rename.

Works for params, optimizer states (NamedTuples), and metrics dicts.
"""

from __future__ import annotations

import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(directory: str, step: int, tree, *, keep: int | None = 3) -> str:
    """Serialize ``tree`` under ``directory/step_<step>``; returns the path."""
    os.makedirs(directory, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "leaves": []}
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        key = f"leaf_{i}"
        arrays[key] = arr
        manifest["leaves"].append(
            {"path": p, "key": key, "dtype": str(arr.dtype),
             "shape": list(arr.shape)})

    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        np.savez_compressed(os.path.join(tmp, "arrays.npz"), **arrays)
        final = os.path.join(directory, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise

    if keep is not None:
        steps = sorted(all_steps(directory))
        for old in steps[:-keep]:
            shutil.rmtree(os.path.join(directory, f"step_{old:08d}"),
                          ignore_errors=True)
    return final


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            try:
                out.append(int(name[len("step_"):]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, example_tree):
    """Restore into the structure of ``example_tree`` (shape/dtype checked)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    with np.load(os.path.join(path, "arrays.npz")) as data:
        stored = {e["path"]: data[e["key"]] for e in manifest["leaves"]}

    paths, leaves, treedef = _flatten_with_paths(example_tree)
    new_leaves = []
    for p, example in zip(paths, leaves):
        if p not in stored:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = stored[p]
        ex = np.asarray(example)
        if tuple(arr.shape) != tuple(ex.shape):
            raise ValueError(
                f"shape mismatch for {p!r}: ckpt {arr.shape} vs {ex.shape}")
        new_leaves.append(jnp.asarray(arr, dtype=ex.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)

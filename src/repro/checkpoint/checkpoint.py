"""Msgpack-based pytree checkpointing (no orbax dependency).

Layout: ``<dir>/step_<n>/ {manifest.msgpack, arrays.npz}``.  The manifest
records leaf paths, dtypes, shapes, and a ``format_version``; arrays are
stored in a single compressed ``.npz``.  Atomic via write-to-tmp+rename.

Works for params, optimizer states (NamedTuples), and metrics dicts — and,
as of format_version 2, the full ``repro.core.TrainState`` (params +
opt_state + attack_state + round counter + PRNG key + metrics history).
Version-1 checkpoints (params only, no ``format_version`` key) are still
readable; callers can branch on ``read_manifest(...)['format_version']``.

Restore is dtype-strict: a manifest/example dtype mismatch raises instead of
silently casting (pass ``allow_cast=True`` to opt back into casting).
"""

from __future__ import annotations

import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

# 1 = params-only trees, no version key in the manifest (legacy).
# 2 = manifest carries format_version; used for full-TrainState checkpoints.
FORMAT_VERSION = 2


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(p) for p in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(directory: str, step: int, tree, *, keep: int | None = 3,
         payload: str | None = None) -> str:
    """Serialize ``tree`` under ``directory/step_<step>``; returns the path.

    ``payload`` optionally tags WHAT the tree is (e.g. ``"train_state"``)
    in the manifest, so restorers can tell a full TrainState from a bare
    params tree instead of guessing from the format version.
    """
    os.makedirs(directory, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    arrays = {}
    manifest = {"step": step, "format_version": FORMAT_VERSION, "leaves": []}
    if payload is not None:
        manifest["payload"] = payload
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(leaf)
        key = f"leaf_{i}"
        arrays[key] = arr
        manifest["leaves"].append(
            {"path": p, "key": key, "dtype": str(arr.dtype),
             "shape": list(arr.shape)})

    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_ckpt_")
    try:
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        np.savez_compressed(os.path.join(tmp, "arrays.npz"), **arrays)
        final = os.path.join(directory, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except Exception:
        shutil.rmtree(tmp, ignore_errors=True)
        raise

    if keep is not None:
        steps = sorted(all_steps(directory))
        for old in steps[:-keep]:
            shutil.rmtree(os.path.join(directory, f"step_{old:08d}"),
                          ignore_errors=True)
    return final


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_"):
            try:
                out.append(int(name[len("step_"):]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def read_manifest(directory: str, step: int) -> dict:
    """The raw manifest dict for ``directory/step_<step>``.

    ``format_version`` is normalized: legacy (pre-versioning) checkpoints
    report 1.  Leaf entries carry ``path``/``dtype``/``shape``.
    """
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    manifest.setdefault("format_version", 1)
    return manifest


def restore(directory: str, step: int, example_tree, *,
            allow_cast: bool = False):
    """Restore into the structure of ``example_tree``.

    Shapes and dtypes are checked against the manifest; a dtype mismatch
    raises ``ValueError`` unless ``allow_cast=True`` (the stored array is
    then cast to the example dtype — the pre-format_version-2 behaviour,
    which silently truncated e.g. f32 optimizer moments to bf16).
    """
    path = os.path.join(directory, f"step_{step:08d}")
    manifest = read_manifest(directory, step)
    dtypes = {e["path"]: e["dtype"] for e in manifest["leaves"]}
    with np.load(os.path.join(path, "arrays.npz")) as data:
        stored = {e["path"]: data[e["key"]] for e in manifest["leaves"]}

    paths, leaves, treedef = _flatten_with_paths(example_tree)
    new_leaves = []
    for p, example in zip(paths, leaves):
        if p not in stored:
            raise KeyError(f"checkpoint missing leaf {p!r}")
        arr = stored[p]
        ex = np.asarray(example)
        if tuple(arr.shape) != tuple(ex.shape):
            raise ValueError(
                f"shape mismatch for {p!r}: ckpt {arr.shape} vs {ex.shape}")
        if dtypes[p] != str(ex.dtype) and not allow_cast:
            raise ValueError(
                f"dtype mismatch for {p!r}: ckpt {dtypes[p]} vs "
                f"{ex.dtype} (pass allow_cast=True to cast)")
        new_leaves.append(jnp.asarray(arr, dtype=ex.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)

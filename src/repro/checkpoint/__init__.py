from repro.checkpoint.checkpoint import (  # noqa: F401
    FORMAT_VERSION,
    all_steps,
    latest_step,
    read_manifest,
    restore,
    save,
)

"""Worker -> batch grouping for the geometric median of means.

The paper (Algorithm 2, step 1) fixes the partition up-front: the l-th batch
is workers {(l-1)b+1, ..., lb} with b = m/k.  Because the Byzantine set B_t
may change every round but the partition is fixed, at most q batches are
contaminated each round regardless of which workers are faulty.

We also provide strided and seeded-permutation partitions (ablations): the
guarantee is identical for any *fixed* partition, but a fresh random partition
per round is NOT safe against the paper's omniscient adversary (it observes
the server's random bits), so reseeding per-round is deliberately not offered.

Beyond the paper, ``k`` need not divide ``m``: when it does not, batches get
near-even sizes (the first ``m % k`` batches take one extra worker).  The
fixed-partition tolerance argument only needs *some* fixed partition into k
groups, so the guarantee is unchanged; the paper's experimental configuration
m=50, k=11 is exactly this case.  ``assignment_matrix`` exposes the partition
as a dense {0,1} (k, m) membership matrix so batch means can be computed as a
single (MXU-friendly) matmul — the form the fused Pallas round kernel
(``repro.kernels.geomed.round``) consumes.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Grouping:
    """Static worker->batch assignment. ``perm[w]`` is the slot of worker w;
    ordering workers by slot and splitting at the cumulative ``batch_sizes``
    boundaries yields the batches (for even groupings this is exactly the
    reshape-to-(k, b) view)."""
    num_workers: int
    num_batches: int
    perm: tuple[int, ...]   # length m, a permutation of range(m)

    @property
    def is_even(self) -> bool:
        return self.num_workers % self.num_batches == 0

    @property
    def batch_size(self) -> int:
        """Workers per batch — only defined for even groupings (k | m)."""
        if not self.is_even:
            raise ValueError(
                f"uneven grouping (m={self.num_workers}, k={self.num_batches})"
                " has no single batch_size; use batch_sizes")
        return self.num_workers // self.num_batches

    @property
    def batch_sizes(self) -> tuple[int, ...]:
        """Per-batch worker counts; near-even when k does not divide m."""
        base, rem = divmod(self.num_workers, self.num_batches)
        return tuple(base + 1 if l < rem else base
                     for l in range(self.num_batches))

    def batches(self) -> list[list[int]]:
        # perm maps worker -> slot; batches are contiguous slot ranges, so
        # invert it (slot -> worker) before splitting at the boundaries.
        inv = np.argsort(self.perm)
        out, start = [], 0
        for size in self.batch_sizes:
            out.append([int(inv[start + j]) for j in range(size)])
            start += size
        return out


def make_grouping(num_workers: int, num_batches: int, *,
                  scheme: str = "contiguous", seed: int = 0) -> Grouping:
    if num_batches < 1 or num_batches > num_workers:
        raise ValueError(
            f"num_batches={num_batches} must be in [1, m={num_workers}]")
    if scheme == "contiguous":          # paper Algorithm 2
        perm = tuple(range(num_workers))
    elif scheme == "strided":
        # worker w goes to batch w % k; stable order within batch.
        order = sorted(range(num_workers), key=lambda w: (w % num_batches, w))
        perm = tuple(int(np.argsort(order)[w]) for w in range(num_workers))
    elif scheme == "seeded":
        rng = np.random.default_rng(seed)
        order = rng.permutation(num_workers)
        perm = tuple(int(np.argsort(order)[w]) for w in range(num_workers))
    else:
        raise ValueError(f"unknown grouping scheme {scheme!r}")
    return Grouping(num_workers=num_workers, num_batches=num_batches,
                    perm=perm)


def worker_batch_ids(grouping: Grouping) -> np.ndarray:
    """(m,) int array: ``worker_batch_ids(g)[w]`` is the batch worker w
    belongs to.  The per-worker (row-wise) view of ``assignment_matrix`` —
    the form selection-style rules (``norm_filter_gmom``) use to rescale a
    worker's contribution to its batch mean without materializing S."""
    ids = np.zeros((grouping.num_workers,), np.int64)
    for l, members in enumerate(grouping.batches()):
        ids[members] = l
    return ids


def assignment_matrix(grouping: Grouping) -> np.ndarray:
    """Dense {0,1} membership matrix S of shape (k, m): S[l, w] = 1 iff
    worker w belongs to batch l.  Batch sums are ``S @ G`` for stacked
    gradients G (m, d); dividing row l by ``batch_sizes[l]`` gives the batch
    means.  This is the form the fused round kernel streams through the MXU.
    """
    s = np.zeros((grouping.num_batches, grouping.num_workers), np.float32)
    for l, members in enumerate(grouping.batches()):
        s[l, members] = 1.0
    return s


def choose_num_batches(num_workers: int, num_byzantine: int, *,
                       epsilon: float = 0.1,
                       prefer_even: bool = True) -> int:
    """The paper's canonical k (Remark 1): k=1 when q=0, else the smallest
    divisor of m with k >= 2(1+epsilon)q (tolerance requires 2(1+eps)q<=k).

    ``prefer_even=True`` (the default, and the historical behavior every
    golden trace is recorded on) keeps the paper's exact-split assumption
    b = m/k, which can overshoot: m=50, q=5 needs k >= 11 but the smallest
    divisor is 25.  ``prefer_even=False`` returns the smallest k >= need
    outright — the paper's own experimental geometry (m=50, k=11), with
    near-even uneven batches handled by ``make_grouping``/the fused round
    kernel's membership matmul.  Callers wanting a specific k (e.g. the
    paper's 11) pass ``num_batches`` explicitly.
    """
    if num_byzantine == 0:
        return 1
    need = 2.0 * (1.0 + epsilon) * num_byzantine
    for k in range(1, num_workers + 1):
        if k >= need and (num_workers % k == 0 or not prefer_even):
            return k
    raise ValueError(
        f"cannot tolerate q={num_byzantine} byzantine of m={num_workers}: "
        f"need k >= {need:.1f} <= m")

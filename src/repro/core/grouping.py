"""Worker -> batch grouping for the geometric median of means.

The paper (Algorithm 2, step 1) fixes the partition up-front: the l-th batch
is workers {(l-1)b+1, ..., lb} with b = m/k.  Because the Byzantine set B_t
may change every round but the partition is fixed, at most q batches are
contaminated each round regardless of which workers are faulty.

We also provide strided and seeded-permutation partitions (ablations): the
guarantee is identical for any *fixed* partition, but a fresh random partition
per round is NOT safe against the paper's omniscient adversary (it observes
the server's random bits), so reseeding per-round is deliberately not offered.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Grouping:
    """Static worker->batch assignment. ``perm[w]`` is the slot of worker w;
    reshaping a permuted (m, ...) array to (k, b, ...) yields the batches."""
    num_workers: int
    num_batches: int
    perm: tuple[int, ...]   # length m, a permutation of range(m)

    @property
    def batch_size(self) -> int:
        return self.num_workers // self.num_batches

    def batches(self) -> list[list[int]]:
        b = self.batch_size
        inv = list(self.perm)
        return [[inv[l * b + j] for j in range(b)]
                for l in range(self.num_batches)]


def make_grouping(num_workers: int, num_batches: int, *,
                  scheme: str = "contiguous", seed: int = 0) -> Grouping:
    if num_batches < 1 or num_batches > num_workers:
        raise ValueError(
            f"num_batches={num_batches} must be in [1, m={num_workers}]")
    if num_workers % num_batches != 0:
        raise ValueError(
            f"k={num_batches} must divide m={num_workers} (paper assumption)")
    if scheme == "contiguous":          # paper Algorithm 2
        perm = tuple(range(num_workers))
    elif scheme == "strided":
        b = num_workers // num_batches
        # worker w goes to batch w % k; stable order within batch.
        order = sorted(range(num_workers), key=lambda w: (w % num_batches, w))
        perm = tuple(int(np.argsort(order)[w]) for w in range(num_workers))
        del b
    elif scheme == "seeded":
        rng = np.random.default_rng(seed)
        order = rng.permutation(num_workers)
        perm = tuple(int(np.argsort(order)[w]) for w in range(num_workers))
    else:
        raise ValueError(f"unknown grouping scheme {scheme!r}")
    return Grouping(num_workers=num_workers, num_batches=num_batches,
                    perm=perm)


def choose_num_batches(num_workers: int, num_byzantine: int, *,
                       epsilon: float = 0.1) -> int:
    """The paper's canonical k (Remark 1): k=1 when q=0, else the smallest
    divisor of m with k >= 2(1+epsilon)q (tolerance requires 2(1+eps)q<=k)."""
    if num_byzantine == 0:
        return 1
    need = 2.0 * (1.0 + epsilon) * num_byzantine
    for k in range(1, num_workers + 1):
        if num_workers % k == 0 and k >= need:
            return k
    raise ValueError(
        f"cannot tolerate q={num_byzantine} byzantine of m={num_workers}: "
        f"need k >= {need:.1f} <= m")

"""Shard-local robust aggregation: the ZeRO-1 contract for every rule.

The paper's server cost is O(md + kd log³N) with d the model dimension —
fine for the linreg testbed and `minitron-4b`, fatal for `qwen2-72b` /
`kimi-k2-1t` where a single gathered (m, d) gradient block exceeds a chip.
The fix is the ZeRO-1 idiom: keep the stacked gradients partitioned over
parameter shards end-to-end and make every registered aggregation rule
operate on per-shard slices:

* **coordinate-wise rules** (``mean``, ``coordinate_median``,
  ``trimmed_mean``, ``coord_median``, ``coord_trimmed_mean``,
  ``random_select``) touch each coordinate independently — they are
  shard-local for free, with NO cross-shard collectives at all;
* **norm-based rules** (``gmom``, ``geomed``, ``gmom_per_leaf``,
  ``norm_select``, ``norm_clip_mean``, ``norm_filter_gmom``, ``krum``)
  need only *scalar-sized* cross-shard reductions: per-shard partial
  squared norms combined into the (k,) distance/norm vectors (one such
  reduction per Weiszfeld iterate for GMoM) and one (m, m) partial
  distance reduction for krum.

:class:`ShardSpec` describes how the stacked gradients are partitioned and
which execution mode combines the partials:

* ``"gspmd"``   — dispatch metadata only.  Reductions stay plain ``jnp``
  and GSPMD inserts the cross-shard psums; used by the production
  group-mode train step (``launch.steps``), where it additionally pins the
  target backend for ``round_backend`` dispatch and forbids the fused
  round kernel (whose leaf concatenation would force a gather).
* ``"shard_map"`` — the hand-scheduled mode for code running INSIDE
  ``shard_map`` with each device holding its slice: per-shard partials are
  combined by an ``all_gather`` over ``axis`` (stacked in device order)
  followed by an ordered ``sum`` over the shard axis.
* ``"virtual"`` — the single-device oracle of ``"shard_map"``: leaves are
  *gathered* but every reduction is computed in the same canonical blocked
  order — per-shard slice partials, stacked shard-major, then the same
  ordered sum.  Because each slice partial runs the identical ops on the
  identical values as the corresponding device in ``"shard_map"`` mode,
  the two modes are **bit-identical** — this is what makes "sharded and
  gathered aggregation agree exactly" a testable contract
  (tests/test_shardmap_aggregate.py) rather than a tolerance judgement.

Partitioning convention (both blocked modes): a stacked leaf with at least
one parameter dim (``ndim > lead_axes``) is split on its LAST dim, which
must divide evenly by ``num_shards``; a leaf with no parameter dims (e.g.
a stacked scalar parameter, shape ``(m,)``) is replicated and its partial
contribution is *owned by shard 0* — every other shard adds an exact zero,
so the ordered sum is unchanged bit for bit.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# repro: bit-stable — reductions in this module must keep a fixed expression
# tree across fusion contexts: use the unrolled chain_sum idiom, never
# jnp.sum/jnp.mean over the shard/member axis (repro.verify RV101/RV105).

_MODES = ("gspmd", "shard_map", "virtual")


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    """How the stacked gradient pytree is partitioned over param shards.

    * ``num_shards``      — shard count along the partitioned (last) dim;
                            1 means "not partitioned" (trivial spec).
    * ``mode``            — ``"gspmd"`` / ``"shard_map"`` / ``"virtual"``
                            (see module docstring).
    * ``axis``            — mesh axis name carrying the shards
                            (``shard_map`` mode's all_gather axis).
    * ``target_backend``  — the backend the lowered program will RUN on
                            (``"tpu"``/``"cpu"``/...); threads through
                            ``aggregators.resolve_round_backend`` so a
                            dry-run sweep lowering TPU mesh programs from a
                            CPU host dispatches the production path, not
                            the host's.  None = use the live backend.
    """
    num_shards: int = 1
    mode: str = "gspmd"
    axis: str = "model"
    target_backend: str | None = None

    def __post_init__(self):
        if self.mode not in _MODES:
            raise ValueError(f"unknown ShardSpec mode {self.mode!r}; "
                             f"have {_MODES}")
        if self.num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got "
                             f"{self.num_shards}")

    @property
    def partitioned(self) -> bool:
        """Stacked gradients arrive as per-shard slices (any mode)."""
        return self.num_shards > 1

    @property
    def blocked(self) -> bool:
        """Reductions must use the canonical blocked order (the
        hand-scheduled ``shard_map`` mode or its ``virtual`` oracle)."""
        return self.partitioned and self.mode in ("shard_map", "virtual")


def target_backend_of(spec: ShardSpec | None) -> str | None:
    return spec.target_backend if spec is not None else None


def is_partitioned(spec: ShardSpec | None) -> bool:
    return spec is not None and spec.partitioned


def shard_slice(leaf, index: int, num_shards: int):
    """Slice ``index`` of ``num_shards`` even splits of the LAST dim."""
    d = leaf.shape[-1]
    if d % num_shards != 0:
        raise ValueError(
            f"last dim {d} of leaf {leaf.shape} does not divide into "
            f"{num_shards} shards")
    c = d // num_shards
    return jax.lax.slice_in_dim(leaf, index * c, (index + 1) * c,
                                axis=leaf.ndim - 1)


def blocked_partial_sum(spec: ShardSpec | None, items, partial_fn, *,
                        shape=(), lead_axes: int = 1):
    """Canonical f32 sum of per-item coordinate reductions, blocked by shard.

    ``items`` is a sequence of leaves (or tuples of leaves sharing their
    trailing coordinate dims); ``partial_fn(*item) -> f32 array of
    ``shape``'' reduces one item's (slice of) coordinates — e.g. per-batch
    squared distances (k,), a squared-movement scalar, or krum's (m, m)
    partial gram.  The first ``lead_axes`` axes of each item's FIRST array
    are non-coordinate axes (the stacked k/m axis); an item whose first
    array has no coordinate dims beyond those is replicated and owned by
    shard 0 (see module docstring).

    With a trivial/gspmd spec this is the plain accumulation loop the
    legacy (unsharded) path always ran — bitwise unchanged, so golden
    traces recorded on that path are unaffected.  With a blocked spec the
    result is the ordered shard-major sum of per-shard partials, identical
    bits whether the shards are real devices (``shard_map``) or virtual
    slices of a gathered leaf (``virtual``).
    """
    items = [it if isinstance(it, tuple) else (it,) for it in items]
    blocked = spec is not None and spec.blocked

    if not blocked:
        acc = jnp.zeros(shape, jnp.float32)
        for it in items:
            acc = acc + partial_fn(*it)
        return acc

    s = spec.num_shards

    def sharded(first, *, check_divisible: bool) -> bool:
        """A leaf with coordinate dims beyond the lead axes is partitioned.

        Divisibility of the last dim is only checkable in ``virtual`` mode,
        where the full leaf is visible; in ``shard_map`` mode the arrays
        are already the local slices (the mesh sharding performed — and
        validated — the split)."""
        if first.ndim <= lead_axes:
            return False
        if check_divisible and first.shape[-1] % s != 0:
            raise ValueError(
                f"leaf {first.shape} has coordinate dims but its last dim "
                f"does not divide into num_shards={s}; shard-local "
                "aggregation requires an even last-dim split")
        return True

    def chain_sum(parts_sk):
        # Ordered shard-major combine as an UNROLLED add chain.  A single
        # ``jnp.sum(axis=0)`` over the shard axis is NOT bit-stable here:
        # XLA may reassociate the s-element reduction differently depending
        # on what it fuses with downstream (observed: 1-ulp drift between
        # the virtual and shard_map lowerings of the same Weiszfeld step).
        # An explicit left-to-right add chain has a fixed expression tree in
        # both modes; s is a device count, so unrolling is cheap.
        acc = parts_sk[0]
        for i in range(1, s):
            acc = acc + parts_sk[i]
        return acc

    if spec.mode == "virtual":
        parts = []
        for i in range(s):
            acc = jnp.zeros(shape, jnp.float32)
            for it in items:
                if sharded(it[0], check_divisible=True):
                    acc = acc + partial_fn(
                        *[shard_slice(a, i, s) for a in it])
                elif i == 0:
                    acc = acc + partial_fn(*it)
            parts.append(acc)
        return chain_sum(jnp.stack(parts))

    # shard_map mode: every array in a sharded item is already the local
    # slice; replicated items contribute on shard 0 only (exact zeros
    # elsewhere keep the ordered sum bit-identical to the virtual oracle).
    on_shard0 = jax.lax.axis_index(spec.axis) == 0
    acc = jnp.zeros(shape, jnp.float32)
    for it in items:
        if sharded(it[0], check_divisible=False):
            acc = acc + partial_fn(*it)
        else:
            p = partial_fn(*it)
            acc = acc + jnp.where(on_shard0, p, jnp.zeros_like(p))
    parts = jax.lax.all_gather(acc, spec.axis, axis=0)   # (s,) + shape
    return chain_sum(parts)

"""Byzantine attack zoo.

The paper's fault model (§1.2): in round t an arbitrary set B_t of up to q
workers reports arbitrary vectors; the adversary is omniscient (sees all
honest gradients, the server program, and the server's random bits) and
colluding, and B_t may change every round.  It cannot corrupt local data —
only the *reported* gradients.

We realize this inside the SPMD program: an ``Attack`` is a pure function
``(stacked_honest_grads, byz_mask, key, context) -> stacked_reported_grads``
that may read every honest gradient (omniscience) but may only *change* rows
where ``byz_mask`` is True (enforced by construction via jnp.where).

Attack selection of B_t per round is handled by ``sample_byzantine_mask``:
either a fixed set, or an adversarially rotating set (different workers each
round — the paper's hardest case for schemes that try to identify culprits).

Multi-round adversaries are ``AttackSchedule``s: the Byzantine set AND the
attack are pure functions of the round index plus a small carried attack
state, so a whole campaign ("stay quiet until the model nearly converges,
then strike") rolls into one ``lax.scan`` (see robust_train.make_run_rounds).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

AttackFn = Callable[..., object]

_REGISTRY: dict[str, "Attack"] = {}


@dataclasses.dataclass(frozen=True)
class Attack:
    name: str
    fn: AttackFn
    description: str = ""

    def __call__(self, stacked_grads, byz_mask, key, **kw):
        return self.fn(stacked_grads, byz_mask, key, **kw)


def register(name: str, description: str = ""):
    def deco(fn):
        _REGISTRY[name] = Attack(name=name, fn=fn, description=description)
        return fn
    return deco


def get_attack(name: str) -> Attack:
    if name not in _REGISTRY:
        raise KeyError(f"unknown attack {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available() -> list[str]:
    return sorted(_REGISTRY)


def _mask_like(mask, g):
    """Broadcast the (m,) bool mask against a stacked leaf (m, ...)."""
    return mask.reshape((-1,) + (1,) * (g.ndim - 1))


def _where_byz(mask, malicious, honest):
    return jax.tree.map(
        lambda bad, good: jnp.where(_mask_like(mask, good), bad, good),
        malicious, honest)


def sample_byzantine_mask(key, num_workers: int, num_byzantine, *,
                          rotate: bool = True, round_index=0) -> jax.Array:
    """(m,) bool mask with exactly q True entries.

    ``rotate=True`` draws a fresh uniformly-random q-subset per round (fold
    the round index into the key) — modeling B_t changing across iterations.
    ``rotate=False`` fixes the first q workers (worst case for contiguous
    grouping: the q faults hit q distinct batches).

    ``num_byzantine`` may be a traced integer (ramp-up schedules vary q
    across scanned rounds); the rank comparison handles that and ties alike.
    """
    if isinstance(num_byzantine, int):
        if num_byzantine == 0:
            return jnp.zeros((num_workers,), bool)
        if not rotate:
            return jnp.arange(num_workers) < num_byzantine
    elif not rotate:
        return jnp.arange(num_workers) < num_byzantine
    from repro.core.aggregators import bottom_k_mask
    key = jax.random.fold_in(key, round_index)
    scores = jax.random.uniform(key, (num_workers,))
    return bottom_k_mask(scores, num_byzantine).astype(bool)


# ---------------------------------------------------------------------------
# attacks

@register("none", "no attack — every worker honest")
def none_attack(stacked_grads, byz_mask, key, **_kw):
    del byz_mask, key
    return stacked_grads


@register("sign_flip", "report -c × true gradient (classic reverse attack)")
def sign_flip_attack(stacked_grads, byz_mask, key, *, scale: float = 10.0,
                     **_kw):
    del key
    malicious = jax.tree.map(lambda g: -scale * g, stacked_grads)
    return _where_byz(byz_mask, malicious, stacked_grads)


@register("zero", "report zero gradients (stalling attack)")
def zero_attack(stacked_grads, byz_mask, key, **_kw):
    del key
    malicious = jax.tree.map(jnp.zeros_like, stacked_grads)
    return _where_byz(byz_mask, malicious, stacked_grads)


@register("random_noise", "report large gaussian noise")
def random_noise_attack(stacked_grads, byz_mask, key, *,
                        scale: float = 100.0, **_kw):
    leaves, treedef = jax.tree.flatten(stacked_grads)
    keys = jax.random.split(key, len(leaves))
    noisy = [scale * jax.random.normal(k, l.shape, l.dtype)
             for k, l in zip(keys, leaves)]
    return _where_byz(byz_mask, jax.tree.unflatten(treedef, noisy),
                      stacked_grads)


@register("mean_shift",
          "omniscient: shift the honest mean by a huge constant direction")
def mean_shift_attack(stacked_grads, byz_mask, key, *, scale: float = 1e3,
                      **_kw):
    del key
    m = jax.tree.leaves(stacked_grads)[0].shape[0]
    q = jnp.maximum(jnp.sum(byz_mask.astype(jnp.float32)), 1.0)
    # each byzantine reports mean + (m/q)*scale*1 so the *average* moves by
    # ~scale in every coordinate — enough to send plain BGD to infinity.
    def mal(g):
        mu = jnp.mean(g, axis=0, keepdims=True)
        shift = (m / q) * scale
        return jnp.broadcast_to(mu + shift, g.shape).astype(g.dtype)
    return _where_byz(byz_mask, jax.tree.map(mal, stacked_grads),
                      stacked_grads)


@register("inner_product",
          "omniscient: report -eps × honest mean (Xie et al. inner-product "
          "manipulation — small norm, survives norm filters)")
def inner_product_attack(stacked_grads, byz_mask, key, *,
                         epsilon_scale: float = 1.0, **_kw):
    del key
    def mal(g):
        mu = jnp.mean(g, axis=0, keepdims=True)
        return jnp.broadcast_to(-epsilon_scale * mu, g.shape).astype(g.dtype)
    return _where_byz(byz_mask, jax.tree.map(mal, stacked_grads),
                      stacked_grads)


@register("colluding_mimic",
          "omniscient collusion: all byzantine report the *same* crafted "
          "point far away, forming a fake cluster to drag the median")
def colluding_mimic_attack(stacked_grads, byz_mask, key, *,
                           scale: float = 50.0, **_kw):
    def mal(g, k):
        mu = jnp.mean(g, axis=0, keepdims=True)
        direction = jax.random.normal(k, mu.shape, jnp.float32)
        direction = direction / jnp.maximum(
            jnp.linalg.norm(direction), 1e-12)
        point = mu + scale * jnp.linalg.norm(mu) * direction.astype(g.dtype)
        return jnp.broadcast_to(point, g.shape).astype(g.dtype)
    leaves, treedef = jax.tree.flatten(stacked_grads)
    keys = jax.random.split(key, len(leaves))
    malicious = jax.tree.unflatten(
        treedef, [mal(l, k) for l, k in zip(leaves, keys)])
    return _where_byz(byz_mask, malicious, stacked_grads)


@register("anti_aggregation",
          "omniscient: estimate what GMoM would output on honest grads and "
          "report its negation scaled up (targets the aggregator itself)")
def anti_aggregation_attack(stacked_grads, byz_mask, key, *,
                            scale: float = 10.0, num_batches: int = 4, **_kw):
    del key
    from repro.core import aggregators as agg
    m = jax.tree.leaves(stacked_grads)[0].shape[0]
    nb = max(1, min(num_batches, m))
    while m % nb != 0:
        nb -= 1
    honest_est = agg.gmom_aggregator(stacked_grads, num_batches=nb,
                                     trim_multiplier=None, max_iters=8)
    malicious = jax.tree.map(
        lambda e, g: jnp.broadcast_to(-scale * e[None], g.shape).astype(g.dtype),
        honest_est, stacked_grads)
    return _where_byz(byz_mask, malicious, stacked_grads)


@register("label_flip",
          "non-omniscient data-poisoning proxy: gradient computed as if "
          "labels were permuted — here approximated by negating the gradient "
          "without rescaling (unit-norm sign attack)")
def label_flip_attack(stacked_grads, byz_mask, key, **_kw):
    del key
    malicious = jax.tree.map(lambda g: -g, stacked_grads)
    return _where_byz(byz_mask, malicious, stacked_grads)


@register("alie",
          "A Little Is Enough [Baruch et al. '19]: all byzantine report "
          "mean - z·std of the honest gradients, with z calibrated from "
          "(m, q) so the point still looks like a plausible honest draw — "
          "small perturbation, accumulates bias across rounds")
def alie_attack(stacked_grads, byz_mask, key, *, z_max: float | None = None,
                min_z: float = 0.5, **_kw):
    del key
    m = jax.tree.leaves(stacked_grads)[0].shape[0]
    honest_w = jnp.logical_not(byz_mask).astype(jnp.float32)     # (m,)
    n_h = jnp.maximum(jnp.sum(honest_w), 1.0)
    if z_max is None:
        # z s.t. Phi(z) = (m - q - s)/(m - q) with s = floor(m/2 + 1) - q:
        # the crafted point ranks inside the majority of honest draws.
        # Small q makes that calibration degenerate (phi -> 1/2 => z -> 0,
        # i.e. reporting the honest mean); floor at min_z so the attack
        # always injects a nonzero within-spread bias.
        q = jnp.sum(byz_mask.astype(jnp.float32))
        s = jnp.floor(m / 2.0 + 1.0) - q
        phi = (m - q - s) / jnp.maximum(m - q, 1.0)
        z = jax.scipy.special.ndtri(jnp.clip(phi, 0.5, 1.0 - 1e-6))
        z = jnp.maximum(z, min_z)
    else:
        z = jnp.asarray(z_max, jnp.float32)

    def mal(g):
        gf = g.astype(jnp.float32)
        w = _mask_like(honest_w, gf)
        mu = jnp.sum(gf * w, axis=0, keepdims=True) / n_h
        var = jnp.sum(jnp.square(gf - mu) * w, axis=0, keepdims=True) / n_h
        point = mu - z * jnp.sqrt(var)
        return jnp.broadcast_to(point, g.shape).astype(g.dtype)

    return _where_byz(byz_mask, jax.tree.map(mal, stacked_grads),
                      stacked_grads)


@register("norm_stealth",
          "adaptive omniscient: report the *negated* honest-mean direction "
          "rescaled to sit just under the server's norm-trim threshold "
          "(multiplier × median worker norm) so trimming never fires")
def norm_stealth_attack(stacked_grads, byz_mask, key, *,
                        trim_multiplier: float = 3.0, safety: float = 0.9,
                        **_kw):
    del key
    from repro.core.geometric_median import batch_mean_norms
    norms = batch_mean_norms(stacked_grads)          # (m,) — honest pre-attack
    tau = safety * trim_multiplier * jnp.median(norms)
    leaves, treedef = jax.tree.flatten(stacked_grads)
    mu = [jnp.mean(l.astype(jnp.float32), axis=0, keepdims=True)
          for l in leaves]
    mu_norm = jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in mu))
    scale = tau / jnp.maximum(mu_norm, 1e-12)
    malicious = jax.tree.unflatten(treedef, [
        jnp.broadcast_to(-scale * x, l.shape).astype(l.dtype)
        for x, l in zip(mu, leaves)])
    return _where_byz(byz_mask, malicious, stacked_grads)


@register("sign_flip_targeted",
          "omniscient, native to majority-vote aggregation: casts all q "
          "byzantine votes against the honest sign exactly on the "
          "coordinates where the margin is thin enough to flip the vote, "
          "and reports the honest mean elsewhere — honest-sized magnitude, "
          "maximal vote damage")
def sign_flip_targeted_attack(stacked_grads, byz_mask, key, **_kw):
    """The adversary native to ``sign_sgd_majority``: per coordinate it
    counts the honest sign votes, identifies the coordinates where casting
    all q byzantine votes against the honest majority flips the outcome
    (margin ≤ 2q in vote counts), and reports a gradient whose sign opposes
    the honest majority exactly there — with honest-mean-|g| magnitude, so
    unlike ``sign_flip``'s −10×g reports it hides inside the honest norm
    envelope.  On thick-margin coordinates it reports the honest mean
    (indistinguishable from an honest worker).  Against averaging rules the
    damage is negligible; against the vote it is optimal per coordinate.
    """
    del key
    m = jax.tree.leaves(stacked_grads)[0].shape[0]
    honest_w = jnp.logical_not(byz_mask).astype(jnp.float32)     # (m,)
    n_h = jnp.maximum(jnp.sum(honest_w), 1.0)
    q = jnp.sum(byz_mask.astype(jnp.float32))

    def mal(g):
        gf = g.astype(jnp.float32)
        w = _mask_like(honest_w, gf)
        neg = jnp.signbit(gf).astype(jnp.float32)
        # votes the server would see if everyone reported honestly, and the
        # honest workers' share of the negative votes
        n_neg_all = jnp.sum(neg, axis=0, keepdims=True)
        n_neg_h = jnp.sum(neg * w, axis=0, keepdims=True)
        maj_neg = 2.0 * n_neg_all > m                # honest-vote outcome
        # flippable: with all q byzantine votes cast against the honest
        # majority the outcome changes (ties resolve to +1, matching the
        # server's vote rule)
        flip_pos_maj = jnp.logical_and(
            jnp.logical_not(maj_neg), 2.0 * (n_neg_h + q) > m)
        flip_neg_maj = jnp.logical_and(maj_neg, 2.0 * n_neg_h <= m)
        flippable = jnp.logical_or(flip_pos_maj, flip_neg_maj)
        # honest-sized magnitude, sign against the majority where it flips
        mu = jnp.sum(gf * w, axis=0, keepdims=True) / n_h
        mag = jnp.sum(jnp.abs(gf) * w, axis=0, keepdims=True) / n_h
        against = jnp.where(maj_neg, mag, -mag)
        point = jnp.where(flippable, against, mu)
        return jnp.broadcast_to(point, g.shape).astype(g.dtype)

    return _where_byz(byz_mask, jax.tree.map(mal, stacked_grads),
                      stacked_grads)


# ---------------------------------------------------------------------------
# attack schedules: multi-round adversaries as pure functions of the round

@dataclasses.dataclass(frozen=True)
class AttackSchedule:
    """A multi-round adversary campaign.

    ``apply(stacked_honest_grads, key, round_index, state) ->
    (reported_grads, byz_mask, new_state)`` must be jit/scan-friendly:
    ``round_index`` is traced inside ``lax.scan`` and ``state`` (from
    ``init_state()``) is the carried attack memory.

    Checkpoint contract: ``init_state()`` must return a pytree whose
    structure is FIXED for the schedule's lifetime with array leaves only
    (scalars as 0-d jnp arrays, ``()`` when stateless), and ``apply`` must
    preserve that structure and every leaf dtype.  This is what lets
    ``repro.core.TrainState`` serialize the adversary's memory alongside
    params/opt_state so resumed runs replay bit-identically
    (tests/test_train_state.py round-trips every registered schedule).
    """
    name: str
    num_workers: int
    num_byzantine: int
    init_state: Callable[[], Any]
    apply: Callable[..., tuple]


_SCHEDULE_REGISTRY: dict[str, Callable[..., AttackSchedule]] = {}
_SCHEDULE_DESCRIPTIONS: dict[str, str] = {}


def register_schedule(name: str, description: str = ""):
    def deco(builder):
        _SCHEDULE_REGISTRY[name] = builder
        _SCHEDULE_DESCRIPTIONS[name] = description
        return builder
    return deco


def make_schedule(name: str, *, num_workers: int, num_byzantine: int,
                  attack: str = "sign_flip", attack_kwargs=(),
                  **kwargs) -> AttackSchedule:
    if name not in _SCHEDULE_REGISTRY:
        raise KeyError(
            f"unknown schedule {name!r}; have {sorted(_SCHEDULE_REGISTRY)}")
    return _SCHEDULE_REGISTRY[name](
        num_workers=num_workers, num_byzantine=num_byzantine, attack=attack,
        attack_kwargs=tuple(attack_kwargs), **kwargs)


def available_schedules() -> list[str]:
    return sorted(_SCHEDULE_REGISTRY)


def describe() -> list[tuple[str, str]]:
    """(name, description) rows for every registered attack, sorted."""
    return [(n, _REGISTRY[n].description) for n in available()]


def describe_schedules() -> list[tuple[str, str]]:
    """(name, description) rows for every registered schedule, sorted."""
    return [(n, _SCHEDULE_DESCRIPTIONS[n]) for n in available_schedules()]


def _stateless(): return ()


@register_schedule("static", "fixed Byzantine set (first q workers), same attack every round")
def static_schedule(*, num_workers, num_byzantine, attack="sign_flip",
                    attack_kwargs=(), **_kw) -> AttackSchedule:
    """Fixed Byzantine set (first q workers), same attack every round."""
    atk, kw = get_attack(attack), dict(attack_kwargs)

    def apply(stacked, key, round_index, state):
        del round_index
        mask = sample_byzantine_mask(key, num_workers, num_byzantine,
                                     rotate=False)
        return atk(stacked, mask, key, **kw), mask, state

    return AttackSchedule("static", num_workers, num_byzantine,
                          _stateless, apply)


@register_schedule("rotating", "fresh random q-subset each round — the paper's time-varying hard case")
def rotating_schedule(*, num_workers, num_byzantine, attack="sign_flip",
                      attack_kwargs=(), **_kw) -> AttackSchedule:
    """Fresh uniformly-random q-subset every round (B_t changes per round —
    the paper's hardest case for culprit-identification defenses)."""
    atk, kw = get_attack(attack), dict(attack_kwargs)

    def apply(stacked, key, round_index, state):
        mask = sample_byzantine_mask(key, num_workers, num_byzantine,
                                     rotate=True, round_index=round_index)
        return atk(stacked, mask, key, **kw), mask, state

    return AttackSchedule("rotating", num_workers, num_byzantine,
                          _stateless, apply)


@register_schedule("ramp_up", "corruption grows 0 -> q over ramp_rounds (slow-burn infiltration)")
def ramp_up_schedule(*, num_workers, num_byzantine, attack="sign_flip",
                     attack_kwargs=(), ramp_rounds: int = 20,
                     **_kw) -> AttackSchedule:
    """Corruption grows from 0 to q over ``ramp_rounds`` rounds (a slowly
    spreading compromise), rotating which workers are faulty."""
    atk, kw = get_attack(attack), dict(attack_kwargs)

    def apply(stacked, key, round_index, state):
        frac = jnp.minimum((round_index + 1.0) / ramp_rounds, 1.0)
        q_t = jnp.ceil(frac * num_byzantine).astype(jnp.int32)
        mask = sample_byzantine_mask(key, num_workers, q_t,
                                     rotate=True, round_index=round_index)
        return atk(stacked, mask, key, **kw), mask, state

    return AttackSchedule("ramp_up", num_workers, num_byzantine,
                          _stateless, apply)


@register_schedule("coordinated_switch", "all colluders switch from attack to attack2 at switch_round")
def coordinated_switch_schedule(*, num_workers, num_byzantine,
                                attack="sign_flip",
                                attack_b="inner_product",
                                attack_kwargs=(), attack_b_kwargs=(),
                                switch_round: int = 10, rotate: bool = True,
                                **_kw) -> AttackSchedule:
    """All colluders run ``attack`` until ``switch_round`` then switch to
    ``attack_b`` in lockstep — probes defenses tuned to one attack family."""
    atk_a, kw_a = get_attack(attack), dict(attack_kwargs)
    atk_b, kw_b = get_attack(attack_b), dict(attack_b_kwargs)

    def apply(stacked, key, round_index, state):
        mask = sample_byzantine_mask(key, num_workers, num_byzantine,
                                     rotate=rotate, round_index=round_index)
        reported = jax.lax.cond(
            round_index < switch_round,
            lambda s: atk_a(s, mask, key, **kw_a),
            lambda s: atk_b(s, mask, key, **kw_b),
            stacked)
        return reported, mask, state

    return AttackSchedule("coordinated_switch", num_workers, num_byzantine,
                          _stateless, apply)


@register_schedule("stealth_then_strike", "stateful: honest until the aggregate gradient norm decays below trigger, then latches into attacking")
def stealth_then_strike_schedule(*, num_workers, num_byzantine,
                                 attack="sign_flip", attack_kwargs=(),
                                 strike_fraction: float = 0.25,
                                 ema_decay: float = 0.8,
                                 **_kw) -> AttackSchedule:
    """Adaptive omniscient campaign: the colluders report honestly while
    tracking an EMA of the honest-mean gradient norm; once it decays below
    ``strike_fraction`` × its initial value (the model is near the optimum,
    where damage is most visible) they latch into attacking every round."""
    atk, kw = get_attack(attack), dict(attack_kwargs)

    def init_state():
        return {"init_norm": jnp.array(-1.0, jnp.float32),
                "ema_norm": jnp.array(0.0, jnp.float32),
                "struck": jnp.array(False)}

    def apply(stacked, key, round_index, state):
        del round_index
        norm = jnp.sqrt(sum(
            jnp.sum(jnp.square(jnp.mean(l, axis=0).astype(jnp.float32)))
            for l in jax.tree.leaves(stacked)))
        first = state["init_norm"] < 0.0
        init_norm = jnp.where(first, norm, state["init_norm"])
        ema = jnp.where(first, norm,
                        ema_decay * state["ema_norm"]
                        + (1.0 - ema_decay) * norm)
        strike = jnp.logical_or(state["struck"],
                                ema < strike_fraction * init_norm)
        base = sample_byzantine_mask(key, num_workers, num_byzantine,
                                     rotate=False)
        mask = jnp.logical_and(base, strike)
        reported = jax.lax.cond(
            strike, lambda s: atk(s, mask, key, **kw), lambda s: s, stacked)
        new_state = {"init_norm": init_norm, "ema_norm": ema,
                     "struck": strike}
        return reported, mask, new_state

    return AttackSchedule("stealth_then_strike", num_workers, num_byzantine,
                          init_state, apply)

"""Byzantine attack zoo.

The paper's fault model (§1.2): in round t an arbitrary set B_t of up to q
workers reports arbitrary vectors; the adversary is omniscient (sees all
honest gradients, the server program, and the server's random bits) and
colluding, and B_t may change every round.  It cannot corrupt local data —
only the *reported* gradients.

We realize this inside the SPMD program: an ``Attack`` is a pure function
``(stacked_honest_grads, byz_mask, key, context) -> stacked_reported_grads``
that may read every honest gradient (omniscience) but may only *change* rows
where ``byz_mask`` is True (enforced by construction via jnp.where).

Attack selection of B_t per round is handled by ``sample_byzantine_mask``:
either a fixed set, or an adversarially rotating set (different workers each
round — the paper's hardest case for schemes that try to identify culprits).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

AttackFn = Callable[..., object]

_REGISTRY: dict[str, "Attack"] = {}


@dataclasses.dataclass(frozen=True)
class Attack:
    name: str
    fn: AttackFn
    description: str = ""

    def __call__(self, stacked_grads, byz_mask, key, **kw):
        return self.fn(stacked_grads, byz_mask, key, **kw)


def register(name: str, description: str = ""):
    def deco(fn):
        _REGISTRY[name] = Attack(name=name, fn=fn, description=description)
        return fn
    return deco


def get_attack(name: str) -> Attack:
    if name not in _REGISTRY:
        raise KeyError(f"unknown attack {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available() -> list[str]:
    return sorted(_REGISTRY)


def _mask_like(mask, g):
    """Broadcast the (m,) bool mask against a stacked leaf (m, ...)."""
    return mask.reshape((-1,) + (1,) * (g.ndim - 1))


def _where_byz(mask, malicious, honest):
    return jax.tree.map(
        lambda bad, good: jnp.where(_mask_like(mask, good), bad, good),
        malicious, honest)


def sample_byzantine_mask(key, num_workers: int, num_byzantine: int, *,
                          rotate: bool = True, round_index=0) -> jax.Array:
    """(m,) bool mask with exactly q True entries.

    ``rotate=True`` draws a fresh uniformly-random q-subset per round (fold
    the round index into the key) — modeling B_t changing across iterations.
    ``rotate=False`` fixes the first q workers (worst case for contiguous
    grouping: the q faults hit q distinct batches).
    """
    if num_byzantine == 0:
        return jnp.zeros((num_workers,), bool)
    if not rotate:
        return jnp.arange(num_workers) < num_byzantine
    key = jax.random.fold_in(key, round_index)
    scores = jax.random.uniform(key, (num_workers,))
    thresh = jnp.sort(scores)[num_byzantine - 1]
    return scores <= thresh


# ---------------------------------------------------------------------------
# attacks

@register("none", "no attack — every worker honest")
def none_attack(stacked_grads, byz_mask, key, **_kw):
    del byz_mask, key
    return stacked_grads


@register("sign_flip", "report -c × true gradient (classic reverse attack)")
def sign_flip_attack(stacked_grads, byz_mask, key, *, scale: float = 10.0,
                     **_kw):
    del key
    malicious = jax.tree.map(lambda g: -scale * g, stacked_grads)
    return _where_byz(byz_mask, malicious, stacked_grads)


@register("zero", "report zero gradients (stalling attack)")
def zero_attack(stacked_grads, byz_mask, key, **_kw):
    del key
    malicious = jax.tree.map(jnp.zeros_like, stacked_grads)
    return _where_byz(byz_mask, malicious, stacked_grads)


@register("random_noise", "report large gaussian noise")
def random_noise_attack(stacked_grads, byz_mask, key, *,
                        scale: float = 100.0, **_kw):
    leaves, treedef = jax.tree.flatten(stacked_grads)
    keys = jax.random.split(key, len(leaves))
    noisy = [scale * jax.random.normal(k, l.shape, l.dtype)
             for k, l in zip(keys, leaves)]
    return _where_byz(byz_mask, jax.tree.unflatten(treedef, noisy),
                      stacked_grads)


@register("mean_shift",
          "omniscient: shift the honest mean by a huge constant direction")
def mean_shift_attack(stacked_grads, byz_mask, key, *, scale: float = 1e3,
                      **_kw):
    del key
    m = jax.tree.leaves(stacked_grads)[0].shape[0]
    q = jnp.maximum(jnp.sum(byz_mask.astype(jnp.float32)), 1.0)
    # each byzantine reports mean + (m/q)*scale*1 so the *average* moves by
    # ~scale in every coordinate — enough to send plain BGD to infinity.
    def mal(g):
        mu = jnp.mean(g, axis=0, keepdims=True)
        shift = (m / q) * scale
        return jnp.broadcast_to(mu + shift, g.shape).astype(g.dtype)
    return _where_byz(byz_mask, jax.tree.map(mal, stacked_grads),
                      stacked_grads)


@register("inner_product",
          "omniscient: report -eps × honest mean (Xie et al. inner-product "
          "manipulation — small norm, survives norm filters)")
def inner_product_attack(stacked_grads, byz_mask, key, *,
                         epsilon_scale: float = 1.0, **_kw):
    del key
    def mal(g):
        mu = jnp.mean(g, axis=0, keepdims=True)
        return jnp.broadcast_to(-epsilon_scale * mu, g.shape).astype(g.dtype)
    return _where_byz(byz_mask, jax.tree.map(mal, stacked_grads),
                      stacked_grads)


@register("colluding_mimic",
          "omniscient collusion: all byzantine report the *same* crafted "
          "point far away, forming a fake cluster to drag the median")
def colluding_mimic_attack(stacked_grads, byz_mask, key, *,
                           scale: float = 50.0, **_kw):
    def mal(g, k):
        mu = jnp.mean(g, axis=0, keepdims=True)
        direction = jax.random.normal(k, mu.shape, jnp.float32)
        direction = direction / jnp.maximum(
            jnp.linalg.norm(direction), 1e-12)
        point = mu + scale * jnp.linalg.norm(mu) * direction.astype(g.dtype)
        return jnp.broadcast_to(point, g.shape).astype(g.dtype)
    leaves, treedef = jax.tree.flatten(stacked_grads)
    keys = jax.random.split(key, len(leaves))
    malicious = jax.tree.unflatten(
        treedef, [mal(l, k) for l, k in zip(leaves, keys)])
    return _where_byz(byz_mask, malicious, stacked_grads)


@register("anti_aggregation",
          "omniscient: estimate what GMoM would output on honest grads and "
          "report its negation scaled up (targets the aggregator itself)")
def anti_aggregation_attack(stacked_grads, byz_mask, key, *,
                            scale: float = 10.0, num_batches: int = 4, **_kw):
    del key
    from repro.core import aggregators as agg
    m = jax.tree.leaves(stacked_grads)[0].shape[0]
    nb = max(1, min(num_batches, m))
    while m % nb != 0:
        nb -= 1
    honest_est = agg.gmom_aggregator(stacked_grads, num_batches=nb,
                                     trim_multiplier=None, max_iters=8)
    malicious = jax.tree.map(
        lambda e, g: jnp.broadcast_to(-scale * e[None], g.shape).astype(g.dtype),
        honest_est, stacked_grads)
    return _where_byz(byz_mask, malicious, stacked_grads)


@register("label_flip",
          "non-omniscient data-poisoning proxy: gradient computed as if "
          "labels were permuted — here approximated by negating the gradient "
          "without rescaling (unit-norm sign attack)")
def label_flip_attack(stacked_grads, byz_mask, key, **_kw):
    del key
    malicious = jax.tree.map(lambda g: -g, stacked_grads)
    return _where_byz(byz_mask, malicious, stacked_grads)

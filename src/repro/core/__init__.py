"""Core library: the paper's Byzantine Gradient Descent as composable pieces.

Public API:
    geometric_median, geometric_median_pytree, trim_weights
    aggregators.get_aggregator / available
    byzantine.get_attack / available / sample_byzantine_mask
    RobustConfig, make_robust_train_step, per_worker_grads, aggregate
    TrainState, init_train_state, advance, save/restore_train_state
    staleness: StalenessBuffer, make_arrival / available_arrivals
    grouping.make_grouping / choose_num_batches
    theory: paper constants & closed forms
"""

from repro.core.geometric_median import (  # noqa: F401
    geometric_median,
    geometric_median_pytree,
    trim_weights,
    batch_mean_norms,
    weiszfeld_step,
)
from repro.core import (  # noqa: F401
    aggregators, byzantine, grouping, staleness, theory)
from repro.core.staleness import (  # noqa: F401
    ArrivalSchedule,
    StalenessBuffer,
    arrival_from_config,
    available_arrivals,
    init_buffer,
    make_arrival,
    merge_reports,
)
from repro.core.shard_aggregation import (  # noqa: F401
    ShardSpec,
    blocked_partial_sum,
)
from repro.core.robust_train import (  # noqa: F401
    RobustConfig,
    aggregate,
    aggregate_reported,
    make_robust_train_step,
    make_run_rounds,
    make_sharded_aggregate,
    make_shardmap_aggregate,
    per_worker_grads,
    schedule_from_config,
)
from repro.core.train_state import (  # noqa: F401
    TrainState,
    advance,
    append_history,
    history_rows,
    init_train_state,
    restore_train_state,
    save_train_state,
)

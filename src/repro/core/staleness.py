"""Bounded-staleness gradient buffering: asynchrony for Byzantine GD.

The paper's system model (§2) is fully synchronous: the server waits for
all m gradient reports before aggregating, so one slow or partitioned
worker stalls every round.  This module relaxes that assumption the way
production parameter servers do — with a *bounded-staleness* buffer:

* ``StalenessBuffer`` keeps each worker's last reported gradient and its
  age (rounds since it was fresh).  A round aggregates fresh reports
  merged with buffered ones whose age is at most the bound τ.
* Rows are weighted by ``discount ** age`` and renormalized so the live
  weights sum to m (the weighted-mean normalization keeps the aggregate's
  scale independent of how many workers straggle); rows older than τ get
  weight zero — the hard drop.
* An ``ArrivalSchedule`` (registry mirroring ``byzantine.AttackSchedule``)
  decides which workers deliver fresh reports each round: honest straggler
  models and the adversarial ``byzantine_max_stale``, where the Byzantine
  workers choose their own staleness (zero — poison at full weight) while
  delaying every honest worker to the bound.

Semantics doc: docs/ASYNC.md (enforced by scripts/check_docs.py — every
registered arrival schedule must appear there and in docs/PAPER_MAP.md).

Checkpoint contract (PR 2): the buffer rides the training-scan carry, so
it MUST live in ``TrainState`` (field ``stale_buffer``; ``()`` when the
async path is disabled) with fixed structure and array leaves only — ages
are int32 (repro.verify RV107 pins both properties).  τ=0 with
``all_sync`` keeps the buffer empty and is bit-identical to the
synchronous trainer.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class StalenessBuffer(NamedTuple):
    """Per-worker last-reported gradients + ages + the staleness bound τ.

    ``grads`` mirrors the stacked-gradient pytree (leaves (m, *shape));
    ``age`` is (m,) int32 — 0 means "reported this round"; ``bound`` is a
    0-d int32 array so the whole buffer is a pure array pytree (the
    TrainState serialization contract).

    Layer C taint roots (repro.verify.taint): ``grads`` carries buffered
    worker reports (``report``-tainted — adversary memory across rounds)
    and ``age`` is adversary-controlled timing (``age``-tainted).  The
    RV302 invariant: ages and the bound may never come to depend on
    report *values* — cross-round coupling is timing and attack
    scheduling only, per the γ^age discount contract of docs/ASYNC.md.
    """
    grads: Any
    age: jax.Array
    bound: jax.Array


def init_buffer(params, num_workers: int, bound: int) -> StalenessBuffer:
    """Round-zero buffer: zero gradients aged past the bound, so nothing
    uninitialized can ever enter an aggregate (age > τ rows drop)."""
    grads = jax.tree.map(
        lambda p: jnp.zeros((num_workers,) + p.shape, p.dtype), params)
    return StalenessBuffer(
        grads=grads,
        age=jnp.full((num_workers,), bound + 1, jnp.int32),
        bound=jnp.asarray(bound, jnp.int32))


def merge_reports(buf: StalenessBuffer, reported, fresh):
    """One round's buffer update: fresh rows replace their buffered entry
    (age resets to 0), stale rows keep the buffered gradient and age by one.

    Returns ``(merged_rows, new_buffer)``; ``merged_rows`` are the
    *unweighted* union (fresh rows pass through bit-exactly), and
    ``new_buffer.grads`` is that same union — the buffer stores raw
    reports, never discounted ones, so a row's weight depends only on its
    CURRENT age.
    """
    fresh = fresh.astype(bool)

    def leaf(rep, old):
        sel = fresh.reshape((fresh.shape[0],) + (1,) * (rep.ndim - 1))
        return jnp.where(sel, rep, old)

    merged = jax.tree.map(leaf, reported, buf.grads)
    new_buf = StalenessBuffer(
        grads=merged,
        age=jnp.where(fresh, 0, buf.age + 1).astype(jnp.int32),
        bound=buf.bound)
    return merged, new_buf


def staleness_weights(age, bound, *, discount: float):
    """Per-row aggregation weight: ``discount ** age`` while age <= bound,
    exactly 0.0 beyond it (the hard drop).  Fresh rows get exactly 1.0 —
    not a computed power — so the all-fresh round is bit-identical to the
    synchronous path."""
    w = jnp.where(age == 0, jnp.float32(1.0),
                  jnp.power(jnp.float32(discount), age.astype(jnp.float32)))
    return jnp.where(age <= bound, w, jnp.float32(0.0))


def apply_staleness(rows, age, bound, *, discount: float):
    """Scale merged rows by their normalized staleness weights.

    Row j is multiplied by ``m * w_j / sum(w)`` (f32 accumulate, cast back
    at the boundary): the weighted mean of the scaled rows equals the
    w-weighted mean of the raw rows, so the aggregate's scale does not
    depend on how many workers straggle.  Dropped rows (age > bound)
    scale to exactly zero.  When every row is fresh the scale is exactly
    1.0 and the rows pass through bit-identically.
    """
    m = age.shape[0]
    w = staleness_weights(age, bound, discount=discount)
    total = jnp.maximum(jnp.sum(w), jnp.float32(1e-12))
    scale = (m * w) / total

    def leaf(g):
        s = scale.reshape((m,) + (1,) * (g.ndim - 1))
        return (g.astype(jnp.float32) * s).astype(g.dtype)

    return jax.tree.map(leaf, rows)


# ---------------------------------------------------------------------------
# arrival schedules: who delivers a fresh report this round

@dataclasses.dataclass(frozen=True)
class ArrivalSchedule:
    """Which workers report fresh each round, as a pure scan-traceable
    function — the asynchrony twin of ``byzantine.AttackSchedule``.

    ``arrive(key, round_index, byz_mask) -> (m,) bool`` must be
    jit/scan-friendly and stateless: everything it needs derives from the
    per-round key, the round index, and the attack schedule's current
    Byzantine mask (so adversarial arrival models can collude with the
    attack — the same omniscience convention the attacks follow).
    """
    name: str
    num_workers: int
    staleness_bound: int
    arrive: Callable[..., jax.Array]


_ARRIVAL_REGISTRY: dict[str, Callable[..., ArrivalSchedule]] = {}
_ARRIVAL_DESCRIPTIONS: dict[str, str] = {}


def register_arrival(name: str, description: str = ""):
    def deco(builder):
        _ARRIVAL_REGISTRY[name] = builder
        _ARRIVAL_DESCRIPTIONS[name] = description
        return builder
    return deco


def make_arrival(name: str, *, num_workers: int, staleness_bound: int,
                 **kwargs) -> ArrivalSchedule:
    if name not in _ARRIVAL_REGISTRY:
        raise KeyError(
            f"unknown arrival schedule {name!r}; have "
            f"{sorted(_ARRIVAL_REGISTRY)}")
    return _ARRIVAL_REGISTRY[name](
        num_workers=num_workers, staleness_bound=staleness_bound, **kwargs)


def available_arrivals() -> list[str]:
    return sorted(_ARRIVAL_REGISTRY)


def describe() -> list[tuple[str, str]]:
    """(name, description) rows for every registered arrival schedule —
    the docs/ASYNC.md table is generated from exactly this."""
    return [(n, _ARRIVAL_DESCRIPTIONS[n]) for n in available_arrivals()]


def arrival_from_config(cfg) -> ArrivalSchedule | None:
    """The configured arrival model, or None when the async path is
    disabled (``all_sync`` with τ=0 — the bit-identical synchronous
    default every pre-existing config resolves to)."""
    if cfg.arrival == "all_sync" and cfg.staleness_bound == 0:
        return None
    return make_arrival(cfg.arrival, num_workers=cfg.num_workers,
                        staleness_bound=cfg.staleness_bound,
                        **dict(cfg.arrival_kwargs))


@register_arrival("all_sync",
                  "every worker reports fresh every round (the paper's §2 "
                  "synchronous model; with τ=0 this IS the sync trainer)")
def all_sync(*, num_workers, staleness_bound, **_kw) -> ArrivalSchedule:
    def arrive(key, round_index, byz_mask):
        del key, round_index, byz_mask
        return jnp.ones((num_workers,), bool)

    return ArrivalSchedule("all_sync", num_workers, staleness_bound, arrive)


@register_arrival("straggler_fixed",
                  "a fixed set of num_stragglers workers delivers only "
                  "every `period` rounds (defaults to τ+1: maximally "
                  "stale but never dropped)")
def straggler_fixed(*, num_workers, staleness_bound, num_stragglers: int = 2,
                    period: int | None = None, **_kw) -> ArrivalSchedule:
    period = (staleness_bound + 1) if period is None else period
    period = max(1, period)

    def arrive(key, round_index, byz_mask):
        del key, byz_mask
        slow = jnp.arange(num_workers) < num_stragglers
        return jnp.logical_or(~slow, (round_index % period) == 0)

    return ArrivalSchedule("straggler_fixed", num_workers, staleness_bound,
                           arrive)


@register_arrival("straggler_rotating",
                  "a fresh random num_stragglers-subset misses each round "
                  "(transient network jitter — the realistic production "
                  "regime)")
def straggler_rotating(*, num_workers, staleness_bound,
                       num_stragglers: int = 2, **_kw) -> ArrivalSchedule:
    from repro.core.byzantine import sample_byzantine_mask

    def arrive(key, round_index, byz_mask):
        del byz_mask
        # decorrelate from the attack schedule's mask draw on the same key
        slow = sample_byzantine_mask(
            jax.random.fold_in(key, 31), num_workers, num_stragglers,
            rotate=True, round_index=round_index)
        return ~slow

    return ArrivalSchedule("straggler_rotating", num_workers,
                           staleness_bound, arrive)


@register_arrival("partition",
                  "a worker block drops off the network for a round window "
                  "[start_round, start_round+length) — ages past τ and is "
                  "hard-dropped until the partition heals")
def partition(*, num_workers, staleness_bound, block_start: int = 0,
              block_size: int = 2, start_round: int = 5, length: int = 10,
              **_kw) -> ArrivalSchedule:
    def arrive(key, round_index, byz_mask):
        del key, byz_mask
        idx = jnp.arange(num_workers)
        in_block = jnp.logical_and(idx >= block_start,
                                   idx < block_start + block_size)
        in_window = jnp.logical_and(round_index >= start_round,
                                    round_index < start_round + length)
        return ~jnp.logical_and(in_block, in_window)

    return ArrivalSchedule("partition", num_workers, staleness_bound, arrive)


@register_arrival("byzantine_max_stale",
                  "adversarial asynchrony: Byzantine workers choose zero "
                  "staleness (fresh poison at full weight every round) "
                  "while delaying every honest worker to the bound τ — "
                  "honest mass decays as discount^age, so large τ lets "
                  "stale-poisoning win (the pinned break point)")
def byzantine_max_stale(*, num_workers, staleness_bound,
                        **_kw) -> ArrivalSchedule:
    period = staleness_bound + 1

    def arrive(key, round_index, byz_mask):
        del key
        # honest worker j refreshes only when (t + j) % (τ+1) == 0 — the
        # adversary (who controls the network) staggers honest arrivals so
        # their ages spread over 0..τ; the colluders always deliver.
        stagger = (round_index + jnp.arange(num_workers)) % period == 0
        return jnp.logical_or(byz_mask.astype(bool), stagger)

    return ArrivalSchedule("byzantine_max_stale", num_workers,
                           staleness_bound, arrive)

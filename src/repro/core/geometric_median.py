"""Geometric median of points in R^d — the heart of the paper's aggregator.

The geometric median of ``{z_1..z_n}`` is ``argmin_y sum_i ||y - z_i||_2``
(paper eq. (6)).  The paper invokes the [CLM+16] interior-point solver for a
``(1+gamma)``-approximation; that algorithm is sequential and CPU-bound with
no TPU analogue, so we substitute the classical **Weiszfeld** fixed-point
iteration (see DESIGN.md §3): each step is a batch of distance reductions and
a weighted mean — exactly the VPU/MXU-friendly shape — and converges linearly
to any required tolerance on non-collinear inputs.

All entry points are pure-functional and jit/pjit friendly (``lax.while_loop``
/ ``lax.fori_loop`` only, no Python control flow on traced values).  Points
may live on a sharded mesh: every reduction is a plain ``jnp`` reduction so
GSPMD inserts the cross-device psums.

Supports optional per-point weights so that norm-trimmed points (paper
Remark 2) participate with weight zero without changing static shapes.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

# repro: bit-stable — the pytree Weiszfeld is part of the shard-local
# bit-equality contract (tests/test_shardmap_aggregate.py): reductions over
# the stacked k/member axis must stay unrolled multiply-add chains
# (_wsum) or route through blocked_partial_sum (repro.verify RV101/RV105).


class WeiszfeldState(NamedTuple):
    y: jax.Array          # current estimate, shape (d,) or pytree-flattened
    objective: jax.Array  # sum_i w_i ||y - z_i||  (scalar)
    step: jax.Array       # iteration counter (int32)
    delta: jax.Array      # last movement ||y_t - y_{t-1}||


def _pairwise_dists(points: jax.Array, y: jax.Array, eps: float) -> jax.Array:
    """||z_i - y|| for each row of ``points`` (n, d) vs ``y`` (d,).  Smoothed
    by ``eps`` to keep the Weiszfeld weights finite when ``y`` hits a point
    (the standard smoothing; bias is O(eps))."""
    diff = points - y[None, :]
    return jnp.sqrt(jnp.sum(diff * diff, axis=-1) + eps * eps)


def weiszfeld_step(points: jax.Array, y: jax.Array, weights: jax.Array,
                   eps: float) -> jax.Array:
    """One Weiszfeld update: y <- sum_i (w_i/d_i) z_i / sum_i (w_i/d_i)."""
    d = _pairwise_dists(points, y, eps)           # (n,)
    inv = weights / d                             # (n,)
    denom = jnp.sum(inv)
    return (inv @ points) / jnp.maximum(denom, eps)


def geometric_median(points: jax.Array,
                     *,
                     weights: jax.Array | None = None,
                     max_iters: int = 64,
                     tol: float = 1e-8,
                     eps: float = 1e-12) -> jax.Array:
    """(1+gamma)-approximate geometric median of ``points`` (n, d).

    ``tol`` is the movement stopping criterion; with the paper's choice
    gamma = 1/N one sets ``tol ~ objective_scale / N`` — in practice 64
    iterations reach float32 fixed point for the k <= 64 regimes used here.

    Initialization is the weighted mean (the k=1 aggregate), which also makes
    the function exactly reduce to the mean after 0 iterations when n == 1.
    """
    n = points.shape[0]
    if weights is None:
        weights = jnp.ones((n,), dtype=points.dtype)
    weights = weights.astype(points.dtype)

    w_sum = jnp.maximum(jnp.sum(weights), eps)
    y0 = (weights @ points) / w_sum

    def objective(y):
        return jnp.sum(weights * _pairwise_dists(points, y, eps))

    def cond(state: WeiszfeldState):
        return jnp.logical_and(state.step < max_iters, state.delta > tol)

    def body(state: WeiszfeldState):
        y_new = weiszfeld_step(points, state.y, weights, eps)
        return WeiszfeldState(
            y=y_new,
            objective=objective(y_new),
            step=state.step + 1,
            delta=jnp.linalg.norm(y_new - state.y),
        )

    init = WeiszfeldState(y=y0, objective=objective(y0),
                          step=jnp.zeros((), jnp.int32),
                          delta=jnp.array(jnp.inf, points.dtype))
    final = jax.lax.while_loop(cond, body, init)
    return final.y


def geometric_median_pytree(batch_means, *,
                            weights: jax.Array | None = None,
                            max_iters: int = 64,
                            tol: float = 1e-8,
                            eps: float = 1e-12,
                            shard_spec=None):
    """Geometric median of k *pytrees* (paper-faithful "global" mode).

    ``batch_means`` is a pytree whose leaves have a leading axis k (the batch
    means, stacked).  The geometric median treats the concatenation of all
    leaves as one R^d vector: distances are summed across leaves via plain
    jnp reductions (=> psum across the model axis when leaves are sharded);
    **no leaf is ever gathered or flattened**, so the peak memory per device
    stays at k × (its shard of the model).

    ``shard_spec`` (a :class:`repro.core.shard_aggregation.ShardSpec`)
    selects the shard-local contract: the Weiszfeld iterate and every
    weighted mean stay per-shard (the weighted k-sums are coordinate-local
    and bitwise width-invariant), and only the (k,) squared distances and
    the scalar movement cross shards — ONE small blocked reduction per
    iterate.  With a trivial spec (None / gspmd) the reductions follow the
    legacy accumulation order (golden traces stay within tolerance).

    Returns a pytree of the same structure without the leading axis.
    """
    from repro.core.shard_aggregation import blocked_partial_sum

    leaves, treedef = jax.tree.flatten(batch_means)
    k = leaves[0].shape[0]
    if weights is None:
        weights = jnp.ones((k,), dtype=jnp.float32)
    weights = weights.astype(jnp.float32)
    w_sum = jnp.maximum(jnp.sum(weights), eps)

    def _wsum(w, l):
        # weighted sum over the leading k axis as an UNROLLED elementwise
        # multiply-add chain: each output coordinate gets a fixed expression
        # tree, so a shard's slice computes exactly the bits of the full
        # leaf's slice.  Both a dot/tensordot lowering and a fused
        # broadcast-multiply + sum-over-k are width-sensitive (the compiler
        # may reassociate or vectorize the k-reduction differently per
        # coordinate width), which would break the shard-local bit-equality
        # contract; k is small (<= num_workers) so unrolling is cheap.
        wf = w.astype(l.dtype)
        acc = wf[0] * l[0]
        for i in range(1, l.shape[0]):
            acc = acc + wf[i] * l[i]
        return acc

    def wmean(ls):
        return [_wsum(weights, l) / w_sum.astype(l.dtype) for l in ls]

    def _pair_sq(l, yl):
        diff = (l - yl[None]).astype(jnp.float32)
        return jnp.sum(diff * diff, axis=tuple(range(1, diff.ndim)))

    def sq_dists(ls, y):
        """(k,) squared distances from stacked points to estimate y."""
        return blocked_partial_sum(shard_spec, list(zip(ls, y)), _pair_sq,
                                   shape=(k,), lead_axes=1)

    def step(y):
        d = jnp.sqrt(sq_dists(leaves, y) + eps * eps)        # (k,)
        inv = weights / d
        denom = jnp.maximum(jnp.sum(inv), eps)
        y_new = [_wsum(inv / denom, l) for l in leaves]
        return y_new

    y0 = wmean(leaves)

    def _pair_delta(x, z):
        return jnp.sum((x - z).astype(jnp.float32) ** 2)

    def flat_delta(a, b):
        return blocked_partial_sum(shard_spec, list(zip(a, b)), _pair_delta,
                                   shape=(), lead_axes=0)

    def cond(carry):
        _, it, delta = carry
        return jnp.logical_and(it < max_iters, delta > tol * tol)

    def body(carry):
        y, it, _ = carry
        y_new = step(y)
        return (y_new, it + 1, flat_delta(y_new, y))

    y, _, _ = jax.lax.while_loop(
        cond, body, (y0, jnp.zeros((), jnp.int32),
                     jnp.array(jnp.inf, jnp.float32)))
    return jax.tree.unflatten(treedef, y)


def trim_weights(norms: jax.Array, *, multiplier: float = 3.0,
                 eps: float = 1e-12) -> jax.Array:
    """Norm-trimming weights (paper Remark 2, self-tuning threshold).

    The paper trims batch means with norm > tau = Theta(d) before the
    approximate geomed so the gamma-deviation term (prop. to max_i ||z_i||)
    stays bounded.  A fixed Theta(d) constant is analysis-only; we use the
    robust, scale-free tau = multiplier × median(norms): at least half the
    batches are honest (k >= 2(1+eps)q), so the median norm is within the
    honest envelope and honest batches are kept w.h.p.

    Returns {0,1} weights, guaranteed not all zero.
    """
    tau = multiplier * jnp.median(norms) + eps
    w = (norms <= tau).astype(norms.dtype)
    # Degenerate guard: if everything got trimmed (all-equal huge norms),
    # fall back to uniform weights rather than a 0/0.
    return jnp.where(jnp.sum(w) > 0, w, jnp.ones_like(w))


def batch_mean_norms(batch_means, *, shard_spec=None) -> jax.Array:
    """Global L2 norm of each of the k stacked pytree batch means.

    With a blocked ``shard_spec`` the squared norms are accumulated as
    per-shard partials and combined by one ordered (k,)-sized reduction —
    the only collective a norm-based selection rule needs."""
    from repro.core.shard_aggregation import blocked_partial_sum

    leaves = jax.tree.leaves(batch_means)
    k = leaves[0].shape[0]

    def _leaf_sq(l):
        lf = l.astype(jnp.float32)
        return jnp.sum(lf * lf, axis=tuple(range(1, lf.ndim)))

    return jnp.sqrt(blocked_partial_sum(shard_spec, leaves, _leaf_sq,
                                        shape=(k,), lead_axes=1))


@functools.partial(jax.jit, static_argnames=("max_iters",))
def geometric_median_jit(points, *, max_iters: int = 64):
    return geometric_median(points, max_iters=max_iters)

"""Robust gradient aggregation rules.

Every aggregator maps a *stacked* per-worker gradient pytree (leaves with a
leading worker axis ``m``) to a single gradient pytree (no leading axis).
All are pure jnp/lax so they jit and shard (the worker axis is sharded over
the mesh ``data`` axis; param dims over ``model`` — reductions become psums).

The paper's contribution is ``gmom`` (geometric median of means, Algorithm 2);
``mean`` is the paper's Algorithm 1 baseline (classical BGD).  The rest are
well-known robust baselines used for the comparison benchmarks:

* ``geomed``            — k = m special case (paper §2.1)
* ``trimmed_mean``      — coordinate-wise beta-trimmed mean [Yin et al. '18]
* ``coordinate_median`` — coordinate-wise median
* ``krum``              — Blanchard et al. '17 [BMGS17], the paper's closest
                          related work; selects the worker whose gradient has
                          the smallest sum of distances to its m-q-2 closest.
* ``norm_clip_mean``    — mean of norm-clipped gradients (practical baseline)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp


from repro.core.geometric_median import (
    batch_mean_norms, geometric_median, geometric_median_pytree, trim_weights)
from repro.core.grouping import Grouping, make_grouping

AggregatorFn = Callable[..., object]   # stacked pytree -> pytree

_REGISTRY: dict[str, "Aggregator"] = {}


@dataclasses.dataclass(frozen=True)
class Aggregator:
    name: str
    fn: AggregatorFn
    description: str = ""

    def __call__(self, stacked_grads, **kw):
        return self.fn(stacked_grads, **kw)


def register(name: str, description: str = ""):
    def deco(fn):
        _REGISTRY[name] = Aggregator(name=name, fn=fn, description=description)
        return fn
    return deco


def get_aggregator(name: str) -> Aggregator:
    if name not in _REGISTRY:
        raise KeyError(f"unknown aggregator {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available() -> list[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# helpers

def _num_workers(stacked) -> int:
    return jax.tree.leaves(stacked)[0].shape[0]


def bottom_k_mask(scores: jax.Array, k: int) -> jax.Array:
    """{0,1} float mask selecting exactly the k smallest-score entries.

    ``scores <= kth-smallest`` over-selects when values tie (e.g. colluding
    byzantine workers reporting identical gradients, or unlucky uniform
    draws); ranking via stable argsort breaks ties by index so exactly k
    entries are ever selected.
    """
    rank = jnp.argsort(jnp.argsort(scores))
    return (rank < k).astype(jnp.float32)


def _apply_grouping(stacked, grouping: Grouping):
    """Permute + reshape worker axis m -> (k, b) and mean over b."""
    perm = jnp.asarray(grouping.perm)
    k, b = grouping.num_batches, grouping.batch_size

    def leaf(g):
        g = jnp.take(g, jnp.argsort(perm), axis=0)  # order workers by slot
        g = g.reshape((k, b) + g.shape[1:])
        return jnp.mean(g, axis=1)

    return jax.tree.map(leaf, stacked)


def batch_means(stacked_grads, num_batches: int, *,
                scheme: str = "contiguous"):
    """Public helper: stacked (m, ...) pytree -> (k, ...) pytree of means."""
    m = _num_workers(stacked_grads)
    grouping = make_grouping(m, num_batches, scheme=scheme)
    return _apply_grouping(stacked_grads, grouping)


# ---------------------------------------------------------------------------
# aggregators

@register("mean", "plain average — the paper's Algorithm 1 (classical BGD)")
def mean_aggregator(stacked_grads, **_kw):
    return jax.tree.map(lambda g: jnp.mean(g, axis=0), stacked_grads)


@register("gmom", "geometric median of means — the paper's Algorithm 2")
def gmom_aggregator(stacked_grads, *, num_batches: int | None = None,
                    num_byzantine: int = 0, epsilon: float = 0.1,
                    grouping_scheme: str = "contiguous",
                    trim_multiplier: float | None = 3.0,
                    max_iters: int = 64, tol: float = 1e-8, **_kw):
    """Paper Algorithm 2 step 4: A_k(g) = med{batch means}, with the
    Remark-2 norm trimming applied as zero Weiszfeld weights."""
    m = _num_workers(stacked_grads)
    if num_batches is None:
        from repro.core.grouping import choose_num_batches
        num_batches = choose_num_batches(m, num_byzantine, epsilon=epsilon)
    if num_batches == 1:    # GMoM reduces to the mean (paper §2.1)
        return mean_aggregator(stacked_grads)
    means = batch_means(stacked_grads, num_batches, scheme=grouping_scheme)
    weights = None
    if trim_multiplier is not None:
        norms = batch_mean_norms(means)
        weights = trim_weights(norms, multiplier=trim_multiplier)
    return geometric_median_pytree(means, weights=weights,
                                      max_iters=max_iters, tol=tol)


@register("geomed", "geometric median of the raw worker gradients (k = m)")
def geomed_aggregator(stacked_grads, *, max_iters: int = 64,
                      tol: float = 1e-8, **_kw):
    return geometric_median_pytree(stacked_grads, max_iters=max_iters,
                                      tol=tol)


@register("coordinate_median", "coordinate-wise median baseline")
def coordinate_median_aggregator(stacked_grads, **_kw):
    return jax.tree.map(lambda g: jnp.median(g, axis=0), stacked_grads)


@register("trimmed_mean", "coordinate-wise beta-trimmed mean baseline")
def trimmed_mean_aggregator(stacked_grads, *, trim_fraction: float = 0.1,
                            num_byzantine: int | None = None, **_kw):
    m = _num_workers(stacked_grads)
    t = num_byzantine if num_byzantine is not None else int(trim_fraction * m)
    t = min(t, (m - 1) // 2)

    def leaf(g):
        s = jnp.sort(g, axis=0)
        if t > 0:
            s = s[t:m - t]
        return jnp.mean(s, axis=0)

    return jax.tree.map(leaf, stacked_grads)


@register("krum", "Krum selection rule [BMGS17] — related-work baseline")
def krum_aggregator(stacked_grads, *, num_byzantine: int = 0, **_kw):
    m = _num_workers(stacked_grads)
    # pairwise squared distances accumulated leaf-by-leaf (never flattens).
    d2 = jnp.zeros((m, m), jnp.float32)
    for g in jax.tree.leaves(stacked_grads):
        gf = g.reshape(m, -1).astype(jnp.float32)
        sq = jnp.sum(gf * gf, axis=1)
        d2 = d2 + (sq[:, None] + sq[None, :] - 2.0 * gf @ gf.T)
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, jnp.float32))
    # score(i) = sum of the m - q - 2 smallest distances to others
    closest = max(m - num_byzantine - 2, 1)
    sorted_d2 = jnp.sort(d2, axis=1)
    scores = jnp.sum(sorted_d2[:, :closest], axis=1)
    winner = jnp.argmin(scores)
    return jax.tree.map(lambda g: jnp.take(g, winner, axis=0), stacked_grads)


@register("norm_clip_mean", "mean of gradients clipped to the median norm")
def norm_clip_mean_aggregator(stacked_grads, *, clip_multiplier: float = 1.0,
                              **_kw):
    norms = batch_mean_norms(stacked_grads)            # (m,)
    tau = clip_multiplier * jnp.median(norms)
    scale = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-12))

    def leaf(g):
        s = scale.astype(g.dtype).reshape((-1,) + (1,) * (g.ndim - 1))
        return jnp.mean(g * s, axis=0)

    return jax.tree.map(leaf, stacked_grads)


# ---------------------------------------------------------------------------
# paper §6 (Discussion) future-work selection rules, implemented & answered
# empirically in benchmarks/selection_rules.py:
#   "A simple idea to defend against the relaxed Byzantine faults is to
#    select a subset of received gradients ... random selection ... or to
#    select the gradients of the small l2 norms."

@register("random_select",
          "paper §6 rule 1: average a random subset of the gradients "
          "(defends only the RELAXED adversary that cannot see the "
          "server's random bits — fails vs the paper's omniscient model)")
def random_select_aggregator(stacked_grads, *, key=None,
                             subset_fraction: float = 0.5, **_kw):
    m = _num_workers(stacked_grads)
    n_sel = max(int(subset_fraction * m), 1)
    if key is None:
        key = jax.random.PRNGKey(0)
    scores = jax.random.uniform(key, (m,))
    sel = bottom_k_mask(scores, n_sel)     # exactly n_sel, even under ties

    def leaf(g):
        s = sel.astype(g.dtype).reshape((-1,) + (1,) * (g.ndim - 1))
        return jnp.sum(g * s, axis=0) / jnp.asarray(n_sel, g.dtype)

    return jax.tree.map(leaf, stacked_grads)


@register("norm_select",
          "paper §6 rule 2: average the gradients with the smallest l2 "
          "norms (beats large-norm attacks; loses to small-norm "
          "inner-product manipulation — see benchmarks/selection_rules)")
def norm_select_aggregator(stacked_grads, *, num_byzantine: int = 0, **_kw):
    m = _num_workers(stacked_grads)
    keep = max(m - max(num_byzantine, 1), 1)
    norms = batch_mean_norms(stacked_grads)            # (m,)
    # colluders reporting identical gradients tie in norm — rank-select so
    # exactly ``keep`` gradients are ever averaged.
    sel = bottom_k_mask(norms, keep)

    def leaf(g):
        s = sel.astype(g.dtype).reshape((-1,) + (1,) * (g.ndim - 1))
        return jnp.sum(g * s, axis=0) / jnp.asarray(keep, g.dtype)

    return jax.tree.map(leaf, stacked_grads)


# ---------------------------------------------------------------------------
# per-leaf ("blockwise") GMoM — the beyond-paper perf variant (DESIGN.md §3)

@register("gmom_per_leaf",
          "GMoM applied independently per parameter tensor (beyond-paper)")
def gmom_per_leaf_aggregator(stacked_grads, *, num_batches: int | None = None,
                             num_byzantine: int = 0, epsilon: float = 0.1,
                             max_iters: int = 64, tol: float = 1e-8, **_kw):
    m = _num_workers(stacked_grads)
    if num_batches is None:
        from repro.core.grouping import choose_num_batches
        num_batches = choose_num_batches(m, num_byzantine, epsilon=epsilon)
    if num_batches == 1:
        return mean_aggregator(stacked_grads)
    means = batch_means(stacked_grads, num_batches)

    def leaf(z):
        k = z.shape[0]
        flat = z.reshape(k, -1)
        med = geometric_median(flat.astype(jnp.float32),
                                  max_iters=max_iters, tol=tol)
        return med.astype(z.dtype).reshape(z.shape[1:])

    return jax.tree.map(leaf, means)

"""Robust gradient aggregation rules.

Every aggregator maps a *stacked* per-worker gradient pytree (leaves with a
leading worker axis ``m``) to a single gradient pytree (no leading axis).
All are pure jnp/lax so they jit and shard (the worker axis is sharded over
the mesh ``data`` axis; param dims over ``model`` — reductions become psums).

The paper's contribution is ``gmom`` (geometric median of means, Algorithm 2);
``mean`` is the paper's Algorithm 1 baseline (classical BGD).  The rest are
well-known robust baselines used for the comparison benchmarks:

* ``geomed``            — k = m special case (paper §2.1)
* ``trimmed_mean``      — coordinate-wise beta-trimmed mean [Yin et al. '18]
* ``coordinate_median`` — coordinate-wise median
* ``krum``              — Blanchard et al. '17 [BMGS17], the paper's closest
                          related work; selects the worker whose gradient has
                          the smallest sum of distances to its m-q-2 closest.
* ``norm_clip_mean``    — mean of norm-clipped gradients (practical baseline)

The naive paper-§6 selection rules (``random_select``, ``norm_select``,
and the ``norm_clip_mean`` baseline) are KNOWN-UNSOUND under the adaptive
small-norm attacks; the **sound combined selection rules** close that gap
(see the section comment above their definitions):

* ``coord_median``       — coordinate-wise median of the k batch means
                           [Yin et al. '18]
* ``coord_trimmed_mean`` — coordinate-wise q-trimmed mean of the k batch
                           means [Yin et al. '18]
* ``norm_filter_gmom``   — two-sided norm-envelope filter (median ± c·MAD,
                           dropping huge AND adversarially-small outliers)
                           then GMoM on the survivors [Su & Xu '18]

The **communication-compressed rules** consume the wire formats of
``repro.core.compression`` natively (see their section comment):

* ``sign_sgd_majority``  — coordinate-wise majority vote over 1-bit sign
                           gradients [Jin et al. '19] — votes on the packed
                           uint8 wire directly
* ``int8_gmom``          — dequantize-then-GMoM on the 8-bit stochastic
                           wire (per-worker scales), reusing the full gmom
                           pipeline incl. ``round_backend`` dispatch

Every rule honors the **shard-local contract** (see
``repro.core.shard_aggregation``): coordinate-wise rules touch each
parameter shard independently (no cross-shard collectives at all), and the
norm-based rules take an optional ``shard_spec`` so their distance/norm
reductions combine per-shard partial squared norms — one (k,)-sized
reduction per Weiszfeld iterate for GMoM, one (m, m) partial distance
reduction for krum.  A partitioned spec also forces the ``reference``
round backend (the fused kernel's leaf concatenation would gather).

Every ``register(...)`` call carries a one-line description plus the
kwarg-dispatch flags (``needs_num_byzantine`` / ``needs_key`` /
``needs_grouping`` / ``needs_shard_spec``) that
``robust_train.aggregate_reported`` reads;
``describe()`` renders the registry as a markdown table (the one in
README.md), and ``scripts/check_docs.py`` fails CI when a registered name
is missing from ``docs/PAPER_MAP.md`` or has an empty description.

``gmom`` dispatches its hot path through ``round_backend``:

* ``"reference"``       — the original jnp pipeline (batch means -> Remark-2
                          trim -> pytree Weiszfeld).  Bit-stable: the golden
                          scenario traces are recorded on this path.
* ``"fused"``           — the Pallas round kernel
                          (``repro.kernels.geomed.round``): one HBM read of
                          the stacked gradients; means, trimming, and the
                          whole Weiszfeld loop stay VMEM-resident.
* ``"fused_interpret"`` — the same kernel in interpret mode (CPU tests).
* ``"auto"`` (default)  — ``fused`` on TPU backends, ``reference`` elsewhere;
                          also falls back to ``reference`` when the (k, d)
                          block exceeds the kernel's VMEM budget.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


from repro.core.geometric_median import (
    batch_mean_norms, geometric_median, geometric_median_pytree, trim_weights)
from repro.core.grouping import Grouping, make_grouping

# repro: robust-stat — reductions feeding the robust statistics below must
# accumulate in f32 before casting back (checked by repro.verify RV105).

AggregatorFn = Callable[..., object]   # stacked pytree -> pytree

_REGISTRY: dict[str, "Aggregator"] = {}

# The shard-local contract classes (see repro.core.shard_aggregation and
# docs/STATIC_ANALYSIS.md).  Every registered rule declares one; the Layer-B
# contract analyzer (repro.verify.contracts) traces the rule under a
# partitioned ShardSpec and statically verifies the lowered computation:
#
# * "coordinate_wise"  — touches each parameter shard independently: the
#                        lowered IR must contain ZERO cross-shard collectives;
# * "norm_based"       — may combine per-shard partials through small,
#                        d-independent reductions only ((k,)/(m,)/(m,m)
#                        shaped — the O(k)/O(m²) server-cost shape of
#                        PAPER.md §Thm 3);
# * "whole_gradient"   — selects a received gradient verbatim (krum): same
#                        collective allowance as norm_based (the (m,m)
#                        partial gram), selection itself is shard-local.
SHARD_CONTRACTS = ("coordinate_wise", "norm_based", "whole_gradient")

# The bounded-influence op families the Layer-C taint analysis
# (repro.verify.taint / docs/STATIC_ANALYSIS.md) recognizes on a
# report→output dataflow.  A rule that claims robustness declares WHICH
# family sanitizes the reports (its ``sanitization_point``); rules with no
# bounded path (the KNOWN-UNSOUND set) declare ``None``.  The analysis
# never reads the declaration while classifying — it rediscovers the
# family from the traced jaxpr and then *compares* (RV303), so a stale or
# aspirational declaration is itself a finding.
SANITIZATION_POINTS = ("clip", "order_stat", "rank_select", "sign_vote",
                       "weiszfeld")


@dataclasses.dataclass(frozen=True)
class Aggregator:
    """Registry entry: the aggregation fn plus the kwarg-dispatch metadata
    ``robust_train.aggregate_reported`` reads.  The flags replace the old
    hardcoded aggregator-name lists: a newly registered rule declares what
    it consumes and the engine threads it — no dispatch-site edits.

    * ``needs_num_byzantine`` — receives ``num_byzantine=cfg.num_byzantine``.
    * ``needs_key``           — receives a per-round PRNG ``key`` (randomized
                                rules; the paper's omniscient adversary sees
                                the same key).
    * ``needs_grouping``      — receives the full batching/median bundle:
                                ``num_batches``, ``epsilon``,
                                ``grouping_scheme``, ``trim_multiplier``,
                                ``max_iters``/``tol``, and ``round_backend``
                                (rules that don't consume a field swallow it
                                via ``**_kw``).
    * ``needs_shard_spec``    — receives the ``ShardSpec`` describing how
                                the stacked gradients are partitioned over
                                param shards (norm-based rules whose
                                reductions cross shards; coordinate-wise
                                rules are shard-local without one).

    ``shard_contract`` declares which collective footprint the rule is
    allowed to lower to under a partitioned ShardSpec (one of
    ``SHARD_CONTRACTS``); the Layer-B analyzer (``repro.verify.contracts``)
    traces the registered fn and rejects the registration when the lowered
    IR exceeds the declared class.  The default is ``"coordinate_wise"`` —
    deliberately the *strictest* class (zero collectives), so an
    undeclared contract can only ever fail the analyzer loudly, never
    silently grant a rule more communication than it admits to.

    ``native_codec`` names the wire format (``repro.core.compression``)
    the rule consumes directly: when ``RobustConfig.compression`` matches
    it, ``aggregate_reported`` skips the server-side decode and hands the
    rule the encoded payload plus a ``like=`` shape/dtype template
    (``sign_sgd_majority`` votes on packed sign bits; ``int8_gmom``
    dequantizes in-rule).  ``None`` means the rule only ever sees float
    gradients — any configured codec is decoded before dispatch.

    ``sanitization_point`` names the bounded-influence op family (one of
    ``SANITIZATION_POINTS``) through which every worker report must pass
    before reaching the rule's output — the channel PAPER.md §1.3 / Thm 3
    requires to be the ONLY one.  ``None`` = the rule admits unbounded
    per-worker influence (the KNOWN-UNSOUND set).  The Layer-C taint
    analysis (``repro.verify.taint``) verifies the declaration against the
    traced dataflow: RV301 fires when a raw report bypasses it, RV303
    when the declared family does not match the discovered one.
    """
    name: str
    fn: AggregatorFn
    description: str = ""
    needs_num_byzantine: bool = False
    needs_key: bool = False
    needs_grouping: bool = False
    needs_shard_spec: bool = False
    shard_contract: str = "coordinate_wise"
    native_codec: str | None = None
    sanitization_point: str | None = None

    def __call__(self, stacked_grads, **kw):
        return self.fn(stacked_grads, **kw)


def register(name: str, description: str = "", *,
             needs_num_byzantine: bool = False, needs_key: bool = False,
             needs_grouping: bool = False, needs_shard_spec: bool = False,
             shard_contract: str = "coordinate_wise",
             native_codec: str | None = None,
             sanitization_point: str | None = None):
    if shard_contract not in SHARD_CONTRACTS:
        raise ValueError(
            f"aggregator {name!r} declares unknown shard_contract "
            f"{shard_contract!r}; must be one of {SHARD_CONTRACTS}")
    if sanitization_point is not None and \
            sanitization_point not in SANITIZATION_POINTS:
        raise ValueError(
            f"aggregator {name!r} declares unknown sanitization_point "
            f"{sanitization_point!r}; must be None or one of "
            f"{SANITIZATION_POINTS}")
    def deco(fn):
        _REGISTRY[name] = Aggregator(
            name=name, fn=fn, description=description,
            needs_num_byzantine=needs_num_byzantine, needs_key=needs_key,
            needs_grouping=needs_grouping, needs_shard_spec=needs_shard_spec,
            shard_contract=shard_contract, native_codec=native_codec,
            sanitization_point=sanitization_point)
        return fn
    return deco


def get_aggregator(name: str) -> Aggregator:
    if name not in _REGISTRY:
        raise KeyError(f"unknown aggregator {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available() -> list[str]:
    return sorted(_REGISTRY)


def describe() -> list[tuple[str, str]]:
    """(name, description) rows for every registered aggregator, sorted."""
    return [(n, _REGISTRY[n].description) for n in available()]


def describe_markdown() -> str:
    """The registry as a markdown table — the source of the README table
    (kept honest by scripts/check_docs.py)."""
    rows = ["| aggregator | description |", "|---|---|"]
    rows += [f"| `{n}` | {d} |" for n, d in describe()]
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# helpers

def _num_workers(stacked) -> int:
    return jax.tree.leaves(stacked)[0].shape[0]


def bottom_k_mask(scores: jax.Array, k: int) -> jax.Array:
    """{0,1} float mask selecting exactly the k smallest-score entries.

    ``scores <= kth-smallest`` over-selects when values tie (e.g. colluding
    byzantine workers reporting identical gradients, or unlucky uniform
    draws); ranking via stable argsort breaks ties by index so exactly k
    entries are ever selected.
    """
    rank = jnp.argsort(jnp.argsort(scores))
    return (rank < k).astype(jnp.float32)


def _apply_grouping(stacked, grouping: Grouping):
    """Permute + reshape worker axis m -> (k, b) and mean over b.

    Both paths accumulate in f32 and cast back to the leaf dtype, so bf16
    batch means agree between k | m and k ∤ m groupings (beyond permutation
    effects) — previously the even path meant directly in the leaf dtype
    and diverged from the uneven f32 contraction.  Both paths are also
    shard-local: the reduction runs over the worker axis only, per
    coordinate, so partitioned gradient slices need no collectives here."""
    k = grouping.num_batches
    if k == grouping.num_workers and \
            grouping.perm == tuple(range(grouping.num_workers)):
        # identity grouping (k = m, contiguous): every report is its own
        # batch mean.  The group-mode production step lands here (its k
        # batch-group gradients ARE the means), so skip the no-op
        # gather/reshape/mean — a singleton-axis mean is bitwise the
        # identity, but lowers as avoidable data movement on sharded grads.
        return stacked
    if not grouping.is_even:
        from repro.core.grouping import assignment_matrix
        s = jnp.asarray(assignment_matrix(grouping))
        sizes = jnp.asarray(grouping.batch_sizes, jnp.float32)

        def leaf_uneven(g):
            # contraction over the worker axis only — no reshape(m, -1), so
            # a sharded trailing dim stays sharded (coordinate-local).
            sums = jnp.einsum("km,m...->k...", s, g.astype(jnp.float32))
            means = sums / sizes.reshape((k,) + (1,) * (g.ndim - 1))
            return means.astype(g.dtype)

        return jax.tree.map(leaf_uneven, stacked)

    perm = jnp.asarray(grouping.perm)
    b = grouping.batch_size

    def leaf(g):
        dt = g.dtype
        g = jnp.take(g, jnp.argsort(perm), axis=0)  # order workers by slot
        g = g.reshape((k, b) + g.shape[1:])
        return jnp.mean(g.astype(jnp.float32), axis=1).astype(dt)

    return jax.tree.map(leaf, stacked)


def batch_means(stacked_grads, num_batches: int, *,
                scheme: str = "contiguous"):
    """Public helper: stacked (m, ...) pytree -> (k, ...) pytree of means."""
    m = _num_workers(stacked_grads)
    grouping = make_grouping(m, num_batches, scheme=scheme)
    return _apply_grouping(stacked_grads, grouping)


# ---------------------------------------------------------------------------
# aggregators

@register("mean", "plain average — the paper's Algorithm 1 (classical BGD), "
          "breakdown point 0: one Byzantine worker moves it arbitrarily",
          shard_contract="coordinate_wise")
def mean_aggregator(stacked_grads, **_kw):
    """Paper Algorithm 1: simple averaging — the failure-free baseline,
    broken by a single Byzantine report (§1.3)."""
    def leaf(g):
        return jnp.mean(g.astype(jnp.float32), axis=0).astype(g.dtype)
    return jax.tree.map(leaf, stacked_grads)


def resolve_round_backend(round_backend: str | None, *, num_batches: int,
                          total_dim: int | None = None,
                          num_workers: int = 0,
                          target_backend: str | None = None,
                          partitioned: bool = False) -> str:
    """Map the ``round_backend`` switch to a concrete path.

    ``auto``/None picks the fused Pallas kernel on TPU backends and the
    reference jnp pipeline elsewhere.  ``target_backend`` names the backend
    the lowered program will RUN on; auto-dispatch keys off it instead of
    the host's ``jax.default_backend()``, so a dry-run sweep lowering TPU
    mesh programs from a CPU host resolves the production path (previously
    those sweeps silently recorded the host's ``reference`` path).

    ``partitioned`` gradients (a ShardSpec with num_shards > 1) force
    ``reference``: the fused round kernel concatenates every leaf into one
    (m, d) block, which on partitioned slices would mean the very gather
    the shard-local contract exists to avoid.  Explicit fused requests get
    a warning; auto falls back silently.

    When ``total_dim`` is known, any fused selection (auto or explicit)
    falls back to ``reference`` if the kernel's VMEM-resident footprint
    (``round.round_resident_bytes`` — the same formula the kernel's own
    guard uses) would blow its budget — silently for auto, with a warning
    for an explicit request."""
    if round_backend not in (None, "auto", "reference", "fused",
                             "fused_interpret"):
        raise ValueError(f"unknown round_backend {round_backend!r}")
    explicit = round_backend not in (None, "auto")
    if not explicit:
        if target_backend is None:
            import jax as _jax
            target_backend = _jax.default_backend()
        round_backend = "fused" if target_backend == "tpu" else "reference"
    if round_backend != "reference" and partitioned:
        if explicit:
            import warnings
            warnings.warn(
                f"round_backend={round_backend!r} requested but the stacked "
                "gradients are partitioned over param shards; the fused "
                "round kernel's leaf concatenation would gather them — "
                "using 'reference'", stacklevel=3)
        return "reference"
    if round_backend != "reference" and total_dim is not None:
        from repro.kernels.geomed import round as round_kernel
        if not round_kernel.fits_vmem(num_workers, num_batches, total_dim):
            if explicit:
                import warnings
                warnings.warn(
                    f"round_backend={round_backend!r} requested but the "
                    f"(k={num_batches}, d={total_dim}) block exceeds the "
                    "fused kernel's VMEM budget; using 'reference'",
                    stacklevel=3)
            return "reference"
    return round_backend


def _total_dim(stacked) -> int:
    return sum(int(np.prod(l.shape[1:], dtype=np.int64)) if l.ndim > 1 else 1
               for l in jax.tree.leaves(stacked))


@register("gmom", "geometric median of means — the paper's Algorithm 2 "
          "(fused Pallas round kernel on TPU, jnp reference elsewhere)",
          needs_num_byzantine=True, needs_grouping=True,
          needs_shard_spec=True, shard_contract="norm_based",
          sanitization_point="weiszfeld")
def gmom_aggregator(stacked_grads, *, num_batches: int | None = None,
                    num_byzantine: int = 0, epsilon: float = 0.1,
                    grouping_scheme: str = "contiguous",
                    trim_multiplier: float | None = 3.0,
                    max_iters: int = 64, tol: float = 1e-8,
                    round_backend: str | None = "auto",
                    shard_spec=None, **_kw):
    """Paper Algorithm 2 step 4: A_k(g) = med{batch means}, with the
    Remark-2 norm trimming applied as zero Weiszfeld weights.

    ``round_backend`` selects the hot-path lowering (see module docstring):
    the golden-trace-stable jnp ``reference`` pipeline, or the ``fused``
    Pallas round kernel that keeps means+trim+Weiszfeld VMEM-resident.
    A partitioned ``shard_spec`` forces ``reference`` (the kernel would
    gather) and routes every distance/norm reduction through
    :func:`repro.core.shard_aggregation.blocked_partial_sum` — one (k,)
    reduction per Weiszfeld iterate, nothing of size d ever crosses shards.
    """
    from repro.core import shard_aggregation as _sa
    m = _num_workers(stacked_grads)
    if num_batches is None:
        from repro.core.grouping import choose_num_batches
        num_batches = choose_num_batches(m, num_byzantine, epsilon=epsilon)
    if num_batches == 1:    # GMoM reduces to the mean (paper §2.1)
        return mean_aggregator(stacked_grads)
    backend = resolve_round_backend(
        round_backend, num_batches=num_batches,
        total_dim=_total_dim(stacked_grads), num_workers=m,
        target_backend=_sa.target_backend_of(shard_spec),
        partitioned=_sa.is_partitioned(shard_spec))
    if backend != "reference":
        from repro.kernels.geomed import round as round_kernel
        grouping = make_grouping(m, num_batches, scheme=grouping_scheme)
        return round_kernel.round_aggregate_pytree(
            stacked_grads, grouping, trim_multiplier=trim_multiplier,
            max_iters=max_iters, tol=tol,
            use_pallas=(backend == "fused"),
            interpret=(backend == "fused_interpret"))
    means = batch_means(stacked_grads, num_batches, scheme=grouping_scheme)
    weights = None
    if trim_multiplier is not None:
        norms = batch_mean_norms(means, shard_spec=shard_spec)
        weights = trim_weights(norms, multiplier=trim_multiplier)
    return geometric_median_pytree(means, weights=weights,
                                      max_iters=max_iters, tol=tol,
                                      shard_spec=shard_spec)


@register("geomed", "geometric median of the raw worker gradients — the "
          "k = m special case of GMoM (paper §2.1)",
          needs_shard_spec=True, shard_contract="norm_based",
          sanitization_point="weiszfeld")
def geomed_aggregator(stacked_grads, *, max_iters: int = 64,
                      tol: float = 1e-8, shard_spec=None, **_kw):
    """GMoM with every worker its own batch (k = m, paper §2.1): maximal
    robustness per report, no variance reduction from batching."""
    return geometric_median_pytree(stacked_grads, max_iters=max_iters,
                                      tol=tol, shard_spec=shard_spec)


@register("coordinate_median", "coordinate-wise median — the marginal-"
          "median baseline of Yin et al. '18",
          shard_contract="coordinate_wise",
          sanitization_point="order_stat")
def coordinate_median_aggregator(stacked_grads, **_kw):
    """Per-coordinate median across workers (the marginal median): robust
    per coordinate, but ignores cross-coordinate structure — the
    comparison point for the paper's *geometric* (joint) median."""
    return jax.tree.map(lambda g: jnp.median(g, axis=0), stacked_grads)


@register("trimmed_mean", "coordinate-wise beta-trimmed mean "
          "[Yin et al. '18] — related-work baseline",
          needs_num_byzantine=True, shard_contract="coordinate_wise",
          sanitization_point="order_stat")
def trimmed_mean_aggregator(stacked_grads, *, trim_fraction: float = 0.1,
                            num_byzantine: int | None = None, **_kw):
    """Coordinate-wise mean after discarding the t largest and t smallest
    entries per coordinate (t = num_byzantine, else trim_fraction x m) —
    Yin et al. 2018's order-optimal rule under its own q < m/2 condition."""
    m = _num_workers(stacked_grads)
    t = num_byzantine if num_byzantine is not None else int(trim_fraction * m)
    t = min(t, (m - 1) // 2)

    def leaf(g):
        s = jnp.sort(g, axis=0)
        if t > 0:
            s = s[t:m - t]
        return jnp.mean(s.astype(jnp.float32), axis=0).astype(g.dtype)

    return jax.tree.map(leaf, stacked_grads)


@register("krum", "Krum selection rule [BMGS17] — the paper's closest "
          "related work; picks one whole gradient via the shard-local "
          "‖a‖²+‖b‖²−2a·b gram expansion (no flattened f32 copies)",
          needs_num_byzantine=True, needs_shard_spec=True,
          shard_contract="whole_gradient",
          sanitization_point="rank_select")
def krum_aggregator(stacked_grads, *, num_byzantine: int = 0,
                    shard_spec=None, **_kw):
    """Krum (Blanchard et al. '17): return the single worker gradient with
    the smallest sum of squared distances to its m - q - 2 nearest
    neighbours.  Selects a *received* gradient verbatim rather than
    averaging — robust, but discards the variance reduction of honest
    averaging the paper's GMoM keeps.

    The pairwise distances come from the ‖a‖² + ‖b‖² − 2a·b expansion of
    one (m, m) gram matrix, accumulated per leaf *in place* via
    ``dot_general`` with an f32 accumulator — no ``reshape(m, -1)`` and no
    full-leaf f32 copy, so peak memory is the stacked gradients themselves
    plus O(m²).  Under a partitioned ``shard_spec`` the per-shard partial
    grams combine through ONE (m, m) blocked reduction — the only
    collective krum needs.

    Requires ``m > q + 2`` so every score sums at least one *other*
    worker's distance; below that the neighbourhood is degenerate and
    Krum's guarantee is void, so we raise rather than silently clamp
    (mirroring the loud-validation style of ``RobustConfig``'s
    q <= (m-1)/2 tolerance condition).
    """
    from repro.core.shard_aggregation import blocked_partial_sum
    m = _num_workers(stacked_grads)
    closest = m - num_byzantine - 2
    if closest < 1:
        raise ValueError(
            f"krum needs m > q + 2 workers (got m={m}, q={num_byzantine}): "
            "the m - q - 2 nearest-neighbour score is degenerate and the "
            "selection guarantee [BMGS17] is void")

    def leaf_gram(g):
        axes = tuple(range(1, g.ndim))
        return jax.lax.dot_general(
            g, g, dimension_numbers=((axes, axes), ((), ())),
            preferred_element_type=jnp.float32)

    gram = blocked_partial_sum(shard_spec, jax.tree.leaves(stacked_grads),
                               leaf_gram, shape=(m, m), lead_axes=1)
    sq = jnp.diagonal(gram)
    d2 = sq[:, None] + sq[None, :] - 2.0 * gram
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, jnp.float32))
    # score(i) = sum of the m - q - 2 smallest distances to others
    sorted_d2 = jnp.sort(d2, axis=1)
    scores = jnp.sum(sorted_d2[:, :closest], axis=1)
    winner = jnp.argmin(scores)
    return jax.tree.map(lambda g: jnp.take(g, winner, axis=0), stacked_grads)


@register("norm_clip_mean",
          "mean of gradients clipped to the median norm — KNOWN-UNSOUND "
          "vs small-norm attacks (alie, norm_stealth, inner_product)",
          needs_shard_spec=True, shard_contract="norm_based")
def norm_clip_mean_aggregator(stacked_grads, *, clip_multiplier: float = 1.0,
                              shard_spec=None, **_kw):
    """Mean of gradients clipped to ``clip_multiplier x median`` norm.

    .. warning:: **known-unsound vs. alie / norm_stealth.**  Clipping only
       bounds each report's *norm*; a coordinated small-norm attack (ALIE's
       mean - z.std report, norm_stealth hiding under the clip threshold,
       small-scale inner_product) passes through unclipped and biases the
       mean by O(q/m) per round — there is NO bounded-deviation guarantee.
       The defense matrix (tests/test_defense_matrix.py) deliberately
       excludes it from the ROBUST set; implementing the paper §6 combined
       selection rules against these adaptive attacks is an open ROADMAP
       item ("Defense gap found by the matrix tests").
    """
    norms = batch_mean_norms(stacked_grads, shard_spec=shard_spec)   # (m,)
    tau = clip_multiplier * jnp.median(norms)
    scale = jnp.minimum(1.0, tau / jnp.maximum(norms, 1e-12))

    def leaf(g):
        s = scale.reshape((-1,) + (1,) * (g.ndim - 1))
        return jnp.mean(g.astype(jnp.float32) * s, axis=0).astype(g.dtype)

    return jax.tree.map(leaf, stacked_grads)


# ---------------------------------------------------------------------------
# paper §6 (Discussion) future-work selection rules, implemented & answered
# empirically in benchmarks/selection_rules.py:
#   "A simple idea to defend against the relaxed Byzantine faults is to
#    select a subset of received gradients ... random selection ... or to
#    select the gradients of the small l2 norms."

@register("random_select",
          "paper §6 rule 1: average a random subset of the gradients "
          "(defends only the RELAXED adversary that cannot see the "
          "server's random bits — fails vs the paper's omniscient model)",
          needs_key=True, shard_contract="coordinate_wise")
def random_select_aggregator(stacked_grads, *, key=None,
                             subset_fraction: float = 0.5, **_kw):
    """Average a uniformly random subset (paper §6, rule 1).  Only defends
    the RELAXED adversary: the paper's omniscient model sees the server's
    random bits (our attacks receive the same ``key``), adapts, and wins —
    the §6 caveat the selection_rules benchmark demonstrates.

    ``key`` is required: the engine threads a fresh per-round key
    (``needs_key`` registry flag).  The old ``PRNGKey(0)`` fallback made
    the "random" subset deterministic and identical every round — a silent
    downgrade to a fixed selection rule — so a missing key now raises."""
    m = _num_workers(stacked_grads)
    n_sel = max(int(subset_fraction * m), 1)
    if key is None:
        raise ValueError(
            "random_select requires a PRNG key: without one the subset is "
            "identical every round (the aggregate_reported registry "
            "dispatch threads a fresh per-round key automatically)")
    scores = jax.random.uniform(key, (m,))
    sel = bottom_k_mask(scores, n_sel)     # exactly n_sel, even under ties

    def leaf(g):
        s = sel.reshape((-1,) + (1,) * (g.ndim - 1))
        acc = jnp.sum(g.astype(jnp.float32) * s, axis=0)
        return (acc / n_sel).astype(g.dtype)

    return jax.tree.map(leaf, stacked_grads)


@register("norm_select",
          "paper §6 rule 2: average the gradients with the smallest l2 "
          "norms — KNOWN-UNSOUND vs small-norm attacks (alie, "
          "norm_stealth); see benchmarks/selection_rules",
          needs_num_byzantine=True, needs_shard_spec=True,
          shard_contract="norm_based")
def norm_select_aggregator(stacked_grads, *, num_byzantine: int = 0,
                           shard_spec=None, **_kw):
    """Average the ``m - q`` smallest-norm gradients (paper §6, rule 2).

    .. warning:: **known-unsound vs. alie / norm_stealth.**  Selecting by
       small norm beats the classic large-norm attacks, but an adversary
       that *minimizes* its norm (ALIE, norm_stealth, small-scale
       inner_product) is preferentially SELECTED by this rule — its crafted
       rows rank below the honest ones and survive into the average, so the
       bounded-deviation property fails exactly on the attacks it is
       documented against in the defense matrix.  Excluded from ROBUST in
       tests/test_defense_matrix.py; the full fix (paper §6 combined
       selection rules) is a separate ROADMAP item.
    """
    m = _num_workers(stacked_grads)
    keep = max(m - max(num_byzantine, 1), 1)
    norms = batch_mean_norms(stacked_grads, shard_spec=shard_spec)   # (m,)
    # colluders reporting identical gradients tie in norm — rank-select so
    # exactly ``keep`` gradients are ever averaged.
    sel = bottom_k_mask(norms, keep)

    def leaf(g):
        s = sel.reshape((-1,) + (1,) * (g.ndim - 1))
        acc = jnp.sum(g.astype(jnp.float32) * s, axis=0)
        return (acc / keep).astype(g.dtype)

    return jax.tree.map(leaf, stacked_grads)


# ---------------------------------------------------------------------------
# SOUND combined selection rules — the paper §6 discussion made rigorous.
#
# PR 1's defense matrix proved the naive §6 selection rules above are NOT
# bounded under the adaptive small-norm attacks (alie / norm_stealth /
# inner_product): the adversary's crafted rows sit inside (or deliberately
# below) the honest norm envelope and survive one-sided selection or
# clipping.  The fix combines *filtering* with a rule that is itself
# robust, per the two natural ingredients from the related work:
#
# * coordinate-wise median / trimmed mean over the k BATCH MEANS
#   (Yin et al. '18, arXiv:1803.01498) — per-coordinate order statistics
#   over a fixed partition: at most q of k batches are contaminated, and
#   a per-coordinate median/trim over k values tolerates q < k/2 outliers
#   regardless of their norms;
# * a TWO-SIDED norm-envelope filter followed by GMoM (the filtering-style
#   combined rule of Su & Xu '18, arXiv:1804.10140): drop reports whose
#   norm deviates from the median norm by more than a MAD-scaled envelope
#   — both the classic huge-norm outliers AND the adversarially-small ones
#   (zero/stalling reports, small-scale inner_product) — then run the
#   paper's geometric-median-of-means on the survivors.  The filter only
#   ever *removes* outliers; boundedness never rests on it, because the
#   GMoM stage already tolerates q < k/2 contaminated batch means.
#
# All three are in the ROBUST set of tests/test_defense_matrix.py and the
# previously-skipped small-norm gap test asserts their bounded deviation.


@register("coord_median",
          "coordinate-wise median of the k batch means [Yin et al. '18] — "
          "sound combined rule: per-coordinate order statistics are immune "
          "to the small-norm attacks that break norm_select",
          needs_num_byzantine=True, needs_grouping=True,
          shard_contract="coordinate_wise",
          sanitization_point="order_stat")
def coord_median_aggregator(stacked_grads, *, num_batches: int | None = None,
                            num_byzantine: int = 0, epsilon: float = 0.1,
                            grouping_scheme: str = "contiguous", **_kw):
    """Coordinate-wise median over the k batch means (Yin et al. '18).

    Same batching discipline as ``gmom`` (fixed partition via
    ``core.grouping``, so at most q of k batch means are contaminated per
    round), but the median is marginal: each coordinate takes the median of
    its k batch-mean values.  A crafted report can only move a coordinate
    past the median by outnumbering the honest batches there — norm games
    (hiding under / ranking below the honest envelope) buy the adversary
    nothing, which is exactly the soundness the one-sided ``norm_select``
    lacks.

    Requires ``2q < k`` (the median's breakdown point): at q >= k/2 the
    contaminated batch means can straddle the median and drag it
    arbitrarily, so an out-of-guarantee configuration raises (same loud
    policy as ``coord_trimmed_mean`` / ``krum``) instead of silently
    emitting an adversary-dominated aggregate."""
    m = _num_workers(stacked_grads)
    if num_batches is None:
        from repro.core.grouping import choose_num_batches
        num_batches = choose_num_batches(m, num_byzantine, epsilon=epsilon)
    if 2 * num_byzantine >= num_batches:
        raise ValueError(
            f"coord_median needs 2q < k batches (got q={num_byzantine}, "
            f"k={num_batches}): the per-coordinate median's breakdown point "
            "is crossed and the Yin et al. '18 guarantee is void — "
            "increase num_batches or lower q")
    means = batch_means(stacked_grads, num_batches, scheme=grouping_scheme)
    return jax.tree.map(lambda z: jnp.median(z, axis=0), means)


@register("coord_trimmed_mean",
          "coordinate-wise q-trimmed mean of the k batch means "
          "[Yin et al. '18] — sound combined rule; trims the q largest AND "
          "q smallest per coordinate, unlike norm_select's one-sided cut",
          needs_num_byzantine=True, needs_grouping=True,
          shard_contract="coordinate_wise",
          sanitization_point="order_stat")
def coord_trimmed_mean_aggregator(stacked_grads, *,
                                  num_batches: int | None = None,
                                  num_byzantine: int = 0,
                                  epsilon: float = 0.1,
                                  grouping_scheme: str = "contiguous",
                                  trim_count: int | None = None, **_kw):
    """Coordinate-wise trimmed mean over the k batch means (Yin et al. '18,
    order-optimal under q < k/2).

    Per coordinate, sort the k batch-mean values and discard the t largest
    and t smallest before averaging, t = ``trim_count`` (default: q — the
    paper's fixed partition contaminates at most q batches per round).  The
    two-sided per-coordinate trim removes adversarial values wherever they
    sit — large, small, or sign-flipped — with no dependence on norms.

    Requires ``2t < k`` so at least one honest-majority value survives per
    coordinate; silently clamping t below the contamination level would
    emit an adversary-dominated aggregate while advertising ROBUST-set
    membership, so (like ``krum``'s degenerate-neighbourhood check) an
    out-of-guarantee configuration raises instead."""
    m = _num_workers(stacked_grads)
    if num_batches is None:
        from repro.core.grouping import choose_num_batches
        num_batches = choose_num_batches(m, num_byzantine, epsilon=epsilon)
    k = num_batches
    t = num_byzantine if trim_count is None else trim_count
    if t < 0 or 2 * t >= k:
        raise ValueError(
            f"coord_trimmed_mean needs 0 <= 2·trim_count < k batches (got "
            f"trim_count={t}, k={k}): trimming cannot cover q Byzantine "
            "batch means and the Yin et al. '18 guarantee is void — "
            "increase num_batches or lower q")
    means = batch_means(stacked_grads, k, scheme=grouping_scheme)

    def leaf(z):
        s = jnp.sort(z, axis=0)
        if t > 0:
            s = s[t:k - t]
        return jnp.mean(s.astype(jnp.float32), axis=0).astype(z.dtype)

    return jax.tree.map(leaf, means)


@register("norm_filter_gmom",
          "paper §6 combined rule [Su & Xu '18]: two-sided norm-envelope "
          "filter (drop reports whose norm sits outside median ± c·MAD — "
          "the huge AND the adversarially-small outliers), then GMoM on "
          "the surviving reports",
          needs_num_byzantine=True, needs_grouping=True,
          needs_shard_spec=True, shard_contract="norm_based",
          sanitization_point="weiszfeld")
def norm_filter_gmom_aggregator(stacked_grads, *,
                                num_batches: int | None = None,
                                num_byzantine: int = 0, epsilon: float = 0.1,
                                envelope_multiplier: float = 4.0,
                                grouping_scheme: str = "contiguous",
                                trim_multiplier: float | None = 3.0,
                                max_iters: int = 64, tol: float = 1e-8,
                                round_backend: str | None = "auto",
                                shard_spec=None, **_kw):
    """Two-sided norm filter -> geometric median of means (the §6
    "combined selection rule", in the filtering style of Su & Xu '18).

    Stage 1 — envelope filter: a report survives iff its l2 norm is within
    ``envelope_multiplier × MAD`` of the median report norm (MAD = median
    absolute deviation, a breakdown-point-1/2 spread estimate; a small
    relative slack keeps near-identical honest norms inside when the MAD
    underflows).  Unlike ``norm_select``'s bottom-k — which an adversary
    *minimizing* its norm is preferentially selected by — the envelope is
    two-sided: huge-norm attacks (sign_flip, mean_shift, noise) fall above
    it, adversarially-small reports (zero, shrunk inner_product) fall
    below.  Because at least half the reports sit within one MAD of the
    median by construction, at least ⌈m/2⌉ reports always survive.

    Stage 2 — GMoM on the survivors: each batch mean is re-averaged over
    its *surviving* members (a batch whose members were all filtered falls
    back to its unfiltered mean so shapes stay static), then the standard
    Remark-2 trim + Weiszfeld pipeline runs via :func:`gmom_aggregator` —
    including its ``round_backend`` dispatch, so the fused Pallas round
    kernel serves this rule on TPU unchanged.  The filter only ever drops
    outliers; boundedness under attacks that *survive* the envelope (alie,
    norm_stealth calibrated below the trim threshold, unit-scale
    inner_product) is inherited from the GMoM stage's q < k/2 median
    tolerance — this is what makes the combined rule sound where
    ``norm_select`` / ``norm_clip_mean`` are not.

    .. note:: with singleton batches (k = m, e.g. the group-mode production
       step where each batch-group gradient is its own report) every
       filtered report IS a fully-filtered batch, so the static-shape
       fallback makes stage 1 a structural no-op and the rule coincides
       with ``gmom`` (whose Remark-2 trim + median still provide the
       bounded-deviation guarantee).  The filter stage adds protection
       precisely when batches have >= 2 members: it restores the honest
       members' mean instead of letting one crafted report poison the
       whole batch mean.
    """
    m = _num_workers(stacked_grads)
    if num_batches is None:
        from repro.core.grouping import choose_num_batches
        num_batches = choose_num_batches(m, num_byzantine, epsilon=epsilon)
    k = num_batches
    norms = batch_mean_norms(stacked_grads, shard_spec=shard_spec)   # (m,)
    med = jnp.median(norms)
    mad = jnp.median(jnp.abs(norms - med))
    tau = envelope_multiplier * mad + 1e-3 * med + 1e-12
    keep = (jnp.abs(norms - med) <= tau).astype(jnp.float32)     # (m,)

    from repro.core.grouping import worker_batch_ids
    grouping = make_grouping(m, k, scheme=grouping_scheme)
    batch_id = jnp.asarray(worker_batch_ids(grouping))           # (m,) static
    sizes = jnp.asarray(grouping.batch_sizes, jnp.float32)       # (k,)
    counts = jax.ops.segment_sum(keep, batch_id, num_segments=k)  # (k,)
    # batch with every member filtered: fall back to its unfiltered mean
    keep_eff = jnp.where(counts[batch_id] > 0, keep, 1.0)
    counts_eff = jnp.where(counts > 0, counts, sizes)
    # Rescale rows so the UNWEIGHTED batch-mean machinery (reference
    # reshape-mean or the fused kernel's membership matmul / batch_sizes
    # division) yields the mean over the surviving members only:
    #   mean_l(g * r) = sum_{w in l, kept} g_w / count_l.
    rescale = keep_eff * sizes[batch_id] / counts_eff[batch_id]   # (m,)

    def leaf(g):
        r = rescale.astype(g.dtype).reshape((-1,) + (1,) * (g.ndim - 1))
        return g * r

    filtered = jax.tree.map(leaf, stacked_grads)
    return gmom_aggregator(filtered, num_batches=k,
                           num_byzantine=num_byzantine, epsilon=epsilon,
                           grouping_scheme=grouping_scheme,
                           trim_multiplier=trim_multiplier,
                           max_iters=max_iters, tol=tol,
                           round_backend=round_backend,
                           shard_spec=shard_spec)


# ---------------------------------------------------------------------------
# per-leaf ("blockwise") GMoM — the beyond-paper perf variant (DESIGN.md §3)

@register("gmom_per_leaf",
          "GMoM applied independently per parameter tensor — beyond-paper "
          "blockwise variant (DESIGN.md §3)",
          needs_num_byzantine=True, needs_grouping=True,
          needs_shard_spec=True, shard_contract="norm_based",
          sanitization_point="weiszfeld")
def gmom_per_leaf_aggregator(stacked_grads, *, num_batches: int | None = None,
                             num_byzantine: int = 0, epsilon: float = 0.1,
                             grouping_scheme: str = "contiguous",
                             max_iters: int = 64, tol: float = 1e-8,
                             shard_spec=None, **_kw):
    """Blockwise GMoM: one geometric median per parameter tensor instead of
    one in the concatenated R^d.  Cheaper to shard (medians run leaf-local)
    at the cost of the paper's joint-geometry guarantee holding only
    per block.

    Under a blocked ``shard_spec`` each leaf's median runs through the
    pytree Weiszfeld with blocked reductions (no ``reshape(k, -1)``, whose
    flatten would destroy the last-dim shard layout)."""
    m = _num_workers(stacked_grads)
    if num_batches is None:
        from repro.core.grouping import choose_num_batches
        num_batches = choose_num_batches(m, num_byzantine, epsilon=epsilon)
    if num_batches == 1:
        return mean_aggregator(stacked_grads)
    means = batch_means(stacked_grads, num_batches, scheme=grouping_scheme)

    if shard_spec is not None and shard_spec.blocked:
        def leaf_blocked(z):
            return geometric_median_pytree(
                {"x": z}, max_iters=max_iters, tol=tol,
                shard_spec=shard_spec)["x"]
        return jax.tree.map(leaf_blocked, means)

    def leaf(z):
        k = z.shape[0]
        flat = z.reshape(k, -1)
        med = geometric_median(flat.astype(jnp.float32),
                                  max_iters=max_iters, tol=tol)
        return med.astype(z.dtype).reshape(z.shape[1:])

    return jax.tree.map(leaf, means)


# ---------------------------------------------------------------------------
# communication-compressed rules (repro.core.compression)
#
# The paper's wire cost is O(md log N) bits per round (§1.4).  These two
# rules consume the compressed wire formats natively: when
# RobustConfig.compression matches the registered ``native_codec``,
# aggregate_reported hands them the encoded payload (plus a ``like=``
# shape/dtype template) instead of decoded floats.  With
# compression="none" they accept raw stacked gradients and behave
# identically — sign_sgd_majority votes on the raw signs, int8_gmom runs
# the plain gmom pipeline — so every existing harness (defense matrix,
# shard bitwise oracle, Layer B) covers them with no special casing.

@register("sign_sgd_majority",
          "coordinate-wise majority vote over 1-bit sign gradients "
          "[Jin et al. '19] — consumes the packed `sign` wire natively "
          "(votes on uint8 words, never reconstructs float gradients); "
          "shard-local with zero cross-shard collectives",
          shard_contract="coordinate_wise", native_codec="sign",
          sanitization_point="sign_vote")
def sign_sgd_majority_aggregator(stacked_grads, *, like=None, **_kw):
    """signSGD with majority vote (Jin et al. '19, arXiv 1902.10336):
    per coordinate, output −1 if a strict majority of the m reported sign
    bits are negative, else +1 (ties → +1).  Tolerant of q < m/2 blind
    sign-flippers; the vote-native ``sign_flip_targeted`` adversary breaks
    it exactly where the honest margin is ≤ 2q (the defense matrix pins
    that break point).

    The vote counting itself (exact integer sums over the worker axis)
    lives in ``repro.core.compression`` next to the packing code; both the
    raw and the packed entry points produce identical counts bit for bit.
    """
    from repro.core import compression
    if like is not None:
        return compression.majority_vote_packed(stacked_grads, like)
    return compression.majority_vote_signs(stacked_grads)


@register("int8_gmom",
          "GMoM on 8-bit stochastically-quantized reports: dequantizes the "
          "`int8_stochastic` wire (per-worker scales) then runs the full "
          "gmom pipeline incl. round_backend dispatch — 4× wire cut with "
          "the paper's Algorithm 2 guarantees on the dequantized reports",
          needs_num_byzantine=True, needs_grouping=True,
          needs_shard_spec=True, shard_contract="norm_based",
          native_codec="int8_stochastic",
          sanitization_point="weiszfeld")
def int8_gmom_aggregator(stacked_grads, *, like=None,
                         num_batches: int | None = None,
                         num_byzantine: int = 0, epsilon: float = 0.1,
                         grouping_scheme: str = "contiguous",
                         trim_multiplier: float | None = 3.0,
                         max_iters: int = 64, tol: float = 1e-8,
                         round_backend: str | None = "auto",
                         shard_spec=None, **_kw):
    """Dequantize-then-GMoM: the int8 payload (q values + per-worker
    scales) is expanded back to ``like``'s dtype in-rule, then the paper's
    Algorithm 2 pipeline runs unchanged — including the ``round_backend``
    dispatch to the fused Pallas round kernel and the shard-local blocked
    reductions.  With ``compression="none"`` (``like=None``) the reports
    arrive unquantized and this IS gmom."""
    if like is not None:
        from repro.core import compression
        stacked_grads = compression.get_codec("int8_stochastic").decode(
            stacked_grads, like)
    return gmom_aggregator(stacked_grads, num_batches=num_batches,
                           num_byzantine=num_byzantine, epsilon=epsilon,
                           grouping_scheme=grouping_scheme,
                           trim_multiplier=trim_multiplier,
                           max_iters=max_iters, tol=tol,
                           round_backend=round_backend,
                           shard_spec=shard_spec)

"""The full, checkpointable state of a multi-round Byzantine GD run.

The paper's convergence guarantee is a statement about ONE uninterrupted
trajectory of rounds under a (possibly stateful, history-dependent)
adversary.  Resuming from a params-only checkpoint breaks that trajectory:
the optimizer moments reset, the adversary's memory (e.g. the
``stealth_then_strike`` EMA/latch) resets, and the metrics trace restarts.
``TrainState`` packages *everything* the trajectory depends on so that an
interrupted-then-resumed run is bit-identical to an uninterrupted one:

    params        model/estimator parameters
    opt_state     optimizer state (repro.optim NamedTuples)
    attack_state  the schedule's carried adversary memory
                  (``AttackSchedule.init_state()`` pytree; ``()`` when
                  stateless — fixed structure, array leaves only)
    round_index   number of completed rounds (int32 scalar)
    base_key      the PRNG key handed to ``make_run_rounds``'s runner
                  (round t folds in t, so the key is constant across chunks)
    history       accumulated per-round metrics, dict[str, (round_index,)]
                  float32 arrays — byte-stable across save/restore
    stale_buffer  the bounded-staleness gradient buffer
                  (``repro.core.staleness.StalenessBuffer``; ``()`` when the
                  async path is disabled — fixed structure, array leaves,
                  int32 ages per repro.verify RV107)

Serialization goes through ``repro.checkpoint`` (format_version 2,
dtype-strict restore).  ``restore_train_state`` rebuilds the example pytree
for the history leaves from the checkpoint manifest, so callers only supply
example params/opt_state and the schedule.
"""

from __future__ import annotations

import re
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import byzantine

# repro.checkpoint (and its msgpack dependency) is imported lazily inside
# save/restore_train_state so that `import repro.core` keeps working in
# environments without the checkpoint extras.
TRAIN_STATE_PAYLOAD = "train_state"


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    attack_state: Any
    round_index: jax.Array
    base_key: jax.Array
    history: Any
    # () when the async path is disabled: a zero-leaf pytree adds nothing to
    # the checkpoint, so pre-staleness checkpoints restore unchanged.
    stale_buffer: Any = ()


def init_train_state(params, opt_state, base_key, *,
                     schedule: byzantine.AttackSchedule | None = None,
                     arrival=None) -> TrainState:
    """Round-zero state: fresh adversary memory, empty history, and —
    when an ``ArrivalSchedule`` is given — an empty staleness buffer."""
    attack_state = schedule.init_state() if schedule is not None else ()
    stale_buffer = ()
    if arrival is not None:
        from repro.core import staleness
        stale_buffer = staleness.init_buffer(
            params, arrival.num_workers, arrival.staleness_bound)
    return TrainState(params=params, opt_state=opt_state,
                      attack_state=attack_state,
                      round_index=jnp.zeros((), jnp.int32),
                      base_key=base_key, history={},
                      stale_buffer=stale_buffer)


def append_history(history, metrics) -> dict:
    """Concatenate a chunk's stacked per-round metrics onto ``history``.

    Metrics are stored float32 — exactly the dtype the scan emits — so a
    checkpoint round-trip reproduces ``float(v)`` bit-for-bit.
    """
    new = {k: np.asarray(v, np.float32) for k, v in metrics.items()}
    if not history:
        return new
    if set(history) != set(new):
        raise ValueError(
            f"metrics keys changed across chunks: {sorted(history)} vs "
            f"{sorted(new)}")
    return {k: np.concatenate([np.asarray(history[k], np.float32), new[k]])
            for k in new}


def history_rows(history) -> list[dict]:
    """The history as a list of per-round {metric: float} dicts (the
    launch-driver logging format)."""
    if not history:
        return []
    n = len(next(iter(history.values())))
    return [{k: float(v[j]) for k, v in history.items()} for j in range(n)]


def advance(run, state: TrainState, worker_batches, *, num_rounds=None,
            per_round_batches: bool = False) -> tuple[TrainState, dict]:
    """Run one chunk of rounds through a ``make_run_rounds`` runner.

    Returns ``(new_state, chunk_metrics)``; ``new_state.history`` has the
    chunk appended and ``round_index`` advanced, so chunked execution with a
    checkpoint at any chunk boundary replays bit-identically.
    """
    params, opt_state, attack_state, stale_buffer, metrics = run(
        state.params, state.opt_state, worker_batches, state.base_key,
        num_rounds=num_rounds, start_round=state.round_index,
        attack_state=state.attack_state, stale_buffer=state.stale_buffer,
        per_round_batches=per_round_batches)
    n = int(jax.tree.leaves(metrics)[0].shape[0])
    return TrainState(
        params=params, opt_state=opt_state, attack_state=attack_state,
        round_index=state.round_index + jnp.asarray(n, jnp.int32),
        base_key=state.base_key,
        history=append_history(state.history, metrics),
        stale_buffer=stale_buffer), metrics


def save_train_state(directory: str, state: TrainState, *,
                     keep: int | None = 3) -> str:
    """Checkpoint the full state under ``directory/step_<round_index>``."""
    from repro import checkpoint
    return checkpoint.save(directory, int(state.round_index), state,
                           keep=keep, payload=TRAIN_STATE_PAYLOAD)


_HISTORY_PATH = re.compile(r"^\.history/\['(.+)'\]$")


def _history_example(manifest: dict) -> dict:
    """Rebuild the history example pytree (keys/shapes/dtypes) from the
    checkpoint manifest — history length varies per checkpoint, so the
    caller cannot supply it."""
    out = {}
    for entry in manifest["leaves"]:
        match = _HISTORY_PATH.match(entry["path"])
        if match:
            out[match.group(1)] = np.zeros(
                tuple(entry["shape"]), dtype=entry["dtype"])
    return out


def restore_train_state(directory: str, step: int, example_params,
                        example_opt_state, *,
                        schedule: byzantine.AttackSchedule | None = None,
                        arrival=None,
                        allow_cast: bool = False,
                        manifest: dict | None = None) -> TrainState:
    """Dtype-strict restore of a TrainState checkpoint.

    Refuses checkpoints that do not hold a TrainState: legacy
    (format_version 1) params-only checkpoints AND bare pytrees saved
    through ``checkpoint.save`` without the ``train_state`` payload tag —
    restore those with ``repro.checkpoint.restore`` instead.  Pass a
    pre-read ``manifest`` to skip re-reading it from disk.  ``arrival``
    must match the saved run's arrival model: with one, the example carries
    an empty ``StalenessBuffer`` whose leaves the checkpoint fills; without
    one the ``stale_buffer`` slot is the empty pytree ``()`` (what every
    pre-staleness checkpoint holds).
    """
    from repro import checkpoint
    if manifest is None:
        manifest = checkpoint.read_manifest(directory, step)
    if manifest["format_version"] < 2:
        raise ValueError(
            f"checkpoint at {directory!r} step {step} is a legacy "
            "params-only checkpoint (format_version "
            f"{manifest['format_version']}); restore params with "
            "repro.checkpoint.restore instead")
    if manifest.get("payload") != TRAIN_STATE_PAYLOAD:
        raise ValueError(
            f"checkpoint at {directory!r} step {step} is not a TrainState "
            f"(payload={manifest.get('payload')!r}); it was saved as a "
            "bare pytree — restore it with repro.checkpoint.restore")
    example_buffer = ()
    if arrival is not None:
        from repro.core import staleness
        example_buffer = staleness.init_buffer(
            example_params, arrival.num_workers, arrival.staleness_bound)
    example = TrainState(
        params=example_params, opt_state=example_opt_state,
        attack_state=schedule.init_state() if schedule is not None else (),
        round_index=jnp.zeros((), jnp.int32),
        # shape/dtype placeholder only — the restored checkpoint supplies the
        # actual key bits.  A PRNGKey(0) literal here reads as a seed and
        # invites copy-paste into real seeding paths (the PR 5 random_select
        # bug class, repro.verify RV102); zeros of the raw key layout cannot.
        base_key=jnp.zeros((2,), jnp.uint32),
        history=_history_example(manifest),
        stale_buffer=example_buffer)
    return checkpoint.restore(directory, step, example,
                              allow_cast=allow_cast)

"""Gradient compression codecs for the worker → server report wire.

The paper's per-round communication cost is O(m·d·log N) bits: every worker
ships its full-precision gradient to the server (§1.4).  Jin et al.
(arXiv 1902.10336) show 1-bit sign gradients with a coordinate-wise
majority vote retain Byzantine tolerance, and stochastic int8 quantization
keeps the GMoM pipeline sound at 4× fewer bits.  This module is the codec
layer under ``robust_train.aggregate_reported``: workers *encode* their
stacked reports, the wire carries the payload, and the server either
*decodes* back to floats before a generic robust rule or — for an
aggregator whose ``native_codec`` matches (``sign_sgd_majority``) —
consumes the payload directly, never materializing float gradients at all.

Registered codecs:

* ``none``            — identity passthrough (the default wire).
* ``sign``            — 1 bit/coordinate: the IEEE sign bit of every
                        coordinate (``jnp.signbit``: −0.0 and negative
                        subnormals count as negative, +0.0 as positive),
                        packed LSB-first into uint8 words along each leaf's
                        LAST dim — the dim the shard-local contract
                        partitions, so per-shard slices pack locally with
                        no cross-shard data motion.  Deterministic and
                        dtype-independent: f32 and bf16 inputs with the
                        same sign pattern pack to identical bytes.
* ``int8_stochastic`` — 8 bits/coordinate + one f32 scale per (worker,
                        leaf): per-worker amax/127 scaling and PRNG-keyed
                        stochastic rounding, unbiased
                        (E[decode(encode(g))] = g) with worst-case
                        per-coordinate error strictly below one scale step.
                        Scales are per-WORKER precisely to close the
                        quantization-range attack: a shared scale would let
                        one Byzantine report inflate every honest worker's
                        quantization error.

This module deliberately carries no ``repro:`` robust-stat marker: every
reduction here is integer vote counting or an exact floating max — there is
no f32 statistic accumulation to protect (repro.verify RV105 guards the
robust statistics in ``aggregators.py``, which consume these helpers).

Shard-locality: packing, unpacking, and vote counting act on the last dim
only, so under a partitioned :class:`~repro.core.shard_aggregation.ShardSpec`
every codec runs on local slices.  The only cross-shard combine is
``int8_stochastic``'s per-worker (m,)-shaped amax — and max is exactly
associative, so the ``shard_map`` all_gather + ordered-maximum chain is
bitwise identical to the gathered ``jnp.max`` the ``virtual`` oracle and
the unsharded path compute.  Stochastic-rounding noise is keyed per
(leaf, shard) via ``fold_in``, so ``shard_map`` and ``virtual`` draw the
same bits slice for slice.  Codecs are stateless — no TrainState field —
so the PR 2 bit-exact resume contract holds with no checkpoint changes.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

EncodeFn = Callable[..., object]   # stacked pytree -> payload pytree
DecodeFn = Callable[..., object]   # (payload, like) -> stacked pytree

_REGISTRY: dict[str, "Codec"] = {}


@dataclasses.dataclass(frozen=True)
class Codec:
    """Registry entry for one wire format.

    * ``encode(stacked, key=None, shard_spec=None)`` maps the stacked
      per-worker gradient pytree to the wire payload.  ``key`` is required
      when ``needs_key`` (randomized codecs); ``shard_spec`` describes how
      leaf last dims are partitioned (see module docstring).
    * ``decode(payload, like)`` reconstructs a stacked pytree with the
      shapes/dtypes of ``like`` (``like`` may be a pytree of
      ``ShapeDtypeStruct``s — only ``.shape``/``.ndim``/``.dtype`` are
      read, so dry-run lowerings need no real gradients).
    * ``bits_per_coordinate`` is the nominal wire width (docs/benchmarks;
      the measured bytes in BENCH_pod_sweeps.json are the ground truth).
    """
    name: str
    description: str
    encode: EncodeFn
    decode: DecodeFn
    needs_key: bool = False
    bits_per_coordinate: float = 32.0


def register(name: str, description: str, *, encode: EncodeFn,
             decode: DecodeFn, needs_key: bool = False,
             bits_per_coordinate: float = 32.0) -> Codec:
    codec = Codec(name=name, description=description, encode=encode,
                  decode=decode, needs_key=needs_key,
                  bits_per_coordinate=bits_per_coordinate)
    _REGISTRY[name] = codec
    return codec


def get_codec(name: str) -> Codec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown codec {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available() -> list[str]:
    return sorted(_REGISTRY)


def describe() -> list[tuple[str, str]]:
    """(name, description) rows for every registered codec, sorted."""
    return [(n, _REGISTRY[n].description) for n in available()]


# ---------------------------------------------------------------------------
# sign: 1-bit packing of the last dim

def packed_words(d: int) -> int:
    """uint8 words needed for d sign bits (last-dim padding to 8)."""
    return -(-d // 8)


def _with_param_dim(leaf):
    """A stacked leaf with no param dims (shape (m,)) packs as (m, 1)."""
    return leaf[:, None] if leaf.ndim == 1 else leaf


def pack_signs(x):
    """Sign bits of ``x`` packed LSB-first into uint8 along the last dim.

    Bit 1 = negative per ``jnp.signbit`` (so −0.0 and negative subnormals
    are negative, +0.0 is positive).  The last dim is zero-padded to a
    multiple of 8; padding bits are 0.  Packing only the LAST dim keeps
    per-shard slices independently packable: each local slice pads its own
    tail, and per-coordinate sign recovery never crosses a word owned by
    another shard.
    """
    d = x.shape[-1]
    words = packed_words(d)
    bits = jnp.signbit(x).astype(jnp.uint8)
    pad = words * 8 - d
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(x.shape[:-1] + (pad,), jnp.uint8)], axis=-1)
    bits = bits.reshape(x.shape[:-1] + (words, 8))
    # unrolled OR chain: exact integer combine, no sum reduction at all
    word = bits[..., 0]
    for b in range(1, 8):
        word = word | (bits[..., b] << b)
    return word


def unpack_signs(packed, d: int):
    """Inverse of :func:`pack_signs`: (..., words) uint8 → (..., d) {0,1}."""
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (packed[..., None] >> shifts) & jnp.uint8(1)    # (..., words, 8)
    bits = bits.reshape(packed.shape[:-1] + (packed.shape[-1] * 8,))
    return bits[..., :d]


def _sign_encode(stacked, *, key=None, shard_spec=None):
    del key, shard_spec   # deterministic; packing is shard-local by design
    return {"packed": jax.tree.map(
        lambda g: pack_signs(_with_param_dim(g)), stacked)}


def _sign_decode(payload, like):
    def leaf(p, g):
        d = g.shape[-1] if g.ndim > 1 else 1
        bits = unpack_signs(p, d)
        signs = (1 - 2 * bits.astype(jnp.int8)).astype(g.dtype)
        return signs[..., 0] if g.ndim == 1 else signs
    return jax.tree.map(leaf, payload["packed"], like)


# ---------------------------------------------------------------------------
# coordinate-wise majority vote (the sign_sgd_majority server rule)
#
# Both entry points produce the identical per-coordinate negative-vote count
# (an exact int32 sum of {0,1}), so the raw path (compression="none") and
# the packed wire path (compression="sign") agree bit for bit.  Ties
# (2·n_neg == m) resolve to +1 in both.

def majority_vote_signs(stacked):
    """Vote directly on raw stacked reports: leaf (m, ...) → ±1 of (...)."""
    def leaf(g):
        m = g.shape[0]
        n_neg = jnp.sum(jnp.signbit(g).astype(jnp.int32), axis=0)
        return jnp.where(2 * n_neg > m, -1, 1).astype(g.dtype)
    return jax.tree.map(leaf, stacked)


def majority_vote_packed(payload, like):
    """Vote on the packed sign payload without reconstructing gradients."""
    def leaf(p, g):
        m = g.shape[0]
        d = g.shape[-1] if g.ndim > 1 else 1
        bits = unpack_signs(p, d)                           # (m, ..., d)
        n_neg = jnp.sum(bits.astype(jnp.int32), axis=0)     # (..., d)
        vote = jnp.where(2 * n_neg > m, -1, 1).astype(g.dtype)
        return vote[..., 0] if g.ndim == 1 else vote
    return jax.tree.map(leaf, payload["packed"], like)


# ---------------------------------------------------------------------------
# int8_stochastic: per-(worker, leaf) scale + PRNG-keyed stochastic rounding

def _chain_max(parts):
    """Ordered maximum over the leading axis — max is exactly associative,
    so this equals ``jnp.max(axis=0)`` bit for bit; the explicit chain keeps
    the expression tree identical between shard_map and virtual mode."""
    acc = parts[0]
    for i in range(1, parts.shape[0]):
        acc = jnp.maximum(acc, parts[i])
    return acc


def _int8_encode(stacked, *, key=None, shard_spec=None):
    if key is None:
        raise ValueError(
            "int8_stochastic requires a PRNG key for stochastic rounding "
            "(aggregate_reported threads a per-round key automatically)")
    from repro.core.shard_aggregation import shard_slice
    leaves, treedef = jax.tree.flatten(stacked)
    blocked = shard_spec is not None and shard_spec.blocked
    q_leaves, s_leaves = [], []
    for i, g in enumerate(leaves):
        kleaf = jax.random.fold_in(key, i)
        gf = g.astype(jnp.float32)
        # per the shard-local partitioning convention: leaves with param
        # dims are split on their last dim; (m,) leaves are replicated.
        sharded = blocked and g.ndim > 1
        axes = tuple(range(1, g.ndim))
        if g.ndim == 1:
            amax = jnp.abs(gf)                              # (m,)
        elif sharded and shard_spec.mode == "shard_map":
            local = jnp.max(jnp.abs(gf), axis=axes)         # (m,) local amax
            parts = jax.lax.all_gather(local, shard_spec.axis, axis=0)
            amax = _chain_max(parts)
        elif sharded and shard_spec.mode == "virtual":
            s = shard_spec.num_shards
            parts = jnp.stack([
                jnp.max(jnp.abs(shard_slice(gf, j, s)), axis=axes)
                for j in range(s)])
            amax = _chain_max(parts)
        else:
            amax = jnp.max(jnp.abs(gf), axis=axes)          # (m,)
        # explicit constant MULTIPLY, not ``amax / 127.0``: XLA
        # strength-reduces constant-divisor divisions into
        # reciprocal multiplies in some fusion contexts but not others
        # (observed: 1-ulp scale drift between the eager and the
        # shard_map lowering of this very line), while a constant
        # multiply is one exactly-rounded op in every context.
        scale = jnp.where(amax > 0.0, amax * (1.0 / 127.0), 1.0)   # (m,)
        sb = scale.reshape((-1,) + (1,) * (g.ndim - 1))
        y = gf / sb                                         # |y| <= 127
        if sharded and shard_spec.mode == "shard_map":
            u = jax.random.uniform(
                jax.random.fold_in(kleaf,
                                   jax.lax.axis_index(shard_spec.axis)),
                g.shape)
        elif sharded and shard_spec.mode == "virtual":
            s = shard_spec.num_shards
            u = jnp.concatenate([
                jax.random.uniform(
                    jax.random.fold_in(kleaf, j),
                    shard_slice(gf, j, s).shape)
                for j in range(s)], axis=-1)
        else:
            u = jax.random.uniform(jax.random.fold_in(kleaf, 0), g.shape)
        qv = jnp.clip(jnp.floor(y + u), -127.0, 127.0).astype(jnp.int8)
        q_leaves.append(qv)
        s_leaves.append(scale)
    return {"q": jax.tree.unflatten(treedef, q_leaves),
            "scale": jax.tree.unflatten(treedef, s_leaves)}


def _int8_decode(payload, like):
    def leaf(qv, s, g):
        sb = s.reshape((-1,) + (1,) * (qv.ndim - 1))
        return (qv.astype(jnp.float32) * sb).astype(g.dtype)
    return jax.tree.map(leaf, payload["q"], payload["scale"], like)


# ---------------------------------------------------------------------------
# none: identity passthrough

def _none_encode(stacked, *, key=None, shard_spec=None):
    del key, shard_spec
    return stacked


def _none_decode(payload, like):
    del like
    return payload


register("none",
         "identity passthrough — full-precision reports, the paper's "
         "O(md log N)-bit wire (§1.4)",
         encode=_none_encode, decode=_none_decode,
         bits_per_coordinate=32.0)

register("sign",
         "1-bit sign compression [Jin et al. '19]: the IEEE sign bit of "
         "every coordinate, packed LSB-first into uint8 words along each "
         "leaf's last (shard-partitioned) dim — deterministic and "
         "dtype-independent",
         encode=_sign_encode, decode=_sign_decode,
         bits_per_coordinate=1.0)

# Layer C note (repro.verify.taint): a codec's per-worker scales are
# derived FROM the reports inside the traced encode, so taint analysis
# marks them report-controlled by plain dataflow — a scale applied to
# anything but that same worker's row, or re-applied after aggregation,
# surfaces as RV301 without any codec-specific rule.
register("int8_stochastic",
         "8-bit stochastic quantization: per-(worker, leaf) amax/127 scale "
         "+ PRNG-keyed stochastic rounding — unbiased, worst-case "
         "per-coordinate error below one scale step; per-worker scales "
         "close the quantization-range attack",
         encode=_int8_encode, decode=_int8_decode, needs_key=True,
         bits_per_coordinate=8.0)

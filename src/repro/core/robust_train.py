"""Byzantine-robust distributed training step.

This is the paper's Algorithm 2 realized as a single jit/pjit-able SPMD
function (DESIGN.md §3-4):

    per-worker grads (vmap over the worker-sharded batch axis)
      -> simulated Byzantine corruption of reported gradients
      -> robust aggregation (GMoM by default)
      -> optimizer update

The worker axis is the mesh ``data`` axis (x ``pod`` on multi-pod meshes):
worker j's shard of the global batch is the paper's S_j, and GSPMD keeps
worker j's gradient on data-rank j because the stacked gradient's leading
axis is sharded over ``data``.

The same function covers the failure-free baseline (attack="none",
aggregator="mean" == paper Algorithm 1) so baseline and robust runs share
every other line of code.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import aggregators, byzantine
from repro.core.geometric_median import (
    batch_mean_norms, geometric_median_pytree, trim_weights)

# repro: train-scan — the multi-round scan carry below is the bit-exact
# resume surface: every carry element must be a TrainState field (PR 2
# checkpoint contract, repro.verify RV106).


@dataclasses.dataclass(frozen=True)
class RobustConfig:
    """Static configuration of the robust aggregation pipeline."""
    num_workers: int
    num_byzantine: int = 0
    num_batches: int | None = None      # None => paper's canonical choice
    aggregator: str = "gmom"
    attack: str = "none"
    attack_kwargs: tuple = ()           # tuple of (key, value) — hashable
    rotate_byzantine: bool = True
    epsilon: float = 0.1                # the paper's fixed eps in 2(1+eps)q<=k
    trim_multiplier: float | None = 3.0
    gmom_max_iters: int = 32
    gmom_tol: float = 1e-7
    grouping_scheme: str = "contiguous"
    # gmom hot-path lowering: "auto" (fused Pallas round kernel on TPU,
    # jnp reference elsewhere), "fused", "fused_interpret", or "reference".
    # The golden traces are recorded on the reference path.
    round_backend: str = "auto"
    # wire format of the worker -> server reports (repro.core.compression):
    # "none" (full precision), "sign" (1 bit/coordinate), or
    # "int8_stochastic".  The server decodes before aggregation unless the
    # aggregator's registered native_codec matches, in which case the rule
    # consumes the payload directly (sign_sgd_majority votes on packed
    # sign bits without ever reconstructing float gradients).
    compression: str = "none"
    # arrival model (repro.core.staleness): which workers deliver a fresh
    # report each round.  "all_sync" with staleness_bound=0 is the paper's
    # synchronous regime and compiles to the identical HLO (empty buffer
    # carry).  Any other setting threads a bounded-staleness buffer through
    # the scan: fresh reports merge with <=tau-stale buffered ones, rows
    # are discount**age-weighted, and age > tau rows are hard-dropped.
    # Semantics: docs/ASYNC.md.
    arrival: str = "all_sync"
    staleness_bound: int = 0
    staleness_discount: float = 0.7
    arrival_kwargs: tuple = ()          # tuple of (key, value) — hashable

    def resolved_num_batches(self) -> int:
        if self.num_batches is not None:
            return self.num_batches
        from repro.core.grouping import choose_num_batches
        return choose_num_batches(self.num_workers, self.num_byzantine,
                                  epsilon=self.epsilon)


def per_worker_grads(loss_fn: Callable, params, worker_batches, *,
                     loss_kwargs: dict | None = None):
    """Stacked gradients: leaf shapes (m, *param_shape).

    ``worker_batches`` is a pytree whose leaves have leading dim m (the worker
    axis).  vmap over that axis computes each worker's gradient from its own
    shard only — the SPMD realization of "machine j computes grad f̄^(j)".

    Returns (stacked_grads, per_worker_loss).
    """
    loss_kwargs = loss_kwargs or {}

    def one_worker(batch):
        return jax.value_and_grad(loss_fn)(params, batch, **loss_kwargs)

    losses, grads = jax.vmap(one_worker, in_axes=(0,))(worker_batches)
    return grads, losses


def aggregate_reported(reported_grads, cfg: RobustConfig, *, key,
                       shard_spec=None, staleness=None):
    """Robust aggregation of already-(possibly-)corrupted reports.

    Which config fields an aggregator receives is driven by its registry
    metadata (the ``needs_*`` flags on ``aggregators.register``), not by a
    hardcoded name list: a newly registered rule declares what it consumes
    and gets it threaded here without touching this dispatch site.  Rules
    take ``**_kw`` so a bundle field they don't consume is swallowed.

    ``shard_spec`` (a :class:`repro.core.shard_aggregation.ShardSpec`)
    describes how the stacked gradients are partitioned over param shards;
    it reaches every rule that registered ``needs_shard_spec`` (the
    norm-based rules whose reductions cross shards — coordinate-wise rules
    are shard-local without it).

    ``cfg.compression`` selects the wire format (repro.core.compression):
    reports are encoded worker-side, and the server decodes the payload
    back to a float pytree before aggregation — unless the aggregator's
    registered ``native_codec`` matches the configured codec, in which
    case the payload is passed straight through (with the original tree as
    the ``like=`` shape/dtype template) and the rule consumes the wire
    format directly.

    ``staleness`` is an ``(age, bound, discount)`` triple from the
    bounded-staleness buffer (repro.core.staleness): rows are rescaled by
    their normalized ``discount**age`` weights (exactly 1.0 when fresh,
    exactly 0.0 past the bound) BEFORE the wire codec sees them — the
    server weighs what it has, then encodes/aggregates as usual.

    This function is the Layer C trust boundary: ``reported_grads`` is
    ``report``-tainted (adversary-controlled end to end, including any
    wire payloads and codec scales derived from it downstream), and the
    RV301 invariant is that its influence exits this call only through
    the aggregator's declared sanitization point — nothing here may mix a
    report-derived value into the output after the rule runs (see
    repro.verify.taint and docs/STATIC_ANALYSIS.md).
    """
    agg = aggregators.get_aggregator(cfg.aggregator)
    kwargs: dict[str, Any] = {}
    if staleness is not None:
        from repro.core import staleness as staleness_lib
        age, bound, discount = staleness
        reported_grads = staleness_lib.apply_staleness(
            reported_grads, age, bound, discount=discount)
    if cfg.compression != "none":
        from repro.core import compression
        codec = compression.get_codec(cfg.compression)
        ckey = None
        if codec.needs_key:
            if key is None:
                raise ValueError(
                    f"compression {cfg.compression!r} needs a PRNG key")
            ckey = jax.random.fold_in(key, 29)
        payload = codec.encode(reported_grads, key=ckey,
                               shard_spec=shard_spec)
        if agg.native_codec == cfg.compression:
            kwargs.update(like=reported_grads)
            reported_grads = payload
        else:
            reported_grads = codec.decode(payload, reported_grads)
    if agg.needs_num_byzantine:
        kwargs.update(num_byzantine=cfg.num_byzantine)
    if agg.needs_key:
        # NOTE: the paper's adversary sees the server's random bits — and so
        # do our omniscient attacks (they receive the same ``key``): the
        # attacker can adapt, which is exactly the §6 caveat under test.
        kwargs.update(key=jax.random.fold_in(key, 13))
    if agg.needs_grouping:
        kwargs.update(num_batches=cfg.resolved_num_batches(),
                      epsilon=cfg.epsilon,
                      grouping_scheme=cfg.grouping_scheme,
                      trim_multiplier=cfg.trim_multiplier,
                      max_iters=cfg.gmom_max_iters, tol=cfg.gmom_tol,
                      round_backend=cfg.round_backend)
    if agg.needs_shard_spec and shard_spec is not None:
        kwargs.update(shard_spec=shard_spec)
    return agg(reported_grads, **kwargs)


def aggregate(stacked_grads, cfg: RobustConfig, *, key, round_index,
              shard_spec=None):
    """Attack simulation + robust aggregation.  Pure; jit-friendly."""
    mask = byzantine.sample_byzantine_mask(
        key, cfg.num_workers, cfg.num_byzantine,
        rotate=cfg.rotate_byzantine, round_index=round_index)
    attack = byzantine.get_attack(cfg.attack)
    attack_kwargs = dict(cfg.attack_kwargs)
    reported = attack(stacked_grads, mask, key, **attack_kwargs)
    return aggregate_reported(reported, cfg, key=key, shard_spec=shard_spec)


def make_robust_train_step(loss_fn: Callable, optimizer, cfg: RobustConfig, *,
                           loss_kwargs: dict | None = None,
                           donate: bool = False):
    """Build ``train_step(params, opt_state, worker_batches, key, round) ->
    (params, opt_state, metrics)``.

    ``optimizer`` follows the repro.optim interface: ``optimizer.update(
    grads, opt_state, params) -> (updates, opt_state)`` and params are
    updated by ``jax.tree.map(add)``.
    """

    def train_step(params, opt_state, worker_batches, key, round_index):
        stacked, losses = per_worker_grads(loss_fn, params, worker_batches,
                                           loss_kwargs=loss_kwargs)
        agg_grad = aggregate(stacked, cfg, key=key, round_index=round_index)
        updates, opt_state = optimizer.update(agg_grad, opt_state, params)
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                              params, updates)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(agg_grad)))
        metrics = {
            "loss_mean": jnp.mean(losses),
            # honest loss: mean over the workers that were *not* byzantine is
            # unknowable inside the step (mask is resampled) — report median
            # as a robust summary instead.
            "loss_median": jnp.median(losses),
            "agg_grad_norm": gnorm,
        }
        return params, opt_state, metrics

    return train_step


# ---------------------------------------------------------------------------
# scan-compiled multi-round training (the adversarial scenario substrate)

def schedule_from_config(cfg: RobustConfig) -> byzantine.AttackSchedule:
    """The AttackSchedule equivalent of the per-round ``aggregate`` path:
    rotating (or static) Byzantine set, fixed attack — so the scan runner
    reproduces the Python-loop trainer exactly."""
    name = "rotating" if cfg.rotate_byzantine else "static"
    return byzantine.make_schedule(
        name, num_workers=cfg.num_workers, num_byzantine=cfg.num_byzantine,
        attack=cfg.attack, attack_kwargs=cfg.attack_kwargs)


def make_run_rounds(loss_fn: Callable, optimizer, cfg: RobustConfig, *,
                    schedule: byzantine.AttackSchedule | None = None,
                    loss_kwargs: dict | None = None,
                    extra_metrics: Callable | None = None,
                    arrival=None):
    """Build a ``lax.scan``-compiled N-round trainer.

    Returns ``run(params, opt_state, worker_batches, key, *, num_rounds,
    start_round=0, attack_state=None, stale_buffer=None,
    per_round_batches=False) ->
    (params, opt_state, attack_state, stale_buffer, metrics)`` where
    ``metrics`` leaves are stacked over rounds.  All N rounds trace into ONE
    jitted scan whose carry is (params, opt_state, attack_state,
    stale_buffer) — a 50-round CPU scenario runs in seconds instead of N
    dispatches of a per-step jit.

    Round ``t`` uses ``jax.random.fold_in(key, t)`` as its step key, so the
    scan reproduces a Python loop over ``make_robust_train_step`` driven with
    the same per-round keys, step for step.

    With ``cfg.aggregator == "gmom"`` the per-round hot path (batch means ->
    Remark-2 trim -> Weiszfeld) dispatches through ``cfg.round_backend``: on
    TPU it is the fused Pallas round kernel
    (``repro.kernels.geomed.round.round_aggregate_kernel``) that keeps the
    whole pipeline VMEM-resident inside the scan body; elsewhere the
    golden-trace-stable jnp reference pipeline runs.

    * fixed-batch mode (default): ``worker_batches`` is the paper's full
      local data S_j, reused every round (Algorithm 1/2 exactly);
    * ``per_round_batches=True``: leaves carry a leading num_rounds axis and
      round t consumes slice t (the LM/streaming regime).

    ``schedule`` defaults to the RobustConfig-equivalent rotating/static
    schedule; pass any ``byzantine.AttackSchedule`` for multi-round
    adversaries (ramp-up, coordinated-switch, stealth-then-strike, ...).
    ``attack_state`` lets chunked callers (checkpoint boundaries) carry the
    adversary's memory across calls — prefer driving the runner through
    ``repro.core.train_state.advance``, which threads the whole
    (params, opt_state, attack_state, round, key, history, stale_buffer)
    TrainState and is what save/restore_train_state checkpoint.
    ``extra_metrics(params, agg_grad)`` appends scenario-specific metrics
    (e.g. estimation error vs true θ).

    ``arrival`` (a :class:`repro.core.staleness.ArrivalSchedule`, default
    ``staleness.arrival_from_config(cfg)``) turns on the bounded-staleness
    path: each round the arrival model picks the fresh reporters, stale
    workers contribute their buffered last report (age-discounted, dropped
    past τ), and the buffer joins the scan carry / ``stale_buffer``
    TrainState field.  When the arrival resolves to None (``all_sync``,
    τ=0) the carry slot is the empty pytree ``()`` and the compiled
    computation is unchanged — the synchronous path stays bit-identical.
    """
    schedule = schedule if schedule is not None else schedule_from_config(cfg)
    loss_kwargs = loss_kwargs or {}
    if arrival is None:
        from repro.core import staleness as staleness_lib
        arrival = staleness_lib.arrival_from_config(cfg)

    def _run(params, opt_state, worker_batches, key, attack_state,
             stale_buffer, num_rounds, start_round, per_round_batches):
        if attack_state is None:
            attack_state = schedule.init_state()
        if arrival is None:
            stale_buffer = ()
        elif stale_buffer is None:
            from repro.core import staleness as staleness_lib
            stale_buffer = staleness_lib.init_buffer(
                params, arrival.num_workers, arrival.staleness_bound)
        rounds = start_round + jnp.arange(num_rounds)

        def body(carry, xs):
            params, opt_state, astate, stale_buffer = carry
            if per_round_batches:
                t, batch = xs
            else:
                t, batch = xs, worker_batches
            key_t = jax.random.fold_in(key, t)
            stacked, losses = per_worker_grads(loss_fn, params, batch,
                                               loss_kwargs=loss_kwargs)
            reported, mask, astate = schedule.apply(stacked, key_t, t, astate)
            if arrival is None:
                agg_grad = aggregate_reported(reported, cfg, key=key_t)
            else:
                from repro.core import staleness as staleness_lib
                fresh = arrival.arrive(key_t, t, mask)
                reported, stale_buffer = staleness_lib.merge_reports(
                    stale_buffer, reported, fresh)
                agg_grad = aggregate_reported(
                    reported, cfg, key=key_t,
                    staleness=(stale_buffer.age, stale_buffer.bound,
                               cfg.staleness_discount))
            updates, opt_state = optimizer.update(agg_grad, opt_state, params)
            params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                                  params, updates)
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(agg_grad)))
            metrics = {
                "loss_mean": jnp.mean(losses),
                "loss_median": jnp.median(losses),
                "agg_grad_norm": gnorm,
                "byz_count": jnp.sum(mask.astype(jnp.int32)),
            }
            if arrival is not None:
                metrics["stale_count"] = jnp.sum(
                    (stale_buffer.age > 0).astype(jnp.int32))
            if extra_metrics is not None:
                metrics.update(extra_metrics(params, agg_grad))
            return (params, opt_state, astate, stale_buffer), metrics

        xs = (rounds, worker_batches) if per_round_batches else rounds
        carry, metrics = jax.lax.scan(
            body, (params, opt_state, attack_state, stale_buffer), xs)
        params, opt_state, attack_state, stale_buffer = carry
        return params, opt_state, attack_state, stale_buffer, metrics

    # start_round stays dynamic so chunked callers (checkpoint boundaries)
    # don't recompile per chunk.
    jitted = jax.jit(_run, static_argnames=("num_rounds",
                                            "per_round_batches"))

    def run(params, opt_state, worker_batches, key, *, num_rounds=None,
            start_round=0, attack_state=None, stale_buffer=None,
            per_round_batches=False):
        if num_rounds is None:
            if not per_round_batches:
                raise ValueError("num_rounds is required with a fixed batch")
            num_rounds = jax.tree.leaves(worker_batches)[0].shape[0]
        if isinstance(stale_buffer, tuple) and stale_buffer == ():
            # the disabled-path TrainState default — _run re-derives it
            stale_buffer = None
        return jitted(params, opt_state, worker_batches, key, attack_state,
                      stale_buffer, num_rounds, start_round,
                      per_round_batches)

    return run


# ---------------------------------------------------------------------------
# beyond-paper: explicit shard_map collective schedule (see EXPERIMENTS §Perf)

def make_shardmap_aggregate(cfg: RobustConfig, mesh, worker_axes=("data",)):
    """GMoM with a hand-written collective schedule under shard_map.

    Baseline GSPMD lowering of ``aggregate`` all-gathers the stacked gradient
    over ``data`` before the batch-mean reshape.  The hand schedule instead:

      1. psum the gradients *within* each batch subgroup via one
         all-reduce over the worker axis with a batch-block mask — realized
         as all_gather of batch-mean partial sums only (k×shard, not m×shard);
      2. runs the trim + Weiszfeld tail on the k means locally (replicated
         over data), dispatched through ``cfg.round_backend`` exactly like
         ``gmom_aggregator``: the fused Pallas round kernel
         (``repro.kernels.geomed.round``) keeps the (k, d) block
         VMEM-resident on TPU; the jnp reference pipeline runs elsewhere
         (and whenever the block exceeds the kernel's VMEM budget).
         Because step 1 already produced the means, the kernel is invoked
         with the k = m identity grouping — its membership matmul is the
         identity and only the resident trim + Weiszfeld stages do work.

    Requires the worker axis size to equal cfg.num_workers and contiguous
    grouping.  Returns ``fn(stacked_local_grads) -> agg_grad`` to be called
    inside shard_map (worker axis unstacked: each rank passes its own grad).
    """
    from jax.experimental.shard_map import shard_map  # noqa: F401
    k = cfg.resolved_num_batches()
    m = cfg.num_workers
    if m % k != 0:
        # The one-hot psum below assumes the even contiguous partition
        # (batch_id = idx // b with a single b); an uneven grouping would
        # silently drop workers idx >= k*b and mis-scale every mean.
        # Uneven k (paper's m=50, k=11) is supported by the gmom/fused
        # round path, not by this hand-scheduled collective yet.
        raise ValueError(
            f"make_shardmap_aggregate requires k | m (got m={m}, k={k}); "
            "use the gmom aggregator path for uneven groupings")
    b = m // k

    def agg_local(my_grad):
        """Runs per-rank inside shard_map; my_grad has no worker axis."""
        axis = worker_axes[0] if len(worker_axes) == 1 else worker_axes
        # worker index along the (possibly multi-) worker axis
        if isinstance(axis, tuple):
            idx = jax.lax.axis_index(axis[0]) * jax.lax.axis_size(axis[1]) \
                + jax.lax.axis_index(axis[1])
        else:
            idx = jax.lax.axis_index(axis)
        batch_id = idx // b

        def leaf(g):
            # one-hot partial contribution to each batch mean, then a single
            # all-reduce produces all k batch means replicated on every rank.
            onehot = (jnp.arange(k) == batch_id).astype(g.dtype) / b
            contrib = jnp.einsum("k,...->k...", onehot, g)
            return jax.lax.psum(contrib, axis_name=axis)

        means = jax.tree.map(leaf, my_grad)
        backend = aggregators.resolve_round_backend(
            cfg.round_backend, num_batches=k,
            total_dim=aggregators._total_dim(means), num_workers=k)
        if backend != "reference":
            from repro.core.grouping import make_grouping
            from repro.kernels.geomed import round as round_kernel
            return round_kernel.round_aggregate_pytree(
                means, make_grouping(k, k),
                trim_multiplier=cfg.trim_multiplier,
                max_iters=cfg.gmom_max_iters, tol=cfg.gmom_tol,
                use_pallas=(backend == "fused"),
                interpret=(backend == "fused_interpret"))
        weights = None
        if cfg.trim_multiplier is not None:
            norms = batch_mean_norms(means)
            weights = trim_weights(norms, multiplier=cfg.trim_multiplier)
        return geometric_median_pytree(
            means, weights=weights, max_iters=cfg.gmom_max_iters,
            tol=cfg.gmom_tol)

    return agg_local


def make_sharded_aggregate(cfg: RobustConfig, mesh=None, *,
                           axis: str = "model",
                           num_shards: int | None = None):
    """Shard-LOCAL aggregation body for code running inside ``shard_map``
    with the stacked gradients partitioned over ``axis`` (the ZeRO-1 layout:
    each device holds every worker's slice of its param shard).

    Complements :func:`make_shardmap_aggregate`, which hand-schedules the
    *data*-axis collectives for gmom only; this one covers EVERY registered
    rule over the *model* axis via the blocked-reduction contract
    (``repro.core.shard_aggregation``): coordinate-wise rules run with no
    collectives at all, norm-based rules all-reduce per-shard partial
    squared norms.  The result is bit-identical to the ``"virtual"``-mode
    single-device oracle on the gathered gradients — the testable form of
    "sharded and gathered aggregation agree exactly"
    (tests/test_shardmap_aggregate.py).

    Returns ``fn(stacked_local_grads, key) -> agg_grad_shard`` where each
    leaf of ``stacked_local_grads`` is the local LAST-dim slice (leading
    worker axis intact) and the returned aggregate is likewise the local
    shard.
    """
    if num_shards is None:
        if mesh is None:
            raise ValueError("make_sharded_aggregate needs a mesh or an "
                             "explicit num_shards")
        num_shards = mesh.shape[axis]
    from repro.core.shard_aggregation import ShardSpec
    spec = ShardSpec(num_shards=num_shards, mode="shard_map", axis=axis)

    def agg_local(stacked_local, key):
        return aggregate_reported(stacked_local, cfg, key=key,
                                  shard_spec=spec)

    return agg_local

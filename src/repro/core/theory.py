"""Closed-form quantities from the paper's theory.

Used by tests and benchmarks to check the implementation against the paper's
own claims (convergence rate, error floor, tolerance region) rather than
against ad-hoc numbers.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class ProblemConstants:
    """Assumption 1 constants of the population risk F."""
    strong_convexity: float      # L
    lipschitz_gradient: float    # M

    @property
    def step_size(self) -> float:
        """The paper's canonical eta = L / (2 M^2)."""
        L, M = self.strong_convexity, self.lipschitz_gradient
        return L / (2.0 * M * M)

    @property
    def population_contraction(self) -> float:
        """Per-step factor of exact population GD (Lemma 3):
        sqrt(1 - L^2/(4 M^2))."""
        L, M = self.strong_convexity, self.lipschitz_gradient
        return math.sqrt(1.0 - L * L / (4.0 * M * M))

    @property
    def theorem1_contraction(self) -> float:
        """Theorem 1/5 rate: 1/2 + 1/2 sqrt(1 - L^2/4M^2)."""
        return 0.5 + 0.5 * self.population_contraction

    def rho(self, xi2: float) -> float:
        """Lemma 4's rho = 1 - sqrt(1-L^2/4M^2) - xi2 L/(2M^2)."""
        return 1.0 - self.population_contraction - xi2 * self.step_size


# Linear regression (paper §4): F(theta)=0.5||theta-theta*||^2 + 0.5
LINEAR_REGRESSION = ProblemConstants(strong_convexity=1.0,
                                     lipschitz_gradient=1.0)
# => eta = 1/2, contraction 1/2 + sqrt(3)/4 ≈ 0.933 (Corollary 1).


def c_alpha(alpha: float) -> float:
    """Lemma 1's C_alpha = 2(1-alpha)/(1-2alpha)."""
    if not 0.0 <= alpha < 0.5:
        raise ValueError("alpha must be in [0, 1/2)")
    return 2.0 * (1.0 - alpha) / (1.0 - 2.0 * alpha)


def tolerance_ok(num_workers: int, num_batches: int, num_byzantine: int, *,
                 epsilon: float = 0.1) -> bool:
    """Tolerance condition 2(1+eps) q <= k <= m (Theorem 1)."""
    return (2.0 * (1.0 + epsilon) * num_byzantine <= num_batches
            <= num_workers)


def error_floor(dim: int, total_samples: int, num_batches: int, *,
                alpha: float = 0.3, c2: float = 1.0) -> float:
    """Theorem 5 floor  c2 * C_alpha * sqrt(d k / N)  (up to the universal
    constant c2, which benchmarks fit empirically)."""
    return c2 * c_alpha(alpha) * math.sqrt(dim * num_batches / total_samples)


def binary_divergence(p: float, q: float) -> float:
    """D(p || q) for Bernoulli — appears in the success probability bound."""
    if p in (0.0, 1.0):
        return (math.log(1.0 / q) if p == 1.0 else math.log(1.0 / (1.0 - q)))
    return p * math.log(p / q) + (1 - p) * math.log((1 - p) / (1 - q))


def success_probability_lower_bound(num_batches: int, num_byzantine: int,
                                    alpha: float, delta: float) -> float:
    """1 - exp(-k D(alpha - q/k || delta)) from Theorem 1."""
    gap = alpha - num_byzantine / num_batches
    if gap <= delta:
        return 0.0
    return 1.0 - math.exp(-num_batches * binary_divergence(gap, delta))

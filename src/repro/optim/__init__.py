from repro.optim.optimizers import (  # noqa: F401
    AdamState,
    Optimizer,
    SGDState,
    adamw,
    paper_gd,
    sgd,
)
from repro.optim import schedule  # noqa: F401

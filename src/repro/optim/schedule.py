"""Learning-rate schedules as pure ``step -> lr`` functions."""

from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, *, warmup_steps: int, total_steps: int,
                  final_fraction: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        progress = jnp.clip((step - warmup_steps)
                            / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_fraction + (1 - final_fraction) * 0.5 * (
            1.0 + jnp.cos(jnp.pi * progress))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)
    return sched


def inverse_sqrt(peak_lr: float, *, warmup_steps: int = 100):
    def sched(step):
        step = jnp.maximum(step.astype(jnp.float32), 1.0)
        return peak_lr * jnp.minimum(
            step / max(warmup_steps, 1),
            jnp.sqrt(warmup_steps / step))
    return sched

"""Pure-pytree optimizers (no external deps).

Interface (optax-like, minimal):

    opt = sgd(lr)                        # or adamw(...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = jax.tree.map(lambda p, u: p + u, params, updates)

The paper's algorithm is plain GD with fixed step eta = L/(2M^2); ``sgd``
with a constant schedule is the paper-faithful choice.  AdamW is provided for
the LM-scale substrate (and is what the assigned-architecture configs use).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]   # step -> lr


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]


class SGDState(NamedTuple):
    step: jax.Array
    momentum: Any   # pytree or () when momentum == 0


def sgd(learning_rate, *, momentum: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    """Plain (momentum) SGD.  momentum=0 == the paper's gradient descent."""
    sched = _as_schedule(learning_rate)

    def init(params):
        mom = (jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
               if momentum else ())
        return SGDState(step=jnp.zeros((), jnp.int32), momentum=mom)

    def update(grads, state: SGDState, params=None):
        del params
        lr = sched(state.step)
        if momentum:
            new_mom = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                state.momentum, grads)
            vec = (jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32),
                new_mom, grads) if nesterov else new_mom)
            updates = jax.tree.map(lambda v: (-lr * v), vec)
            return updates, SGDState(step=state.step + 1, momentum=new_mom)
        updates = jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads)
        return updates, SGDState(step=state.step + 1, momentum=())

    return Optimizer(name="sgd", init=init, update=update)


class AdamState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def adamw(learning_rate, *, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          grad_clip_norm: float | None = None) -> Optimizer:
    """AdamW with optional global-norm gradient clipping.

    The moments are f32 regardless of param dtype (mixed-precision practice:
    bf16 params, f32 optimizer state)."""
    sched = _as_schedule(learning_rate)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return AdamState(step=jnp.zeros((), jnp.int32),
                         mu=jax.tree.map(zeros, params),
                         nu=jax.tree.map(zeros, params))

    def update(grads, state: AdamState, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip_norm is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        count = state.step + 1
        lr = sched(state.step)
        bc1 = 1.0 - b1 ** count.astype(jnp.float32)
        bc2 = 1.0 - b2 ** count.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                          state.nu, grads)

        def upd(m, v, p):
            mhat = m / bc1
            vhat = v / bc2
            step = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(jnp.float32)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, AdamState(step=count, mu=mu, nu=nu)

    return Optimizer(name="adamw", init=init, update=update)


def paper_gd(problem_constants) -> Optimizer:
    """The paper's fixed-step GD: eta = L / (2 M^2) (Theorem 1)."""
    return sgd(problem_constants.step_size)

"""Production-scale train / serve steps and their abstract input specs.

Two gradient paths implement the paper's Algorithm 2 (DESIGN.md §4):

* **worker mode** (repro.core.robust_train) — one gradient per worker,
  attack at worker granularity.  Faithful to the paper line-by-line; used at
  experiment scale (the stacked (m, P) gradients are the paper server's
  O(md) memory, impossible at 72B+).
* **group mode** (here) — gradients computed directly per batch-group:
  mean-of-means == pooled mean, so the k honest batch means are identical to
  worker mode's (tests assert this), while peak memory drops from (m, P) to
  (k, P) with the 2D param layout preserved.  Byzantine corruption is
  injected at batch-mean granularity — exactly the quantity the analysis
  bounds (at most q of k batches contaminated).  This is the path the
  512-chip dry-run and the multi-pod scenario sweep (repro.sim.sweep)
  lower; aggregation dispatches through the registry
  (robust_train.aggregate_reported), so rc.aggregator / rc.round_backend /
  an optional AttackSchedule are all first-class here.

``input_specs`` provides ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_shape, long_context_variant
from repro.configs.base import InputShape, ModelConfig
from repro.core import RobustConfig, byzantine
from repro.core.robust_train import aggregate_reported
from repro.models import model as model_lib


# ---------------------------------------------------------------------------
# batch construction

def train_batch_struct(cfg: ModelConfig, shape: InputShape, num_groups: int):
    """Abstract train batch: leaves (k, B/k, ...)."""
    k = num_groups
    if shape.global_batch % k != 0:
        raise ValueError(f"global_batch={shape.global_batch} % k={k} != 0")
    bg = shape.global_batch // k
    T = shape.seq_len
    i32 = jnp.int32

    def arr(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if cfg.family == "vlm":
        t_text = T - cfg.num_patches
        return {
            "tokens": arr((k, bg, t_text), i32),
            "labels": arr((k, bg, t_text), i32),
            "patches": arr((k, bg, cfg.num_patches, cfg.d_model), cfg.dtype),
        }
    if cfg.family == "audio":
        t_enc = max(T // cfg.encoder_seq_divisor, 1)
        return {
            "tokens": arr((k, bg, T), i32),
            "labels": arr((k, bg, T), i32),
            "frames": arr((k, bg, t_enc, cfg.d_model), cfg.dtype),
        }
    return {"tokens": arr((k, bg, T), i32), "labels": arr((k, bg, T), i32)}


def prefill_batch_struct(cfg: ModelConfig, shape: InputShape):
    B, T = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def arr(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if cfg.family == "vlm":
        return {"tokens": arr((B, T - cfg.num_patches), i32),
                "patches": arr((B, cfg.num_patches, cfg.d_model), cfg.dtype)}
    if cfg.family == "audio":
        t_enc = max(T // cfg.encoder_seq_divisor, 1)
        return {"tokens": arr((B, T), i32),
                "frames": arr((B, t_enc, cfg.d_model), cfg.dtype)}
    return {"tokens": arr((B, T), i32)}


def decode_input_struct(cfg: ModelConfig, shape: InputShape):
    """(tokens, positions, state) for one serve_step against a seq_len-deep
    context."""
    B, T = shape.global_batch, shape.seq_len
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    positions = jax.ShapeDtypeStruct((B,), jnp.int32)
    state = jax.eval_shape(
        lambda: model_lib.init_decode_state(cfg, B, T))
    return tokens, positions, state


def input_specs(arch_or_cfg, shape_name: str, *, num_groups: int = 4):
    """The dry-run entry: abstract inputs for (arch, shape)."""
    cfg = (arch_or_cfg if isinstance(arch_or_cfg, ModelConfig)
           else get_config(arch_or_cfg))
    shape = get_shape(shape_name)
    if shape.name == "long_500k":
        cfg = long_context_variant(cfg)
    if shape.kind == "train":
        return cfg, shape, train_batch_struct(cfg, shape, num_groups)
    if shape.kind == "prefill":
        return cfg, shape, prefill_batch_struct(cfg, shape)
    return cfg, shape, decode_input_struct(cfg, shape)


# ---------------------------------------------------------------------------
# abstract params / optimizer state

def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(model_lib.init, cfg=cfg),
        # repro: ignore[RV102] eval_shape only traces — the key's value is never consumed
        jax.random.key(0))


def abstract_opt_state(optimizer, params_struct):
    return jax.eval_shape(optimizer.init, params_struct)


# ---------------------------------------------------------------------------
# steps

def make_group_train_step(cfg: ModelConfig, rc: RobustConfig, optimizer, *,
                          microbatches: int = 1, grad_shardings=None,
                          schedule: byzantine.AttackSchedule | None = None,
                          shard_spec=None):
    """Group-mode robust train step (the production/dry-run path).

    rc.num_workers is interpreted as k (the number of batches); the attack
    mask has k entries with rc.num_byzantine contaminated batches.
    ``grad_shardings`` (optional pytree of NamedSharding for the stacked
    (k, *param) gradients) anchors the scan output so the cross-data
    gradient reduction lowers as reduce-scatter into the optimizer layout —
    and, crucially, keeps the gradients PARTITIONED over the model axis
    end-to-end: aggregation consumes the per-shard slices directly, no
    O(d) gather ever materializes (the shard-local contract,
    ``repro.core.shard_aggregation``).

    ``shard_spec`` (a ``ShardSpec``, usually
    ``launch.sharding.grad_shard_spec(mesh, cfg)``) reaches
    ``aggregate_reported`` so norm-based rules route their reductions
    through the blocked contract and ``round_backend`` auto-dispatch keys
    off the TARGET backend instead of the lowering host's — a dry-run sweep
    lowering TPU programs from a CPU host resolves the production path.

    Aggregation dispatches through ``robust_train.aggregate_reported`` —
    the same registry path the scenario engine uses — so ``rc.aggregator``
    (gmom / mean / trimmed_mean / krum / ...) and ``rc.round_backend`` (the
    fused Pallas round kernel vs the jnp reference) are first-class here,
    not pinned to the inline gmom pipeline this step used to hard-code.
    With ``rc.num_batches == k`` the gmom grouping is the identity (each
    batch-group mean is its own "batch"), reproducing the historical
    trim + Weiszfeld tail value for value.

    ``schedule`` threads a multi-round ``AttackSchedule`` through the step
    (the pod-sweep path: attack × schedule at batch-mean granularity).
    When given, the step signature gains the adversary's carried state:
    ``train_step(params, opt_state, batch, key, round_index, attack_state)
    -> (params, opt_state, metrics, attack_state)``; without it the
    historical 5-arg signature is unchanged.
    """
    k = rc.num_workers

    def group_value_and_grad(params, group_batch):
        if microbatches == 1:
            return jax.value_and_grad(model_lib.loss_fn)(
                params, group_batch, cfg)

        def reshape(x):
            n = x.shape[0]
            assert n % microbatches == 0
            return x.reshape((microbatches, n // microbatches) + x.shape[1:])

        mb = jax.tree.map(reshape, group_batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def mb_step(carry, b):
            g_acc, l_acc = carry
            l, g = jax.value_and_grad(model_lib.loss_fn)(params, b, cfg)
            g_acc = jax.tree.map(
                lambda a, x: a + x.astype(jnp.float32), g_acc, g)
            return (g_acc, l_acc + l), None

        (g, l), _ = jax.lax.scan(
            mb_step, (zero, jnp.zeros((), jnp.float32)), mb)
        inv = 1.0 / microbatches
        return l * inv, jax.tree.map(lambda x: x * inv, g)

    attack = byzantine.get_attack(rc.attack)
    attack_kwargs = dict(rc.attack_kwargs)

    def _step_core(params, opt_state, batch, key, round_index, attack_state):
        # sequential scan over the k batch-groups (gradient accumulation
        # with per-group gradients kept separate): one group's activations
        # live at a time, and shard_map regions (MoE EP) stay legal.  Each
        # group is itself data-parallel over the full data axis.
        def group_step(_, group_batch):
            loss, grad = group_value_and_grad(params, group_batch)
            return None, (loss, grad)

        _, (losses, grads) = jax.lax.scan(group_step, None, batch)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        if schedule is None:
            mask = byzantine.sample_byzantine_mask(
                key, k, rc.num_byzantine, rotate=rc.rotate_byzantine,
                round_index=round_index)
            reported = attack(grads, mask, key, **attack_kwargs)
        else:
            reported, mask, attack_state = schedule.apply(
                grads, key, round_index, attack_state)
        agg = aggregate_reported(reported, rc, key=key,
                                 shard_spec=shard_spec)
        updates, opt_state = optimizer.update(agg, opt_state, params)
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                              params, updates)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(agg)))
        metrics = {"loss_mean": jnp.mean(losses),
                   "loss_median": jnp.median(losses),
                   "agg_grad_norm": gnorm,
                   "byz_count": jnp.sum(mask.astype(jnp.int32))}
        return params, opt_state, metrics, attack_state

    if schedule is None:
        def train_step(params, opt_state, batch, key, round_index):
            params, opt_state, metrics, _ = _step_core(
                params, opt_state, batch, key, round_index, None)
            return params, opt_state, metrics
    else:
        def train_step(params, opt_state, batch, key, round_index,
                       attack_state):
            return _step_core(params, opt_state, batch, key, round_index,
                              attack_state)

    return train_step


def make_mean_train_step(cfg: ModelConfig, optimizer, *,
                         microbatches: int = 1):
    """Failure-free baseline (paper Algorithm 1 at production scale):
    identical pipeline with k=1, mean aggregation, no attack."""
    rc = RobustConfig(num_workers=1, num_byzantine=0, num_batches=1,
                      aggregator="mean", attack="none", trim_multiplier=None)
    return make_group_train_step(cfg, rc, optimizer,
                                 microbatches=microbatches)


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return model_lib.prefill(params, cfg, batch)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, state, tokens, positions):
        return model_lib.decode_step(params, cfg, state, tokens, positions)
    return serve_step

"""Batched serving driver.

* ``--scale cpu`` (default): actually serves — reduced config, batched
  greedy decoding over synthetic prompts with throughput stats.
* ``--scale pod``: dry-run lowering of the serve step for the decode shapes
  on the production mesh (run via ``python -m repro.launch.dryrun`` which
  sets the required XLA device flag).

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-3-4b \
        --batch 8 --new-tokens 64
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHITECTURES, get_config
from repro.models import model as model_lib


def serve_cpu(args):
    cfg = get_config(args.arch).reduced()
    if cfg.family == "hybrid":
        cfg = cfg.with_(ssm_chunk=8)
    params = model_lib.init(jax.random.PRNGKey(args.seed), cfg)
    B = args.batch
    max_len = args.prompt_len + args.new_tokens
    # synthetic prompts draw from the same CLI seed as the params (folded so
    # the two streams differ) — a fixed literal key here would pin the
    # prompts across --seed values (repro.verify RV102).
    prompt_key = jax.random.fold_in(jax.random.PRNGKey(args.seed), 1)
    prompts = jax.random.randint(prompt_key, (B, args.prompt_len),
                                 0, cfg.vocab_size)
    state = model_lib.init_decode_state(cfg, B, max_len)
    step = jax.jit(lambda s, t, p: model_lib.decode_step(params, cfg, s, t, p))

    # prefill via decode steps (reference path)
    logits = None
    t0 = time.time()
    for t in range(args.prompt_len):
        logits, state = step(state, prompts[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
    t_prefill = time.time() - t0

    tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    out = [tokens]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        logits, state = step(state, tokens,
                             jnp.full((B,), args.prompt_len + i, jnp.int32))
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tokens)
    dt = time.time() - t0
    print(f"[serve] {args.arch}: prefill {args.prompt_len}tok in "
          f"{t_prefill:.2f}s; decode {args.new_tokens}x{B} in {dt:.2f}s "
          f"({B * args.new_tokens / max(dt, 1e-9):.1f} tok/s CPU)")
    return jnp.concatenate(out, axis=1)


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="h2o-danube-3-4b",
                   choices=list(ARCHITECTURES))
    p.add_argument("--scale", default="cpu", choices=["cpu", "pod"])
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--new-tokens", type=int, default=32)
    p.add_argument("--shape", default="decode_32k",
                   choices=["decode_32k", "long_500k"])
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)
    if args.scale == "cpu":
        serve_cpu(args)
    else:
        from repro.launch import dryrun
        dryrun.force_host_device_count()
        dryrun.dryrun_pair(args.arch, args.shape, multi_pod=args.multi_pod)


if __name__ == "__main__":
    main()

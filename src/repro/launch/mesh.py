"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model").

The Byzantine worker axis is ``data`` (x ``pod`` on multi-pod) — see
DESIGN.md §4.  Functions, not module constants: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before first init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(*, data: int = 2, model: int = 2, pod: int | None = None):
    """Small virtual mesh for CI-scale dry-run tests (8 host devices)."""
    if pod is not None:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """The worker/batch axes: ("data",) or ("pod", "data")."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def data_size(mesh) -> int:
    out = 1
    for a in data_axes(mesh):
        out *= mesh.shape[a]
    return out


def model_size(mesh) -> int:
    return mesh.shape["model"]

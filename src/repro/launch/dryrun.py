"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes with 512 placeholder host devices.

For each pair this lowers the real step function — group-mode robust
train_step (train_4k), prefill forward (prefill_32k), or single-token
serve_step (decode_32k / long_500k) — with full-size ShapeDtypeStruct inputs
and the production shardings, compiles it, and records
``memory_analysis``/``cost_analysis``/collective bytes for §Dry-run and
§Roofline of EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

The production meshes need 256/512 devices; on a CPU host the entry points
call :func:`force_host_device_count` BEFORE jax's backend initializes.  This
used to happen as an import-time ``os.environ`` mutation, which poisoned any
process that imported dryrun helpers after its own jax init (a later import
silently saw 512 virtual devices — or, worse, tests importing this module
for its helper API flipped the flag under an already-initialized backend).
Import is now side-effect free: callers that want the 512-device dry-run
environment invoke ``force_host_device_count`` explicitly (both CLI ``main``
entry points here and in ``repro.sim.sweep`` do), and everything else —
``lower_pair``/``dryrun_pair`` with an injected small mesh, the sweep's
comparison helpers, CI test collection — imports safely.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import re
import sys
import time
import traceback

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHITECTURES, get_shape
from repro.configs.base import ModelConfig
from repro.models.meshctx import set_mesh
from repro.core import RobustConfig, byzantine
from repro.launch import mesh as mesh_lib
from repro.launch import sharding, steps
from repro.roofline import analysis
from repro import optim

DEFAULT_HOST_DEVICE_COUNT = 512


def _jax_backend_initialized() -> bool:
    """True once jax has locked its device count (first backend init)."""
    try:
        from jax._src import xla_bridge as xb
    except Exception:  # pragma: no cover - private-API drift
        return False
    if hasattr(xb, "backends_are_initialized"):
        try:
            return bool(xb.backends_are_initialized())
        except Exception:  # pragma: no cover
            pass
    return bool(getattr(xb, "_backends", None))


def force_host_device_count(count: int = DEFAULT_HOST_DEVICE_COUNT) -> None:
    """Arm ``--xla_force_host_platform_device_count=<count>``.

    Must run before jax initializes its backend (jax locks the device count
    at first init).  Raises if the backend is already up with fewer devices
    than requested — the old import-time mutation failed silently in exactly
    this case.  No-op when the live backend already has enough devices
    (e.g. a subprocess that exported the flag itself).
    """
    if _jax_backend_initialized():
        if jax.device_count() >= count:
            return
        raise RuntimeError(
            f"jax backend already initialized with {jax.device_count()} "
            f"device(s); cannot force {count} host devices now.  Call "
            "force_host_device_count() before any jax device/array use, or "
            "export XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{count} before starting python.")
    # Normalize rather than append: XLA_FLAGS may already carry the flag —
    # once (an exported =8 from a test shell), or several times (repeated
    # invocation under the old append logic, or a caller stacking exports).
    # XLA's flag parsing makes duplicate occurrences ambiguous, so strip
    # every occurrence and emit exactly one with the effective count (the
    # max of every pre-existing value and the request — a pre-existing
    # smaller count would make the production meshes fail later with a
    # confusing mesh-size error).  Repeated calls are idempotent: the
    # rewritten string is identical, including whitespace.
    flags = os.environ.get("XLA_FLAGS", "")
    flag_re = re.compile(
        r"--xla_force_host_platform_device_count=(\d+)")
    effective = max([int(v) for v in flag_re.findall(flags)] + [count])
    stripped = " ".join(flag_re.sub(" ", flags).split())
    os.environ["XLA_FLAGS"] = (
        stripped +
        f" --xla_force_host_platform_device_count={effective}").strip()


def _mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


@dataclasses.dataclass
class DryrunArtifacts:
    """Everything one lower+compile produces, for downstream consumers.

    ``repro.sim.sweep`` builds per-scenario collective-cost entries from
    these; ``dryrun_pair`` keeps its original record-only return."""
    arch: str
    shape_name: str
    mesh_name: str
    step_kind: str
    num_chips: int
    cfg: ModelConfig
    shape: object
    record: analysis.RooflineRecord
    lowered: object
    compiled: object
    compile_seconds: float


def default_train_rc(num_groups: int) -> RobustConfig:
    """The historical dry-run aggregation config (gmom + sign_flip)."""
    return RobustConfig(num_workers=num_groups, num_byzantine=1,
                        num_batches=num_groups, aggregator="gmom",
                        attack="sign_flip", gmom_max_iters=8)


def lower_pair(arch_or_cfg, shape_name: str, *, multi_pod: bool = False,
               mesh=None, num_groups: int = 4, microbatches: int = 1,
               fsdp: bool = True, rc: RobustConfig | None = None,
               schedule: byzantine.AttackSchedule | None = None,
               gather_grads: bool = False,
               verbose: bool = True) -> DryrunArtifacts:
    """Lower + compile one (arch, shape, mesh) and return all artifacts.

    ``rc`` injects the full aggregation pipeline configuration (aggregator,
    attack, round_backend, trim, ...) into the group-mode train step;
    ``schedule`` additionally threads a multi-round ``AttackSchedule``
    through the step (the lowered function then takes/returns the
    adversary's carried state).  Train shapes only; both default to the
    historical gmom + sign_flip dry-run configuration.

    ``gather_grads=True`` lowers the dense O(d)-per-device BASELINE: the
    stacked gradients are constrained fully replicated before aggregation
    (the gather the pre-shard-local code implied) and the aggregation runs
    with a trivial ShardSpec.  Default False keeps gradients partitioned
    over ``model`` end-to-end — the shard-local path whose peak memory the
    pod sweep's big-model cells gate against the gathered baseline.
    """
    if mesh is None:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    cfg, shape, batch = steps.input_specs(arch_or_cfg, shape_name,
                                          num_groups=num_groups)
    arch = arch_or_cfg if isinstance(arch_or_cfg, str) else cfg.name
    num_chips = mesh.size
    t0 = time.time()

    with set_mesh(mesh):
        params_s = steps.abstract_params(cfg)
        pshard = sharding.param_shardings(params_s, mesh, cfg, fsdp=fsdp)

        if shape.kind == "train":
            if rc is None:
                rc = default_train_rc(num_groups)
            opt = optim.adamw(3e-4)
            opt_s = steps.abstract_opt_state(opt, params_s)
            oshard = sharding.opt_state_shardings(opt_s, params_s, mesh,
                                                  cfg, fsdp=fsdp)
            bshard = sharding.batch_shardings(batch, mesh)
            if gather_grads:
                gshard = sharding.gathered_grad_shardings(params_s, mesh)
                spec = dataclasses.replace(
                    sharding.grad_shard_spec(mesh, cfg), num_shards=1)
            else:
                gshard = sharding.stacked_grad_shardings(params_s, mesh, cfg,
                                                         fsdp=fsdp)
                spec = sharding.grad_shard_spec(mesh, cfg)
            step_fn = steps.make_group_train_step(cfg, rc, opt,
                                                  microbatches=microbatches,
                                                  grad_shardings=gshard,
                                                  schedule=schedule,
                                                  shard_spec=spec)
            key_s = jax.ShapeDtypeStruct((2,), jax.numpy.uint32)
            round_s = jax.ShapeDtypeStruct((), jax.numpy.int32)
            rep = sharding.replicated(mesh)
            if schedule is None:
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(pshard, oshard, bshard, rep, rep),
                    donate_argnums=(0, 1))
                lowered = jitted.lower(params_s, opt_s, batch, key_s, round_s)
            else:
                astate_s = jax.eval_shape(schedule.init_state)
                ashard = jax.tree.map(lambda _: rep, astate_s)
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(pshard, oshard, bshard, rep, rep, ashard),
                    donate_argnums=(0, 1))
                lowered = jitted.lower(params_s, opt_s, batch, key_s,
                                       round_s, astate_s)
            step_kind = "train_step"

        elif shape.kind == "prefill":
            bshard = jax.tree.map(
                lambda x: jax.NamedSharding(
                    mesh, P(*((sharding.serve_batch_spec(
                        mesh, shape.global_batch)[0],)
                        + (None,) * (len(x.shape) - 1)))),
                batch)
            step_fn = steps.make_prefill_step(cfg)
            jitted = jax.jit(step_fn, in_shardings=(pshard, bshard))
            lowered = jitted.lower(params_s, batch)
            step_kind = "prefill"

        else:  # decode
            tokens_s, positions_s, state_s = batch
            sshard = sharding.decode_state_shardings(
                state_s, mesh, cfg, shape.global_batch)
            bspec = sharding.serve_batch_spec(mesh, shape.global_batch)
            baxis = bspec[0] if len(bspec) else None
            tshard = jax.NamedSharding(mesh, P(baxis, None))
            posshard = jax.NamedSharding(mesh, P(baxis))
            step_fn = steps.make_serve_step(cfg)
            jitted = jax.jit(step_fn,
                             in_shardings=(pshard, sshard, tshard, posshard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_s, state_s, tokens_s, positions_s)
            step_kind = "serve_step"

        compiled = lowered.compile()

    elapsed = time.time() - t0
    record = analysis.build_record(
        arch=arch, shape=shape, cfg=cfg, mesh_name=_mesh_name(mesh),
        num_chips=num_chips, step=step_kind, compiled=compiled)
    if verbose:
        mem = compiled.memory_analysis()
        print(f"[dryrun] {arch} × {shape_name} × {_mesh_name(mesh)} "
              f"({step_kind}) compiled in {elapsed:.1f}s")
        print(f"  memory_analysis: {mem}")
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  collectives: {record.collective_breakdown}")
        print(f"  roofline: compute={record.compute_term:.3e}s "
              f"memory={record.memory_term:.3e}s "
              f"collective={record.collective_term:.3e}s "
              f"-> {record.bottleneck}-bound "
              f"(useful-FLOPs ratio {record.useful_flops_ratio:.2f})")
    return DryrunArtifacts(
        arch=arch, shape_name=shape_name, mesh_name=_mesh_name(mesh),
        step_kind=step_kind, num_chips=num_chips, cfg=cfg, shape=shape,
        record=record, lowered=lowered, compiled=compiled,
        compile_seconds=elapsed)


def dryrun_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
                mesh=None, num_groups: int = 4, microbatches: int = 1,
                fsdp: bool = True, verbose: bool = True,
                rc: RobustConfig | None = None, schedule=None,
                gather_grads: bool = False,
                return_artifacts: bool = False):
    """Lower+compile one (arch, shape, mesh); returns a RooflineRecord.

    Thin wrapper over :func:`lower_pair` kept for the original CLI/record
    contract; pass ``return_artifacts=True`` for (record, lowered, compiled).
    """
    art = lower_pair(arch, shape_name, multi_pod=multi_pod, mesh=mesh,
                     num_groups=num_groups, microbatches=microbatches,
                     fsdp=fsdp, rc=rc, schedule=schedule,
                     gather_grads=gather_grads, verbose=verbose)
    if return_artifacts:
        return art.record, art.lowered, art.compiled
    return art.record


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=list(ARCHITECTURES))
    p.add_argument("--shape", choices=["train_4k", "prefill_32k",
                                       "decode_32k", "long_500k"])
    p.add_argument("--all", action="store_true",
                   help="run every (arch × shape) pair")
    p.add_argument("--multi-pod", action="store_true",
                   help="use the 2×16×16 multi-pod mesh")
    p.add_argument("--num-groups", type=int, default=4,
                   help="k — number of gradient batches (train shapes)")
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--no-fsdp", action="store_true")
    p.add_argument("--out", default=None, help="write JSON records here")
    args = p.parse_args(argv)

    # entry-point guard: the production meshes need 512 host devices; this
    # must NOT happen at import time (see module docstring).
    force_host_device_count(DEFAULT_HOST_DEVICE_COUNT)

    pairs = []
    if args.all:
        for arch in ARCHITECTURES:
            for shape in ("train_4k", "prefill_32k", "decode_32k",
                          "long_500k"):
                pairs.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            p.error("--arch and --shape required unless --all")
        pairs = [(args.arch, args.shape)]

    records, failures = [], []
    for arch, shape in pairs:
        try:
            records.append(dryrun_pair(
                arch, shape, multi_pod=args.multi_pod,
                num_groups=args.num_groups, microbatches=args.microbatches,
                fsdp=not args.no_fsdp))
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))

    if records:
        print()
        print(analysis.format_table(records))
    if args.out:
        analysis.save_records(records, args.out)
        print(f"\nwrote {len(records)} records to {args.out}")
    if failures:
        print(f"\nFAILURES ({len(failures)}):")
        for arch, shape, err in failures:
            print(f"  {arch} × {shape}: {err}")
        sys.exit(1)


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes with 512 placeholder host devices.

For each pair this lowers the real step function — group-mode robust
train_step (train_4k), prefill forward (prefill_32k), or single-token
serve_step (decode_32k / long_500k) — with full-size ShapeDtypeStruct inputs
and the production shardings, compiles it, and records
``memory_analysis``/``cost_analysis``/collective bytes for §Dry-run and
§Roofline of EXPERIMENTS.md.

Usage:
    python -m repro.launch.dryrun --arch qwen2-72b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]

NOTE: the XLA_FLAGS line above MUST run before any other import — jax locks
the device count at first init (do not set this flag globally; smoke tests
and benchmarks must see 1 device).
"""

import argparse
import json
import sys
import time
import traceback

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHITECTURES, get_shape
from repro.models.meshctx import set_mesh
from repro.core import RobustConfig
from repro.launch import mesh as mesh_lib
from repro.launch import sharding, steps
from repro.roofline import analysis
from repro import optim


def _mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)


def dryrun_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
                mesh=None, num_groups: int = 4, microbatches: int = 1,
                fsdp: bool = True, verbose: bool = True,
                return_artifacts: bool = False):
    """Lower+compile one (arch, shape, mesh); returns a RooflineRecord."""
    if mesh is None:
        mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    cfg, shape, batch = steps.input_specs(arch, shape_name,
                                          num_groups=num_groups)
    num_chips = mesh.size
    t0 = time.time()

    with set_mesh(mesh):
        params_s = steps.abstract_params(cfg)
        pshard = sharding.param_shardings(params_s, mesh, cfg, fsdp=fsdp)

        if shape.kind == "train":
            rc = RobustConfig(num_workers=num_groups, num_byzantine=1,
                              num_batches=num_groups, aggregator="gmom",
                              attack="sign_flip", gmom_max_iters=8)
            opt = optim.adamw(3e-4)
            opt_s = steps.abstract_opt_state(opt, params_s)
            oshard = sharding.opt_state_shardings(opt_s, params_s, mesh,
                                                  cfg, fsdp=fsdp)
            bshard = sharding.batch_shardings(batch, mesh)
            gshard = sharding.stacked_grad_shardings(params_s, mesh, cfg,
                                                     fsdp=fsdp)
            step_fn = steps.make_group_train_step(cfg, rc, opt,
                                                  microbatches=microbatches,
                                                  grad_shardings=gshard)
            key_s = jax.ShapeDtypeStruct((2,), jax.numpy.uint32)
            rep = sharding.replicated(mesh)
            jitted = jax.jit(
                step_fn,
                in_shardings=(pshard, oshard, bshard, rep, rep),
                donate_argnums=(0, 1))
            lowered = jitted.lower(
                params_s, opt_s, batch, key_s,
                jax.ShapeDtypeStruct((), jax.numpy.int32))
            step_kind = "train_step"

        elif shape.kind == "prefill":
            bshard = jax.tree.map(
                lambda x: jax.NamedSharding(
                    mesh, P(*((sharding.serve_batch_spec(
                        mesh, shape.global_batch)[0],)
                        + (None,) * (len(x.shape) - 1)))),
                batch)
            step_fn = steps.make_prefill_step(cfg)
            jitted = jax.jit(step_fn, in_shardings=(pshard, bshard))
            lowered = jitted.lower(params_s, batch)
            step_kind = "prefill"

        else:  # decode
            tokens_s, positions_s, state_s = batch
            sshard = sharding.decode_state_shardings(
                state_s, mesh, cfg, shape.global_batch)
            bspec = sharding.serve_batch_spec(mesh, shape.global_batch)
            baxis = bspec[0] if len(bspec) else None
            tshard = jax.NamedSharding(mesh, P(baxis, None))
            posshard = jax.NamedSharding(mesh, P(baxis))
            step_fn = steps.make_serve_step(cfg)
            jitted = jax.jit(step_fn,
                             in_shardings=(pshard, sshard, tshard, posshard),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_s, state_s, tokens_s, positions_s)
            step_kind = "serve_step"

        compiled = lowered.compile()

    record = analysis.build_record(
        arch=arch, shape=shape, cfg=cfg, mesh_name=_mesh_name(mesh),
        num_chips=num_chips, step=step_kind, compiled=compiled)
    if verbose:
        mem = compiled.memory_analysis()
        print(f"[dryrun] {arch} × {shape_name} × {_mesh_name(mesh)} "
              f"({step_kind}) compiled in {time.time() - t0:.1f}s")
        print(f"  memory_analysis: {mem}")
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
              f"bytes={ca.get('bytes accessed', 0):.3e}")
        print(f"  collectives: {record.collective_breakdown}")
        print(f"  roofline: compute={record.compute_term:.3e}s "
              f"memory={record.memory_term:.3e}s "
              f"collective={record.collective_term:.3e}s "
              f"-> {record.bottleneck}-bound "
              f"(useful-FLOPs ratio {record.useful_flops_ratio:.2f})")
    if return_artifacts:
        return record, lowered, compiled
    return record


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", choices=list(ARCHITECTURES))
    p.add_argument("--shape", choices=["train_4k", "prefill_32k",
                                       "decode_32k", "long_500k"])
    p.add_argument("--all", action="store_true",
                   help="run every (arch × shape) pair")
    p.add_argument("--multi-pod", action="store_true",
                   help="use the 2×16×16 multi-pod mesh")
    p.add_argument("--num-groups", type=int, default=4,
                   help="k — number of gradient batches (train shapes)")
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--no-fsdp", action="store_true")
    p.add_argument("--out", default=None, help="write JSON records here")
    args = p.parse_args(argv)

    pairs = []
    if args.all:
        for arch in ARCHITECTURES:
            for shape in ("train_4k", "prefill_32k", "decode_32k",
                          "long_500k"):
                pairs.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            p.error("--arch and --shape required unless --all")
        pairs = [(args.arch, args.shape)]

    records, failures = [], []
    for arch, shape in pairs:
        try:
            records.append(dryrun_pair(
                arch, shape, multi_pod=args.multi_pod,
                num_groups=args.num_groups, microbatches=args.microbatches,
                fsdp=not args.no_fsdp))
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            failures.append((arch, shape, repr(e)))

    if records:
        print()
        print(analysis.format_table(records))
    if args.out:
        analysis.save_records(records, args.out)
        print(f"\nwrote {len(records)} records to {args.out}")
    if failures:
        print(f"\nFAILURES ({len(failures)}):")
        for arch, shape, err in failures:
            print(f"  {arch} × {shape}: {err}")
        sys.exit(1)


if __name__ == "__main__":
    main()

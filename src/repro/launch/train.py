"""End-to-end training driver.

Two modes:

* ``--scale cpu`` (default): actually runs — reduced config, synthetic token
  stream, worker-mode Byzantine GD (the paper-faithful path), checkpointing,
  metrics log.  This is deliverable (b)'s end-to-end driver at CPU scale.
* ``--scale pod``: builds the production 16×16 (or 2×16×16) job with the
  group-mode step and full-size config, and executes the dry-run lowering
  (this container has no TPU; on real hardware the same code path runs by
  passing real arrays instead of ShapeDtypeStructs).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch minitron-4b \
        --steps 50 --byzantine 2 --attack sign_flip --aggregator gmom
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro import checkpoint, optim
from repro.core import (RobustConfig, aggregators, byzantine,
                        init_train_state, make_run_rounds,
                        restore_train_state, save_train_state,
                        schedule_from_config, staleness)
from repro.core.train_state import advance, history_rows
from repro.configs import ARCHITECTURES, get_config
from repro.data.tokens import TokenStream
from repro.models import model as model_lib


def build_cpu_batch(cfg, stream: TokenStream, step: int, key):
    batch = stream.batch(step)
    m, bw = batch["tokens"].shape[:2]
    if cfg.family == "vlm":
        t = batch["tokens"].shape[-1]
        keep = t - cfg.num_patches
        batch = {"tokens": batch["tokens"][..., :keep],
                 "labels": batch["labels"][..., :keep],
                 "patches": jax.random.normal(
                     key, (m, bw, cfg.num_patches, cfg.d_model), cfg.dtype)}
    elif cfg.family == "audio":
        t_enc = max(batch["tokens"].shape[-1] // cfg.encoder_seq_divisor, 1)
        batch = dict(batch, frames=jax.random.normal(
            key, (m, bw, t_enc, cfg.d_model), cfg.dtype))
    return batch


def resume_train_state(ckpt_dir, params, opt_state, schedule, step_key,
                       arrival=None):
    """Restore the latest checkpoint in ``ckpt_dir`` into a TrainState.

    Returns ``(state, restored_step)`` — ``(fresh state, 0)`` when there is
    no checkpoint.  format_version>=2 checkpoints restore the FULL state
    (params + opt_state + attack_state + round + key + metrics history), so
    the resumed trajectory is bit-identical to an uninterrupted run.
    Legacy params-only checkpoints take a one-shot compatibility path:
    params are restored (with dtype casting, as the old restore did),
    everything else reinitializes, and a loud warning says so; the next
    save writes the full state.
    """
    state = init_train_state(params, opt_state, step_key, schedule=schedule,
                             arrival=arrival)
    step = checkpoint.latest_step(ckpt_dir) if ckpt_dir else None
    if step is None:
        return state, 0
    manifest = checkpoint.read_manifest(ckpt_dir, step)
    if manifest.get("payload") == "train_state":
        state = restore_train_state(ckpt_dir, step, params, opt_state,
                                    schedule=schedule, arrival=arrival,
                                    manifest=manifest)
        print(f"[train] restored full TrainState (round {step}, "
              f"schedule {schedule.name!r}) from {ckpt_dir}")
        return state, step
    # legacy v1 checkpoints and bare params trees saved via checkpoint.save
    params = checkpoint.restore(ckpt_dir, step, params, allow_cast=True)
    print(f"[train] WARNING: legacy params-only checkpoint (step {step}, "
          f"{ckpt_dir}): optimizer state, adversary state, and metrics "
          "history were not saved and restart fresh — the resumed "
          "trajectory will NOT match an uninterrupted run. The next "
          "checkpoint upgrades to a full TrainState.")
    return state._replace(params=params,
                          round_index=jnp.asarray(step, jnp.int32)), step


def train_cpu(args) -> dict:
    cfg = get_config(args.arch).reduced()
    m = args.workers
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                         global_batch=args.batch, num_workers=m,
                         seed=args.seed)
    rc = RobustConfig(num_workers=m, num_byzantine=args.byzantine,
                      attack=args.attack, aggregator=args.aggregator,
                      num_batches=args.num_batches,
                      round_backend=args.round_backend,
                      arrival=args.arrival,
                      staleness_bound=args.staleness_bound)
    opt = optim.adamw(args.lr)
    loss_fn = lambda p, b: model_lib.loss_fn(p, b, cfg)  # noqa: E731
    if args.schedule:
        schedule = byzantine.make_schedule(
            args.schedule, num_workers=m, num_byzantine=args.byzantine,
            attack=args.attack)
    else:
        schedule = schedule_from_config(rc)
    # Scan-compiled multi-round runner: rounds run in chunks of
    # --scan-chunk, each chunk a single XLA dispatch (the Python loop only
    # handles logging and checkpoint boundaries).
    arrival = staleness.arrival_from_config(rc)
    run = make_run_rounds(loss_fn, opt, rc, schedule=schedule,
                          arrival=arrival)

    key = jax.random.PRNGKey(args.seed)
    params = model_lib.init(key, cfg)
    opt_state = opt.init(params)
    step_key = jax.random.fold_in(key, 10_000)
    # NOTE: resume assumes the same --seed/--batch/--seq-len (the data
    # stream re-derives from args); the step keys themselves are restored
    # from the checkpoint.
    state, start = resume_train_state(args.ckpt_dir, params, opt_state,
                                      schedule, step_key, arrival=arrival)

    chunk = max(1, args.scan_chunk)
    if args.ckpt_dir:
        chunk = min(chunk, args.ckpt_every)
    t0 = time.time()
    i = start
    while i < args.steps:
        n = min(chunk, args.steps - i)
        if args.ckpt_dir:   # never scan across a checkpoint boundary
            n = min(n, args.ckpt_every - i % args.ckpt_every)
        rounds = [build_cpu_batch(cfg, stream, j, jax.random.fold_in(key, j))
                  for j in range(i, i + n)]
        batch = jax.tree.map(lambda *xs: jnp.stack(xs), *rounds)
        state, _ = advance(run, state, batch, per_round_batches=True)
        i += n
        if (i - 1) % args.log_every < n or i == args.steps:
            print(f"[train] step {i - 1:4d} loss_median="
                  f"{float(state.history['loss_median'][-1]):.4f} "
                  f"gnorm={float(state.history['agg_grad_norm'][-1]):.3f} "
                  f"({time.time() - t0:.1f}s)")
        # boundary saves plus a final save, so the completed run is always
        # resumable/inspectable even when steps % ckpt_every != 0
        if args.ckpt_dir and (i % args.ckpt_every == 0 or i == args.steps):
            save_train_state(args.ckpt_dir, state)
    history = history_rows(state.history)
    result = {"arch": args.arch, "aggregator": args.aggregator,
              "attack": args.attack, "byzantine": args.byzantine,
              "schedule": schedule.name,
              "arrival": args.arrival,
              "staleness_bound": args.staleness_bound,
              "resumed_from": start,
              "final_loss": history[-1]["loss_median"] if history else None,
              "first_loss": history[0]["loss_median"] if history else None,
              "history": history}
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
    return result


def train_pod(args):
    from repro.launch import dryrun
    # dryrun no longer forces the 512 virtual host devices at import time;
    # arm the flag explicitly before the first backend init.
    dryrun.force_host_device_count()
    rec = dryrun.dryrun_pair(args.arch, "train_4k",
                             multi_pod=args.multi_pod,
                             num_groups=args.num_batches or 4,
                             microbatches=args.microbatches)
    print("[train] pod-scale step compiled; roofline:",
          json.dumps(rec.to_dict(), indent=1, default=str))


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="minitron-4b", choices=list(ARCHITECTURES))
    p.add_argument("--scale", default="cpu", choices=["cpu", "pod"])
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--workers", type=int, default=8)
    p.add_argument("--byzantine", type=int, default=2)
    p.add_argument("--num-batches", type=int, default=None, dest="num_batches")
    p.add_argument("--attack", default="sign_flip",
                   choices=byzantine.available())
    p.add_argument("--schedule", default=None,
                   choices=byzantine.available_schedules(),
                   help="multi-round attack schedule (default: rotating)")
    p.add_argument("--scan-chunk", type=int, default=10, dest="scan_chunk",
                   help="rounds fused into one lax.scan dispatch")
    p.add_argument("--round-backend", default="auto", dest="round_backend",
                   choices=["auto", "fused", "fused_interpret", "reference"],
                   help="gmom hot-path lowering: fused Pallas round kernel "
                        "vs jnp reference (auto: fused on TPU)")
    p.add_argument("--aggregator", default="gmom",
                   choices=aggregators.available())
    p.add_argument("--arrival", default="all_sync",
                   choices=staleness.available_arrivals(),
                   help="arrival model: which workers report fresh each "
                        "round (docs/ASYNC.md); stale workers contribute "
                        "their bounded-staleness buffered gradient")
    p.add_argument("--staleness-bound", type=int, default=0,
                   dest="staleness_bound",
                   help="max buffered-gradient age tau (0 with all_sync = "
                        "the paper's synchronous path, bit-identical)")
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq-len", type=int, default=64)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--out", default=None)
    args = p.parse_args(argv)
    if args.scale == "cpu":
        train_cpu(args)
    else:
        # train_pod arms the 512 virtual host devices itself
        # (dryrun.force_host_device_count) — no pre-set XLA_FLAGS needed.
        train_pod(args)


if __name__ == "__main__":
    main()

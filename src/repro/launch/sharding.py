"""Sharding rules: param/activation/state -> mesh PartitionSpecs.

Strategy (measurement-driven — see EXPERIMENTS.md §Perf iteration 1):

* **Attention families (dense/moe/vlm/audio + hybrid's shared block):**
  sequence parallelism.  The residual stream is T-sharded over ``model``
  (models/attention.py shard_map region); attention/MLP weights are
  *compute-replicated* over ``model`` and FSDP-sharded for storage.  Naive
  GSPMD head sharding was measured at ~14 TB/device/step of score-tensor
  all-reduces on qwen2-72b (GQA kv=8 < |model|=16 and non-divisible head
  counts make head-TP unpartitionable); SP needs only the kv all-gather.
* **SSM families (rwkv/zamba2-mamba):** the time recurrence forbids
  T-sharding, but SSM head counts divide |model| (64, 80), so classic
  head-/channel-TP applies: col-parallel in-projections, row-parallel
  out-projections (one all-reduce per block).
* **MoE experts:** E -> model (expert parallelism; matches the shard_map
  in_specs in models/moe.py).  Router replicated.
* **Vocab:** embed/unembed V -> model (Megatron vocab-parallel loss).
* **Storage (FSDP):** optimizer state + master params shard their largest
  divisible dim over ``data`` — or over (data × model) jointly when the
  10 bytes/param footprint would not fit HBM on ``data`` alone (72B+).
* **Decode caches:** the cache length dim S -> model (each model rank scores
  its slice of the context; softmax combines with tiny psums), batch ->
  data.  SSM decode states: heads -> model.
* scan-stack leading dims (layers/groups) are never sharded.
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch import mesh as mesh_lib

_STACK_DIMS = {"layers": 1, "mamba": 2, "encoder": 1}

# families whose attention runs sequence-parallel (weights replicated)
SP_FAMILIES = ("dense", "moe", "vlm", "audio", "hybrid")


def _divides(dim_size: int, axis_size: int) -> bool:
    return axis_size > 0 and dim_size % axis_size == 0 \
        and dim_size >= axis_size


def storage_axes(cfg: ModelConfig | None, mesh) -> tuple:
    """FSDP storage axes: data, or data+model for very large models."""
    dd = mesh_lib.data_axes(mesh)
    if cfg is None:
        return dd
    footprint = cfg.param_count() * 10  # bf16 params + f32 adam m,v
    per_chip_data_only = footprint / mesh_lib.data_size(mesh)
    if per_chip_data_only > 12e9:
        return dd + ("model",)
    return dd


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def _fsdp_entry(axes):
    if isinstance(axes, tuple) and len(axes) == 1:
        return axes[0]
    return axes


def param_partition_spec(path, leaf, mesh, cfg: ModelConfig | None = None, *,
                         fsdp: bool = True,
                         min_fsdp_elems: int = 1 << 20) -> P:
    names = [getattr(k, "key", str(k)) for k in path]
    top = names[0] if names else ""
    name = names[-1] if names else ""
    skip = _STACK_DIMS.get(top, 0)
    shape = leaf.shape
    ndim = len(shape)
    model_n = mesh.shape["model"] if "model" in mesh.axis_names else 0
    spec = [None] * ndim
    family = cfg.family if cfg is not None else None

    def assign(dim, axis, axis_size) -> bool:
        d = dim if dim >= 0 else ndim + dim
        if d < skip or d >= ndim or spec[d] is not None:
            return False
        if not _divides(shape[d], axis_size):
            return False
        spec[d] = axis
        return True

    in_moe = "moe" in names
    in_rwkv_tm = "time_mix" in names
    in_rwkv_cm = "channel_mix" in names
    in_mamba = top == "mamba"

    # ------------------------------------------------------------- model axis
    model_used = False
    if model_n:
        if in_moe:
            if name == "router":
                pass                                    # replicated
            elif name in ("w_gate", "w_up", "w_down"):
                model_used = assign(skip, "model", model_n)    # E -> model
        elif in_rwkv_tm:
            if name in ("wr", "wk", "wv", "wg"):
                model_used = assign(-2, "model", model_n)      # H
            elif name in ("wo",):
                model_used = assign(-2, "model", model_n)      # H*hd (row)
            elif name in ("w0", "u"):
                model_used = assign(-2, "model", model_n)      # (H, hd)
            elif name == "w_lora_b":
                model_used = assign(-2, "model", model_n)      # (r, H, hd)
            elif name in ("scale", "bias") and "ln_x" in names:
                model_used = assign(-1, "model", model_n)      # (H*hd,)
        elif in_rwkv_cm:
            if name == "wk":
                model_used = assign(-1, "model", model_n)      # F (col)
            elif name == "wv":
                model_used = assign(-2, "model", model_n)      # F (row)
        elif in_mamba and family == "hybrid":
            if name in ("w_z", "w_x"):
                model_used = assign(-1, "model", model_n)      # Din (col)
            elif name in ("conv_x",):
                model_used = assign(-1, "model", model_n)      # Din channels
            elif name == "w_out":
                model_used = assign(-2, "model", model_n)      # Din (row)
            elif name in ("A_log", "dt_bias", "D", "w_dt"):
                model_used = assign(-1, "model", model_n)      # H
            elif name == "scale" and "norm" in names:
                model_used = assign(-1, "model", model_n)      # (Din,)
        elif name in ("embed", "unembed"):
            vocab_dim = -2 if name == "embed" else -1
            model_used = assign(vocab_dim, "model", model_n)   # V -> model
        elif name in ("w_gate", "w_up") and family in SP_FAMILIES:
            model_used = assign(-1, "model", model_n)          # F (col TP)
        elif name == "w_down" and family in SP_FAMILIES:
            model_used = assign(-2, "model", model_n)          # F (row TP)
        # SP families: attention weights stay model-replicated.

    # ------------------------------------------------------------ FSDP storage
    if fsdp and leaf.size >= min_fsdp_elems and cfg is not None:
        axes = storage_axes(cfg, mesh)
        # don't stack model storage onto leaves already TP-sharded
        if model_used and "model" in axes:
            axes = tuple(a for a in axes if a != "model")
        if axes:
            n = _axes_size(mesh, axes)
            order = sorted(range(skip, ndim), key=lambda d: -shape[d])
            for d in order:
                if spec[d] is None and _divides(shape[d], n):
                    spec[d] = _fsdp_entry(axes)
                    break
    return P(*spec)


def param_shardings(params, mesh, cfg: ModelConfig | None = None, *,
                    fsdp: bool = True):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [NamedSharding(mesh, param_partition_spec(p, l, mesh, cfg,
                                                      fsdp=fsdp))
             for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def stacked_grad_shardings(params, mesh, cfg: ModelConfig | None = None, *,
                           fsdp: bool = True):
    """Shardings for the (k, *param) stacked per-group gradients: leading k
    dim replicated, param dims keep the 2D param layout (DESIGN.md §4).
    Constraining the scan output to this turns the cross-data gradient
    reduction into a reduce-scatter aligned with the optimizer layout
    instead of a full all-reduce (measured 1.16 TB/device/step of f32
    all-reduce on kimi-k2 without it — EXPERIMENTS §Perf iteration 3)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for p, l in flat:
        spec = param_partition_spec(p, l, mesh, cfg, fsdp=fsdp)
        out.append(NamedSharding(mesh, P(None, *spec)))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# activations / batches / decode state

def train_batch_spec(mesh) -> P:
    ax = mesh_lib.data_axes(mesh)
    return P(None, ax if len(ax) > 1 else ax[0])


def batch_shardings(batch, mesh):
    spec = train_batch_spec(mesh)

    def leaf(x):
        nd = len(x.shape)
        full = P(*(tuple(spec) + (None,) * (nd - 2)))
        return NamedSharding(mesh, full)

    return jax.tree.map(leaf, batch)


def serve_batch_spec(mesh, batch_size: int) -> P:
    ax = mesh_lib.data_axes(mesh)
    axes = ax if len(ax) > 1 else ax[0]
    if batch_size % mesh_lib.data_size(mesh) == 0:
        return P(axes)
    return P(None)


def decode_state_shardings(state, mesh, cfg: ModelConfig, batch_size: int):
    """KV caches (ndim 5: L,B,S,KV,hd): S -> model, B -> data.
    SSM/conv states: a head/channel dim -> model, B -> data."""
    bspec = serve_batch_spec(mesh, batch_size)
    b_axis = bspec[0] if len(bspec) > 0 else None
    model_n = mesh.shape["model"] if "model" in mesh.axis_names else 0

    def leaf(x):
        shape = x.shape
        nd = len(shape)
        spec = [None] * nd
        b_dim = None
        for d in range(nd):
            if shape[d] == batch_size and b_axis is not None:
                spec[d] = b_axis
                b_dim = d
                break
        if model_n:
            if nd >= 5 and b_dim is not None and b_dim + 1 < nd \
                    and _divides(shape[b_dim + 1], model_n):
                # attention cache (..., B, S, KV, hd): shard S
                spec[b_dim + 1] = "model"
            else:
                # recurrent state: shard the first divisible feature dim
                # after batch (heads/channels)
                start = (b_dim + 1) if b_dim is not None else 0
                for d in range(start, nd):
                    if spec[d] is None and _divides(shape[d], model_n):
                        spec[d] = "model"
                        break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(leaf, state)


def replicated(mesh):
    return NamedSharding(mesh, P())


def gathered_grad_shardings(params, mesh):
    """Fully-replicated shardings for the stacked (k, *param) gradients —
    the dense O(d)-per-device baseline the shard-local contract replaces.
    Constraining the scan output to this forces the gather the legacy
    aggregation path implied; it exists so the pod sweep can RECORD that
    baseline's peak memory next to the partitioned path (the
    ``grad_mode="gathered"`` cells in BENCH_pod_sweeps.json)."""
    return jax.tree.map(lambda _: replicated(mesh), params)


def grad_shard_spec(mesh, cfg: ModelConfig | None = None, *,
                    mode: str = "gspmd", target_backend: str | None = "tpu"):
    """The ``ShardSpec`` matching :func:`stacked_grad_shardings`: stacked
    gradients partitioned over the mesh ``model`` axis (via TP/FSDP param
    dims), aggregation reductions left to GSPMD (``mode="gspmd"``), and
    ``round_backend`` dispatch pinned to the mesh's TARGET backend so
    dry-run lowering from a CPU host resolves the production path."""
    from repro.core.shard_aggregation import ShardSpec
    model_n = mesh.shape["model"] if "model" in mesh.axis_names else 1
    return ShardSpec(num_shards=model_n, mode=mode, axis="model",
                     target_backend=target_backend)


def opt_state_shardings(opt_state, params, mesh,
                        cfg: ModelConfig | None = None, *, fsdp: bool = True):
    pshard = param_shardings(params, mesh, cfg, fsdp=fsdp)
    pflat = jax.tree.leaves(pshard)
    leaves, treedef = jax.tree.flatten(opt_state)
    by_shape = {}
    pleaves = jax.tree.leaves(params)
    for pl_, sh in zip(pleaves, pflat):
        by_shape.setdefault(tuple(pl_.shape), sh)
    out = []
    for l in leaves:
        sh = by_shape.get(tuple(l.shape))
        out.append(sh if sh is not None and l.ndim > 0 else replicated(mesh))
    return jax.tree.unflatten(treedef, out)

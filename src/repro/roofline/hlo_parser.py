"""Structural HLO cost model with loop trip-count correction.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body **once**,
regardless of trip count — useless for scan-over-layers models (an 80-layer
model reports ~1 layer of FLOPs).  This parser walks the post-partitioning
per-device HLO text and accumulates:

  * ``dot_flops``          — 2 × |result| × |contracted dims| per dot op
  * ``collective_bytes``   — result bytes of all-gather / all-reduce /
                             reduce-scatter / all-to-all / collective-permute
  * ``bytes_accessed``     — operand-read + result-write bytes of every
                             materializing instruction (fusion internals are
                             registers and excluded; aliasing ops excluded)

each multiplied by the product of enclosing while-loop trip counts.  Trip
counts are read from the loop condition computation (the largest s32
constant compared against the induction variable — an upper bound for
early-exit loops like Weiszfeld, which is the conservative direction).

Known approximations (documented in EXPERIMENTS.md §Roofline):
  * elementwise FLOPs are ignored (dot-dominated workloads);
  * ``bytes_accessed`` assumes every instruction result materializes in HBM
    once per execution — XLA may keep small results in registers/cache, so
    this is an upper bound on HBM traffic;
  * dynamic trip counts use their static upper bound.
"""

from __future__ import annotations

import dataclasses
import math
import re

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "u4": 1, "s4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\],{}$ ])*?)\s*([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONST_RE = re.compile(r"\bs32\[\]\s+constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w\.\-]+)")

_ALIAS_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
              "bitcast", "after-all", "partition-id", "replica-id",
              "custom-call"}  # custom-call bytes unknowable; usually tiny here


def _shape_elems_bytes(text: str):
    elems = 0
    nbytes = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    result_text: str
    rest: str            # text after the op's opening paren (full tail)
    operands: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    shapes: dict          # instr name -> result_text


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                current = Computation(name=m.group(1), instrs=[], shapes={})
                comps[current.name] = current
            continue
        if line.strip() == "}":
            continue
        if current is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        name, body = im.group(1), im.group(2)
        om = _OP_RE.match(body)
        if not om:
            continue
        result_text, op = om.group(1), om.group(2)
        tail = body[om.end():]
        # operands live in the first balanced paren group
        depth, end = 1, len(tail)
        for i, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        operand_text = tail[:end]
        operands = _OPERAND_RE.findall(operand_text)
        ins = Instr(name=name, op=op, result_text=result_text,
                    rest=tail, operands=operands)
        current.instrs.append(ins)
        current.shapes[name] = result_text
    return comps


def _trip_count(cond: Computation) -> int:
    best = 1
    for ins in cond.instrs:
        for m in _CONST_RE.finditer(ins.result_text + " " + ins.rest):
            best = max(best, int(m.group(1)))
        if ins.op == "constant":
            m = re.search(r"s32\[\]", ins.result_text)
            c = re.search(r"constant\((\d+)\)", "constant(" + ins.rest)
            if m and c:
                best = max(best, int(c.group(1)))
    return best


def _dot_flops(ins: Instr, shapes: dict) -> float:
    _, _ = shapes, None
    res_elems, _ = _shape_elems_bytes(ins.result_text)
    cm = _CONTRACT_RE.search(ins.rest)
    if cm is None:
        return 2.0 * res_elems   # degenerate
    dims = [int(d) for d in cm.group(1).split(",") if d]
    lhs = ins.operands[0] if ins.operands else None
    lhs_shape_text = shapes.get(lhs, "")
    m = _SHAPE_RE.search(lhs_shape_text)
    contracted = 1
    if m and m.group(2):
        sizes = [int(d) for d in m.group(2).split(",")]
        for d in dims:
            if d < len(sizes):
                contracted *= sizes[d]
    return 2.0 * res_elems * contracted


@dataclasses.dataclass
class HloCost:
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: dict = dataclasses.field(
        default_factory=lambda: {op: 0.0 for op in _COLLECTIVES})
    max_trip_product: float = 1.0

    def add(self, other: "HloCost"):
        self.dot_flops += other.dot_flops
        self.bytes_accessed += other.bytes_accessed
        self.collective_bytes += other.collective_bytes
        for k, v in other.collective_breakdown.items():
            self.collective_breakdown[k] += v
        self.max_trip_product = max(self.max_trip_product,
                                    other.max_trip_product)


def _walk(comp: Computation, comps: dict, mult: float, cost: HloCost,
          in_fusion: bool, memo_shapes_cache: dict):
    cost.max_trip_product = max(cost.max_trip_product, mult)
    for ins in comp.instrs:
        op = ins.op
        base = op.replace("-start", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            _, nbytes = _shape_elems_bytes(ins.result_text)
            cost.collective_bytes += nbytes * mult
            cost.collective_breakdown[base] += nbytes * mult
        if op == "dot":
            cost.dot_flops += _dot_flops(ins, comp.shapes) * mult
        if op == "while":
            cm = _CALL_RE.findall(ins.rest)
            body_name = cond_name = None
            bm = re.search(r"body=%?([\w\.\-]+)", ins.rest)
            cm2 = re.search(r"condition=%?([\w\.\-]+)", ins.rest)
            if bm:
                body_name = bm.group(1)
            if cm2:
                cond_name = cm2.group(1)
            trips = _trip_count(comps[cond_name]) if cond_name in comps else 1
            if body_name in comps:
                _walk(comps[body_name], comps, mult * trips, cost,
                      in_fusion, memo_shapes_cache)
            continue
        if op in ("fusion", "call", "reduce", "sort", "scatter", "map",
                  "reduce-window", "select-and-scatter", "conditional"):
            for cname in _CALL_RE.findall(ins.rest):
                if cname in comps and cname != comp.name:
                    _walk(comps[cname], comps, mult, cost,
                          True, memo_shapes_cache)
        if not in_fusion and op not in _ALIAS_OPS and op != "while":
            if op == "dynamic-update-slice":
                # in-place on TPU: traffic = the update slice, not the buffer
                upd = ins.operands[1] if len(ins.operands) > 1 else None
                st = comp.shapes.get(upd)
                b = _shape_elems_bytes(st)[1] if st else 0
                cost.bytes_accessed += 2 * b * mult
                continue
            _, wbytes = _shape_elems_bytes(ins.result_text)
            rbytes = 0
            for o in ins.operands:
                st = comp.shapes.get(o)
                if st is not None:
                    _, b = _shape_elems_bytes(st)
                    rbytes += b
            cost.bytes_accessed += (wbytes + rbytes) * mult


def analyze(hlo_text: str, entry: str | None = None) -> HloCost:
    comps = parse_computations(hlo_text)
    if entry is None:
        # ENTRY computation: marked in header text
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
        entry = m.group(1) if m else next(iter(comps))
    cost = HloCost()
    _walk(comps[entry], comps, 1.0, cost, False, {})
    return cost

from repro.roofline.analysis import (  # noqa: F401
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    RooflineRecord,
    build_record,
    collective_bytes,
    format_table,
    model_flops,
)

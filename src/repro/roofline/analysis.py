"""Roofline analysis from compiled dry-run artifacts.

Terms per (arch × shape × mesh), computed from the *per-device* partitioned
HLO (jax's ``compiled.cost_analysis()`` / ``as_text()`` describe the
SPMD-partitioned per-device module, so every term below is per-chip; the
assignment's ``X/(chips × BW)`` formulas reduce to exactly this once X is
understood as the global quantity = chips × per-device):

    compute_term    = per_device_FLOPs / PEAK_FLOPS
    memory_term     = per_device_bytes_accessed / HBM_BW
    collective_term = per_device_collective_bytes / ICI_BW

collective bytes are parsed from the HLO text: the result-shape bytes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction (cost_analysis does not expose them).

Hardware constants (assignment): TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import json
import re

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# shapes like  f32[8,128]{1,0}  or  bf16[2,4,8]
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# instruction line:  %name = <result shapes> <op-name>(
_INSTR_RE = re.compile(
    r"=\s*(.*?)\s(" + "|".join(_COLLECTIVES) + r")(?:-(?:start|done))?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-collective-op result bytes summed over the module.

    ``-start``/``-done`` async pairs are counted once (on -start)."""
    out = {op: 0 for op in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if m is None:
            continue
        if "-done(" in line:           # async completion: already counted
            continue
        result_part, op = m.group(1), m.group(2)
        out[op] += _shape_bytes(result_part)
    return out


@dataclasses.dataclass
class RooflineRecord:
    arch: str
    shape: str
    mesh: str
    step: str
    flops_per_device: float           # trip-count-corrected dot FLOPs
    bytes_per_device: float           # trip-count-corrected bytes accessed
    collective_bytes_per_device: float  # trip-count-corrected
    collective_breakdown: dict
    peak_memory_bytes: float | None
    model_flops_global: float
    num_chips: int
    # raw XLA cost_analysis numbers (while bodies counted ONCE — kept for
    # cross-checking the parser; see hlo_parser docstring)
    xla_flops_raw: float = 0.0
    xla_bytes_raw: float = 0.0

    @property
    def compute_term(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_term(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_term(self) -> float:
        return self.collective_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_term, "memory": self.memory_term,
                 "collective": self.collective_term}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (chips × per-device HLO flops): how much of compiled
        compute is 'useful' (catches remat/redundancy waste).  > 1 means the
        compiler did *less* than the analytic count (e.g. decode reads)."""
        total = self.flops_per_device * self.num_chips
        return self.model_flops_global / total if total else float("nan")

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(compute_term=self.compute_term,
                 memory_term=self.memory_term,
                 collective_term=self.collective_term,
                 bottleneck=self.bottleneck,
                 useful_flops_ratio=self.useful_flops_ratio)
        return d


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D for training (N = active params,
    D = tokens), 2·N·D for inference forward passes."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def build_record(*, arch: str, shape, cfg, mesh_name: str, num_chips: int,
                 step: str, compiled, lowered=None) -> RooflineRecord:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):        # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        peak = float(getattr(mem, "temp_size_in_bytes", 0)
                     + getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "output_size_in_bytes", 0)
                     - getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        peak = None
    text = compiled.as_text()
    from repro.roofline import hlo_parser
    hc = hlo_parser.analyze(text)
    return RooflineRecord(
        arch=arch, shape=shape.name, mesh=mesh_name, step=step,
        flops_per_device=hc.dot_flops,
        bytes_per_device=hc.bytes_accessed,
        collective_bytes_per_device=hc.collective_bytes,
        collective_breakdown=dict(hc.collective_breakdown),
        peak_memory_bytes=peak,
        model_flops_global=model_flops(cfg, shape),
        num_chips=num_chips,
        xla_flops_raw=flops,
        xla_bytes_raw=nbytes,
    )


def sweep_entry(record: RooflineRecord, *, scenario: str) -> dict:
    """Per-scenario collective-cost record entry for the pod-sweep gate.

    The JSON-stable projection of a RooflineRecord keyed by scenario name:
    everything ``repro.sim.sweep``'s ``--check`` compares (total collective
    bytes, per-collective breakdown, compiled peak memory) plus the roofline
    context needed to read the record without re-deriving the setup.
    """
    return {
        "scenario": scenario,
        "arch": record.arch,
        "shape": record.shape,
        "mesh": record.mesh,
        "step": record.step,
        "num_chips": record.num_chips,
        "collective_bytes_per_device": record.collective_bytes_per_device,
        "collective_breakdown": dict(record.collective_breakdown),
        "peak_memory_bytes": record.peak_memory_bytes,
        "flops_per_device": record.flops_per_device,
        "bytes_per_device": record.bytes_per_device,
        "collective_term": record.collective_term,
        "bottleneck": record.bottleneck,
    }


def format_table(records: list[RooflineRecord]) -> str:
    header = ("| arch | shape | mesh | step | compute s | memory s | "
              "collective s | bottleneck | useful-FLOPs | peak GiB/chip |")
    sep = "|" + "---|" * 10
    rows = [header, sep]
    for r in records:
        peak = (f"{r.peak_memory_bytes / 2**30:.2f}"
                if r.peak_memory_bytes else "n/a")
        rows.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.step} "
            f"| {r.compute_term:.3e} | {r.memory_term:.3e} "
            f"| {r.collective_term:.3e} | {r.bottleneck} "
            f"| {r.useful_flops_ratio:.2f} | {peak} |")
    return "\n".join(rows)


def save_records(records: list[RooflineRecord], path: str):
    with open(path, "w") as f:
        json.dump([r.to_dict() for r in records], f, indent=1)


def load_records(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)

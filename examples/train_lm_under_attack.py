"""Train a (reduced) assigned-architecture LM under Byzantine attack.

Runs the same comparison as the paper — classical BGD vs Byzantine GD —
but on a non-convex transformer LM with the worker-mode robust step, for a
few hundred steps.  This is the end-to-end training driver of deliverable
(b); arch/attack/aggregator are CLI-selectable:

    PYTHONPATH=src python examples/train_lm_under_attack.py \
        --arch minitron-4b --steps 200

For production (pod-scale) training the same step lowers on the 16x16 mesh:
    PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-4b \
        --shape train_4k
"""

import argparse

import jax

from repro import optim
from repro.configs import ARCHITECTURES, get_config
from repro.core import RobustConfig, make_robust_train_step
from repro.data.tokens import TokenStream
from repro.models import model as M


def run(arch: str, aggregator: str, attack: str, steps: int, m: int = 8):
    cfg = get_config(arch).reduced()
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=64,
                         global_batch=16, num_workers=m, seed=0)
    rc = RobustConfig(num_workers=m, num_byzantine=2, attack=attack,
                      aggregator=aggregator, num_batches=8)
    opt = optim.adamw(1e-3)
    step = jax.jit(make_robust_train_step(
        lambda p, b: M.loss_fn(p, b, cfg), opt, rc))
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    trace = []
    for i in range(steps):
        params, opt_state, metrics = step(
            params, opt_state, stream.batch(i), jax.random.PRNGKey(5), i)
        loss = float(metrics["loss_median"])
        trace.append(loss)
        if i % max(steps // 10, 1) == 0:
            print(f"  step {i:4d}  loss {loss:.4f}")
    return trace


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="minitron-4b",
                   choices=list(ARCHITECTURES))
    p.add_argument("--steps", type=int, default=200)
    args = p.parse_args()

    results = {}
    for aggregator, attack in [("mean", "none"), ("mean", "sign_flip"),
                               ("gmom", "sign_flip")]:
        print(f"\n=== {args.arch}: aggregator={aggregator} "
              f"attack={attack} ===")
        results[(aggregator, attack)] = run(args.arch, aggregator, attack,
                                            args.steps)

    print("\nsummary (final loss):")
    for (agg, atk), trace in results.items():
        print(f"  {agg:5s} + {atk:10s}: {trace[0]:.3f} -> {trace[-1]:.3f}")
    clean = results[("mean", "none")][-1]
    robust = results[("gmom", "sign_flip")][-1]
    print(f"\nByzantine GD within {abs(robust - clean):.3f} nats of the "
          f"attack-free run; classical BGD diverged to "
          f"{results[('mean', 'sign_flip')][-1]:.2f}.")


if __name__ == "__main__":
    main()

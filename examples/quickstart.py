"""Quickstart: Byzantine Gradient Descent in ~40 lines.

Trains the paper's linear-regression model (§4) with m=20 workers of which
q=3 are Byzantine (omniscient sign-flip), comparing classical BGD (mean
aggregation, paper Algorithm 1) against the paper's geometric-median-of-means
(Algorithm 2).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import RobustConfig, make_robust_train_step, theory
from repro.data import regression

DIM, N, M_WORKERS, Q = 50, 20_000, 20, 3

key = jax.random.PRNGKey(0)
dataset = regression.generate(key, dim=DIM, total_samples=N,
                              num_workers=M_WORKERS)
batches = regression.worker_batches(dataset)

for aggregator in ("mean", "gmom"):
    rc = RobustConfig(
        num_workers=M_WORKERS,
        num_byzantine=Q,
        attack="sign_flip",          # Byzantine workers report -10x gradient
        aggregator=aggregator,       # "gmom" = the paper's Algorithm 2
    )
    optimizer = optim.paper_gd(theory.LINEAR_REGRESSION)   # eta = L/(2M^2)
    train_step = jax.jit(make_robust_train_step(
        regression.squared_loss, optimizer, rc))

    theta = jnp.zeros((DIM,))
    opt_state = optimizer.init(theta)
    for t in range(30):
        theta, opt_state, metrics = train_step(
            theta, opt_state, batches, jax.random.PRNGKey(1), t)

    err = float(jnp.linalg.norm(theta - dataset.theta_star))
    print(f"{aggregator:5s}: ||theta - theta*|| = {err:10.4f}  "
          f"({'BROKEN' if err > 1 else 'converged'})")

print(f"\ntheory floor ~ C_a*sqrt(dk/N) = "
      f"{theory.error_floor(DIM, N, rc.resolved_num_batches()):.4f}")

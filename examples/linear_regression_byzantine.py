"""The paper's linear-regression experiment (§4, Corollary 1), end to end.

Sweeps every attack in the zoo against every aggregator, prints the
convergence table, and checks the empirical contraction rate and error floor
against the paper's closed forms.

    PYTHONPATH=src python examples/linear_regression_byzantine.py
"""

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import (RobustConfig, byzantine, make_robust_train_step,
                        theory)
from repro.core.grouping import choose_num_batches
from repro.data import regression

DIM, N, M_WORKERS, Q, ROUNDS = 100, 50_000, 50, 4, 50


def run(aggregator: str, attack: str):
    key = jax.random.PRNGKey(0)
    ds = regression.generate(key, dim=DIM, total_samples=N,
                             num_workers=M_WORKERS)
    k = choose_num_batches(M_WORKERS, Q)
    rc = RobustConfig(num_workers=M_WORKERS, num_byzantine=Q,
                      num_batches=k, attack=attack, aggregator=aggregator)
    opt = optim.paper_gd(theory.LINEAR_REGRESSION)
    step = jax.jit(make_robust_train_step(regression.squared_loss, opt, rc))
    theta = jnp.zeros((DIM,))
    opt_state = opt.init(theta)
    batches = regression.worker_batches(ds)
    errs = []
    for t in range(ROUNDS):
        errs.append(float(jnp.linalg.norm(theta - ds.theta_star)))
        theta, opt_state, _ = step(theta, opt_state, batches,
                                   jax.random.PRNGKey(1), t)
    errs.append(float(jnp.linalg.norm(theta - ds.theta_star)))
    return errs, k


def main():
    print(f"linear regression: d={DIM} N={N} m={M_WORKERS} q={Q}")
    print(f"theory: eta = {theory.LINEAR_REGRESSION.step_size}, "
          f"contraction = {theory.LINEAR_REGRESSION.theorem1_contraction:.4f}"
          f" (Cor. 1: 1/2 + sqrt(3)/4)")
    print()
    header = f"{'aggregator':18s} {'attack':18s} {'err@0':>8s} " \
             f"{'err@10':>8s} {'err@final':>10s}"
    print(header)
    print("-" * len(header))
    for attack in byzantine.available():
        for aggregator in (["mean", "gmom"] if attack != "none"
                           else ["mean"]):
            errs, k = run(aggregator, attack)
            print(f"{aggregator:18s} {attack:18s} {errs[0]:8.3f} "
                  f"{errs[10]:8.3f} {errs[-1]:10.4f}")
    print()
    print(f"error floor (Thm 5, c2=1): "
          f"{theory.error_floor(DIM, N, k):.4f}; "
          f"centralized minimax sqrt(d/N) = {(DIM / N) ** 0.5:.4f}")


if __name__ == "__main__":
    main()

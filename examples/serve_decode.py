"""Batched serving demo: prefill a batch of prompts, then decode tokens.

Exercises the serve path (the one decode_32k / long_500k lower at pod
scale): KV-cache/recurrent-state construction, batched single-token
decode_step, and greedy sampling, on a reduced config on CPU.

    PYTHONPATH=src python examples/serve_decode.py --arch h2o-danube-3-4b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHITECTURES, get_config
from repro.models import model as M


def prefill_into_state(params, cfg, tokens, state):
    """Feed the prompt through decode_step token by token (simple reference
    prefill; pod-scale prefill uses the batched forward — see
    repro.launch.steps.make_prefill_step)."""
    B, T = tokens.shape
    step = jax.jit(lambda s, t, p: M.decode_step(params, cfg, s, t, p))
    logits = None
    for t in range(T):
        logits, state = step(state, tokens[:, t:t + 1],
                             jnp.full((B,), t, jnp.int32))
    return logits, state


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="h2o-danube-3-4b",
                   choices=list(ARCHITECTURES))
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=32)
    p.add_argument("--new-tokens", type=int, default=32)
    args = p.parse_args()

    cfg = get_config(args.arch).reduced()
    if cfg.family == "hybrid":
        cfg = cfg.with_(ssm_chunk=8)
    params = M.init(jax.random.PRNGKey(0), cfg)
    B = args.batch
    max_len = args.prompt_len + args.new_tokens
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (B, args.prompt_len), 0, cfg.vocab_size)

    state = M.init_decode_state(cfg, B, max_len)
    print(f"{args.arch}: state leaves "
          f"{[l.shape for l in jax.tree.leaves(state)][:4]} ...")

    t0 = time.time()
    logits, state = prefill_into_state(params, cfg, prompts, state)
    print(f"prefill {args.prompt_len} tokens x{B}: {time.time() - t0:.2f}s")

    step = jax.jit(lambda s, t, p: M.decode_step(params, cfg, s, t, p))
    tokens = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    generated = [tokens]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        pos = jnp.full((B,), args.prompt_len + i, jnp.int32)
        logits, state = step(state, tokens, pos)
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        generated.append(tokens)
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decoded {args.new_tokens} tokens x{B} in {dt:.2f}s "
          f"({B * args.new_tokens / max(dt, 1e-9):.1f} tok/s on CPU)")
    print("sample token ids:", out[0, :16].tolist())


if __name__ == "__main__":
    main()

"""Shared helpers for the benchmark harness."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import RobustConfig, make_robust_train_step, theory
from repro.data import regression

RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")


def ensure_results_dir():
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return RESULTS_DIR


def save_json(name: str, payload):
    ensure_results_dir()
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


BENCH_DIR = os.path.dirname(os.path.abspath(__file__))


def save_bench(name: str, payload: dict) -> str:
    """Write a CHECKED-IN benchmark record ``benchmarks/BENCH_<name>.json``.

    Unlike ``save_json`` (scratch output under benchmarks/results/, not
    committed), BENCH files are committed with the PR that produced them so
    reviewers and later sessions can read the performance trajectory from
    git history.  See docs/BENCHMARKS.md for the workflow and field
    conventions.  Metadata records the backend the numbers were taken on —
    a fused-kernel speedup measured on CPU says nothing about TPU and
    vice versa.
    """
    record = {
        "bench": name,
        "recorded_unix": int(time.time()),
        "backend": jax.default_backend(),
        "jax_version": jax.__version__,
        "cpu_count": os.cpu_count(),
        **payload,
    }
    path = os.path.join(BENCH_DIR, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1, sort_keys=False)
        f.write("\n")
    return path


def ab_time(fn_a, fn_b, *args, iters: int = 30, warmup: int = 5):
    """Interleaved A/B wall-time (median us per call for each function).

    Alternating the two measurements inside one loop cancels machine-load
    drift that back-to-back loops pick up — required for honest fused vs
    unfused comparisons on shared CI hosts.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        tb.append(time.perf_counter() - t0)
    med = lambda ts: float(sorted(ts)[len(ts) // 2] * 1e6)  # noqa: E731
    return med(ta), med(tb)


def run_linreg(*, dim, total_samples, num_workers, num_byzantine,
               num_batches, attack, aggregator, rounds, seed=0,
               rotate=True, trim_multiplier=3.0, eta=None):
    """One Byzantine-GD linreg run; returns per-round error trace
    ||theta_t - theta*||."""
    key = jax.random.PRNGKey(seed)
    ds = regression.generate(key, dim=dim, total_samples=total_samples,
                             num_workers=num_workers)
    rc = RobustConfig(num_workers=num_workers, num_byzantine=num_byzantine,
                      num_batches=num_batches, attack=attack,
                      aggregator=aggregator, rotate_byzantine=rotate,
                      trim_multiplier=trim_multiplier)
    opt = optim.sgd(eta if eta is not None
                    else theory.LINEAR_REGRESSION.step_size)
    step = jax.jit(make_robust_train_step(regression.squared_loss, opt, rc))
    theta = jnp.zeros((dim,))
    opt_state = opt.init(theta)
    batches = regression.worker_batches(ds)
    errs = []
    for t in range(rounds):
        errs.append(float(jnp.linalg.norm(theta - ds.theta_star)))
        theta, opt_state, _ = step(theta, opt_state, batches,
                                   jax.random.fold_in(key, 777), t)
    errs.append(float(jnp.linalg.norm(theta - ds.theta_star)))
    return errs, ds


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.elapsed = time.perf_counter() - self.t0


def time_call(fn, *args, iters=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out   # us per call

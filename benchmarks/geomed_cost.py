"""Benchmark 4 — server-side aggregation cost (paper §1.4 / Remark 2).

Claims checked:
  (a) Weiszfeld reaches the (1+gamma)-approximation with gamma = 1/N in few
      iterations (the paper invokes [CLM+16]'s O(qd log^3 N); we substitute
      Weiszfeld — DESIGN.md §3 — and measure its iteration count & wall time).
  (b) cost scales ~ linearly in d and in k (the paper's O(md + qd log^3 N)
      is linear in d at fixed k).
  (c) the fused Pallas kernel step agrees with the jnp step (interpret mode)
      and its VMEM working set stays in budget.
  (d) the fused ROUND kernel (kernels/geomed/round.py: grads -> batch means
      -> trim -> full Weiszfeld in one VMEM-resident pass) is bit-identical
      to its jnp reference in interpret mode, its resident set stays in
      budget across the (k, d) sweep, and the fused formulation's wall time
      is recorded against the unfused pipeline (the checked-in
      BENCH_round_kernel.json carries the full sweep; see docs/BENCHMARKS.md).
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import save_json, time_call
from repro.core.geometric_median import geometric_median, weiszfeld_step


def iterations_to_gamma(points, gamma):
    """# Weiszfeld iterations until objective <= (1+gamma) * best."""
    pts = jnp.asarray(points)
    w = jnp.ones((pts.shape[0],), jnp.float32)

    def obj(y):
        return float(jnp.sum(jnp.linalg.norm(pts - y[None], axis=1)))

    best = obj(np.asarray(geometric_median(pts, max_iters=512, tol=1e-12)))
    y = jnp.mean(pts, axis=0)
    for it in range(1, 200):
        y = weiszfeld_step(pts, y, w, 1e-12)
        if obj(y) <= (1 + gamma) * best + 1e-12:
            return it
    return 200


def main() -> dict:
    rng = np.random.default_rng(0)
    out = {}

    # (a) iterations vs gamma (k=20 batch means, d=1000)
    pts = rng.normal(size=(20, 1000)).astype(np.float32)
    gammas = [1e-2, 1e-4, 1e-6, 1e-8]
    iters = [iterations_to_gamma(pts, g) for g in gammas]
    out["iters_vs_gamma"] = {"gamma": gammas, "iters": iters}
    for g, i in zip(gammas, iters):
        print(f"geomed_cost,gamma={g:.0e},iters={i}")

    # (b) wall time vs d and k (jit'd full geomed, CPU)
    times_d = []
    for d in [100, 1000, 10_000, 100_000]:
        pts = jnp.asarray(rng.normal(size=(20, d)).astype(np.float32))
        fn = jax.jit(lambda p: geometric_median(p, max_iters=32))
        us, _ = time_call(fn, pts, iters=3)
        times_d.append(us)
        print(f"geomed_cost,d={d},us_per_call={us:.0f}")
    out["time_vs_d"] = {"d": [100, 1000, 10_000, 100_000], "us": times_d}

    times_k = []
    for k in [4, 8, 16, 32, 64]:
        pts = jnp.asarray(rng.normal(size=(k, 10_000)).astype(np.float32))
        fn = jax.jit(lambda p: geometric_median(p, max_iters=32))
        us, _ = time_call(fn, pts, iters=3)
        times_k.append(us)
        print(f"geomed_cost,k={k},us_per_call={us:.0f}")
    out["time_vs_k"] = {"k": [4, 8, 16, 32, 64], "us": times_k}

    # (c) kernel step agreement + VMEM budget
    from repro.kernels.geomed import geomed as gk, ref as gref
    pts = jnp.asarray(rng.normal(size=(32, 8192)).astype(np.float32))
    y = jnp.mean(pts, axis=0)
    w = jnp.ones((32,))
    kout = gk.weiszfeld_step(pts, y, w, interpret=True)
    rout = gref.weiszfeld_step_ref(pts, y, w)
    err = float(jnp.max(jnp.abs(kout - rout)))
    vmem_bytes = 32 * gk.TILE_D * 4 * 2   # z tile + partials, double-buffered
    out["kernel"] = {"max_err_vs_ref": err, "tile_d": gk.TILE_D,
                     "vmem_working_set_bytes": vmem_bytes,
                     "vmem_budget_bytes": 16 * 2**20}
    print(f"geomed_cost,kernel_err={err:.2e},"
          f"vmem_working_set={vmem_bytes/2**10:.0f}KiB")

    # (d) fused round kernel: bit-agreement, VMEM residency, fused-vs-unfused
    from benchmarks.common import ab_time
    from repro.core import aggregators
    from repro.core.grouping import make_grouping
    from repro.kernels.geomed import round as round_kernel

    rows = []
    for (m, k, d) in [(20, 10, 1000), (50, 11, 4096)]:
        g = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        grouping = make_grouping(m, k)
        ker = round_kernel.round_aggregate_kernel(g, grouping,
                                                  interpret=True,
                                                  max_iters=16)
        ref = round_kernel.round_aggregate_ref(g, grouping, max_iters=16)
        unfused = jax.jit(lambda x, k=k: aggregators.gmom_aggregator(
            x, num_batches=k, round_backend="reference", max_iters=16))
        fused = jax.jit(lambda x, grouping=grouping:
                        round_kernel.round_aggregate_ref(
                            x, grouping, max_iters=16))
        tu, tf = ab_time(unfused, fused, g, iters=15)
        resident = round_kernel.round_resident_bytes(m, k, d)
        rows.append({
            "m": m, "k": k, "d": d,
            "bit_identical": bool(np.array_equal(np.asarray(ker),
                                                 np.asarray(ref))),
            "unfused_us": tu, "fused_us": tf,
            "vmem_resident_bytes": resident,
            "vmem_budget_bytes": round_kernel.VMEM_BUDGET_BYTES,
        })
        print(f"geomed_cost,round_kernel,m={m},k={k},d={d},"
              f"bit_identical={rows[-1]['bit_identical']},"
              f"resident={resident / 2**10:.0f}KiB")
    out["round_kernel"] = rows

    save_json("geomed_cost.json", out)
    return out


if __name__ == "__main__":
    main()

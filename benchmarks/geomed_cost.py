"""Benchmark 4 — server-side aggregation cost (paper §1.4 / Remark 2).

Claims checked:
  (a) Weiszfeld reaches the (1+gamma)-approximation with gamma = 1/N in few
      iterations (the paper invokes [CLM+16]'s O(qd log^3 N); we substitute
      Weiszfeld — DESIGN.md §3 — and measure its iteration count & wall time).
  (b) cost scales ~ linearly in d and in k (the paper's O(md + qd log^3 N)
      is linear in d at fixed k).
  (c) the fused Pallas kernel step agrees with the jnp step (interpret mode)
      and its VMEM working set stays in budget.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import save_json, time_call
from repro.core.geometric_median import geometric_median, weiszfeld_step


def iterations_to_gamma(points, gamma):
    """# Weiszfeld iterations until objective <= (1+gamma) * best."""
    pts = jnp.asarray(points)
    w = jnp.ones((pts.shape[0],), jnp.float32)

    def obj(y):
        return float(jnp.sum(jnp.linalg.norm(pts - y[None], axis=1)))

    best = obj(np.asarray(geometric_median(pts, max_iters=512, tol=1e-12)))
    y = jnp.mean(pts, axis=0)
    for it in range(1, 200):
        y = weiszfeld_step(pts, y, w, 1e-12)
        if obj(y) <= (1 + gamma) * best + 1e-12:
            return it
    return 200


def main() -> dict:
    rng = np.random.default_rng(0)
    out = {}

    # (a) iterations vs gamma (k=20 batch means, d=1000)
    pts = rng.normal(size=(20, 1000)).astype(np.float32)
    gammas = [1e-2, 1e-4, 1e-6, 1e-8]
    iters = [iterations_to_gamma(pts, g) for g in gammas]
    out["iters_vs_gamma"] = {"gamma": gammas, "iters": iters}
    for g, i in zip(gammas, iters):
        print(f"geomed_cost,gamma={g:.0e},iters={i}")

    # (b) wall time vs d and k (jit'd full geomed, CPU)
    times_d = []
    for d in [100, 1000, 10_000, 100_000]:
        pts = jnp.asarray(rng.normal(size=(20, d)).astype(np.float32))
        fn = jax.jit(lambda p: geometric_median(p, max_iters=32))
        us, _ = time_call(fn, pts, iters=3)
        times_d.append(us)
        print(f"geomed_cost,d={d},us_per_call={us:.0f}")
    out["time_vs_d"] = {"d": [100, 1000, 10_000, 100_000], "us": times_d}

    times_k = []
    for k in [4, 8, 16, 32, 64]:
        pts = jnp.asarray(rng.normal(size=(k, 10_000)).astype(np.float32))
        fn = jax.jit(lambda p: geometric_median(p, max_iters=32))
        us, _ = time_call(fn, pts, iters=3)
        times_k.append(us)
        print(f"geomed_cost,k={k},us_per_call={us:.0f}")
    out["time_vs_k"] = {"k": [4, 8, 16, 32, 64], "us": times_k}

    # (c) kernel step agreement + VMEM budget
    from repro.kernels.geomed import geomed as gk, ref as gref
    pts = jnp.asarray(rng.normal(size=(32, 8192)).astype(np.float32))
    y = jnp.mean(pts, axis=0)
    w = jnp.ones((32,))
    kout = gk.weiszfeld_step(pts, y, w, interpret=True)
    rout = gref.weiszfeld_step_ref(pts, y, w)
    err = float(jnp.max(jnp.abs(kout - rout)))
    vmem_bytes = 32 * gk.TILE_D * 4 * 2   # z tile + partials, double-buffered
    out["kernel"] = {"max_err_vs_ref": err, "tile_d": gk.TILE_D,
                     "vmem_working_set_bytes": vmem_bytes,
                     "vmem_budget_bytes": 16 * 2**20}
    print(f"geomed_cost,kernel_err={err:.2e},"
          f"vmem_working_set={vmem_bytes/2**10:.0f}KiB")

    save_json("geomed_cost.json", out)
    return out


if __name__ == "__main__":
    main()

"""Benchmark 5 — communication & rounds (paper §1.4: O(log N) rounds,
O(md log N) total communication).

Checks that the number of rounds to reach within 2x of the error floor grows
~ logarithmically with N, and derives the per-round communication volume of
the TPU mapping from the dry-run collective bytes (worker->server d-vector
pushes map to the gradient reduce/gather collectives).
"""

from __future__ import annotations

import math
import os

import numpy as np

from benchmarks.common import run_linreg, save_json


def rounds_to_converge(errs, floor):
    for t, e in enumerate(errs):
        if e <= 2.0 * floor:
            return t
    return len(errs)


def main() -> dict:
    out = {"rounds_vs_N": []}
    for N in [2_000, 8_000, 32_000, 128_000]:
        errs, _ = run_linreg(dim=20, total_samples=N, num_workers=20,
                             num_byzantine=2, num_batches=10,
                             attack="sign_flip", aggregator="gmom",
                             rounds=60)
        floor = errs[-1]
        r = rounds_to_converge(errs, max(floor, 1e-8))
        out["rounds_vs_N"].append({"N": N, "rounds": r, "logN": math.log(N)})
        print(f"communication,N={N},rounds_to_2x_floor={r}")

    # per-round communication of the TPU mapping, from the dry-run records
    roofline_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                 "roofline_singlepod.json")
    if os.path.exists(roofline_path):
        import json
        with open(roofline_path) as f:
            recs = json.load(f)
        trains = [r for r in recs if r["step"] == "train_step"]
        out["per_round_collective_bytes_per_chip"] = {
            r["arch"]: r["collective_bytes_per_device"] for r in trains}
        for r in trains:
            print(f"communication,{r['arch']},collective_GB_per_chip_round="
                  f"{r['collective_bytes_per_device']/1e9:.1f}")
    save_json("communication.json", out)
    return out


if __name__ == "__main__":
    main()

"""Benchmark 3 — breakdown point (Lemma 1 / Theorem 1 tolerance region).

The guarantee needs 2(1+eps)q <= k, i.e. < 1/2 of batches contaminated.
Sweep q with fixed k and verify: convergence below the threshold, breakdown
at/above it — locating the empirical breakdown against alpha = 1/2.
"""

from __future__ import annotations

from benchmarks.common import run_linreg, save_json

M = 24
K = 12
DIM = 30
N = 24_000


def main() -> list[dict]:
    rows = []
    b = M // K
    for q in [0, 1, 2, 3, 4, 5, 6, 7, 8, 10, 12, 14, 16]:
        errs, _ = run_linreg(
            dim=DIM, total_samples=N, num_workers=M, num_byzantine=q,
            num_batches=K, attack="mean_shift", aggregator="gmom",
            rounds=40, rotate=False,   # fixed set: workers 0..q-1, so they
            trim_multiplier=None)      # contaminate ceil(q/b) batches
        bad_batches = -(-q // b)       # ceil
        frac = bad_batches / K
        ok = errs[-1] < 1.0
        rows.append({"q": q, "k": K, "bad_batches": bad_batches,
                     "contaminated_batch_fraction": frac,
                     "final_error": errs[-1], "converged": ok})
        print(f"breakdown,q={q},bad_batches={bad_batches},"
              f"frac={frac:.2f},err={errs[-1]:.3f},converged={ok}")
    # theoretical guarantee boundary: largest q with 2(1+eps)q <= k
    save_json("breakdown.json", {
        "rows": rows,
        "theory_guaranteed_q": int(K / 2.2),
        "median_breakdown_fraction": 0.5,
    })
    return rows


if __name__ == "__main__":
    main()

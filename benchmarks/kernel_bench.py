"""Benchmark 6 — kernel microbenchmarks.

CPU wall-times of the jnp oracles (the compiled path this container runs)
plus interpret-mode agreement checks for the Pallas TPU kernels.  On real
TPU hardware the same harness times the pallas path (use_pallas=True).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_json, time_call
from repro.kernels.attention import flash, ref as attn_ref
from repro.kernels.geomed import ops as geomed_ops
from repro.core.geometric_median import geometric_median


def main() -> dict:
    rng = np.random.default_rng(0)
    out = {"attention": [], "geomed": []}

    for (B, T, H, KV, hd) in [(1, 512, 8, 2, 64), (1, 1024, 8, 8, 64),
                              (2, 2048, 4, 1, 128)]:
        q = jnp.asarray(rng.normal(size=(B, T, H, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, T, KV, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, T, KV, hd)).astype(np.float32))
        fn = jax.jit(lambda a, b, c: attn_ref.flash_attention_ref(
            a, b, c, causal=True))
        us, ref_out = time_call(fn, q, k, v, iters=3)
        flops = 4.0 * B * H * T * T * hd / 2      # causal half
        row = {"B": B, "T": T, "H": H, "KV": KV, "hd": hd,
               "ref_us": us, "ref_gflops": flops / us / 1e3}
        # interpret-mode agreement on a slice (full interpret is slow)
        small = min(T, 256)
        kout = flash.flash_attention(
            q[:, :small], k[:, :small], v[:, :small], causal=True,
            block_q=128, block_kv=128, interpret=True)
        rout = attn_ref.flash_attention_ref(
            q[:, :small], k[:, :small], v[:, :small], causal=True)
        row["kernel_max_err"] = float(jnp.max(jnp.abs(kout - rout)))
        out["attention"].append(row)
        print(f"kernel_bench,attention,T={T},us={us:.0f},"
              f"err={row['kernel_max_err']:.1e}")

    for (k_, d) in [(8, 10_000), (32, 100_000), (8, 1_000_000)]:
        pts = jnp.asarray(rng.normal(size=(k_, d)).astype(np.float32))
        fn = jax.jit(lambda p: geometric_median(p, max_iters=16))
        us, _ = time_call(fn, pts, iters=3)
        row = {"k": k_, "d": d, "jnp_us": us,
               "hbm_passes_per_iter_jnp": 3, "hbm_passes_per_iter_kernel": 2}
        if d <= 100_000:
            kout = geomed_ops.geometric_median_kernel(pts, interpret=True,
                                                      max_iters=16)
            jout = geometric_median(pts, max_iters=16)
            row["kernel_max_err"] = float(jnp.max(jnp.abs(kout - jout)))
        out["geomed"].append(row)
        print(f"kernel_bench,geomed,k={k_},d={d},us={us:.0f}")

    save_json("kernel_bench.json", out)
    return out


if __name__ == "__main__":
    main()

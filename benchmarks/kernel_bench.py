"""Benchmark 6 — kernel microbenchmarks.

CPU wall-times of the jnp oracles (the compiled path this container runs)
plus interpret-mode agreement checks for the Pallas TPU kernels.  On real
TPU hardware the same harness times the pallas path (use_pallas=True).

The fused-round section compares the fused round formulation
(kernels/geomed/round.py: membership-matmul batch means + resident
Weiszfeld, the Pallas kernel's algorithm) against the unfused pre-PR
pipeline across (m, k, d) sweeps, and records the result to the CHECKED-IN
``benchmarks/BENCH_round_kernel.json`` (see docs/BENCHMARKS.md).  The
headline rows run the paper-scale server configuration m=50, q=5: pre-PR
the divisibility constraint k | m forced k=25 there, while the fused
kernel's membership matmul supports the paper's exact k=11 — so the
post-PR fused round beats the pre-PR unfused round end to end on this
backend, on top of the TPU HBM-pass reduction the kernel itself buys
(modeled in the ``hbm_model`` section).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ab_time, save_bench, save_json, time_call
from repro.kernels.attention import flash, ref as attn_ref
from repro.kernels.geomed import ops as geomed_ops
from repro.kernels.geomed import round as round_kernel
from repro.core import aggregators
from repro.core.geometric_median import geometric_median
from repro.core.grouping import choose_num_batches, make_grouping
from repro.core.robust_train import per_worker_grads
from repro.data import regression


def main() -> dict:
    rng = np.random.default_rng(0)
    out = {"attention": [], "geomed": []}

    for (B, T, H, KV, hd) in [(1, 512, 8, 2, 64), (1, 1024, 8, 8, 64),
                              (2, 2048, 4, 1, 128)]:
        q = jnp.asarray(rng.normal(size=(B, T, H, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, T, KV, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, T, KV, hd)).astype(np.float32))
        fn = jax.jit(lambda a, b, c: attn_ref.flash_attention_ref(
            a, b, c, causal=True))
        us, ref_out = time_call(fn, q, k, v, iters=3)
        flops = 4.0 * B * H * T * T * hd / 2      # causal half
        row = {"B": B, "T": T, "H": H, "KV": KV, "hd": hd,
               "ref_us": us, "ref_gflops": flops / us / 1e3}
        # interpret-mode agreement on a slice (full interpret is slow)
        small = min(T, 256)
        kout = flash.flash_attention(
            q[:, :small], k[:, :small], v[:, :small], causal=True,
            block_q=128, block_kv=128, interpret=True)
        rout = attn_ref.flash_attention_ref(
            q[:, :small], k[:, :small], v[:, :small], causal=True)
        row["kernel_max_err"] = float(jnp.max(jnp.abs(kout - rout)))
        out["attention"].append(row)
        print(f"kernel_bench,attention,T={T},us={us:.0f},"
              f"err={row['kernel_max_err']:.1e}")

    for (k_, d) in [(8, 10_000), (32, 100_000), (8, 1_000_000)]:
        pts = jnp.asarray(rng.normal(size=(k_, d)).astype(np.float32))
        fn = jax.jit(lambda p: geometric_median(p, max_iters=16))
        us, _ = time_call(fn, pts, iters=3)
        row = {"k": k_, "d": d, "jnp_us": us,
               "hbm_passes_per_iter_jnp": 3, "hbm_passes_per_iter_kernel": 2}
        if d <= 100_000:
            kout = geomed_ops.geometric_median_kernel(pts, interpret=True,
                                                      max_iters=16)
            jout = geometric_median(pts, max_iters=16)
            row["kernel_max_err"] = float(jnp.max(jnp.abs(kout - jout)))
        out["geomed"].append(row)
        print(f"kernel_bench,geomed,k={k_},d={d},us={us:.0f}")

    out["round_kernel"] = round_kernel_bench()
    save_json("kernel_bench.json", out)
    return out


def _hbm_bytes_per_round(m, k, d, iters):
    """Modeled HBM traffic per aggregation round (f32), the quantity the
    fused kernel actually optimizes on TPU: unfused materializes the batch
    means and re-reads them every Weiszfeld iteration at HBM level; the
    fused kernel reads the stacked gradients once and keeps Z in VMEM."""
    unfused = 4 * (m * d            # read stacked gradients for the means
                   + k * d          # write batch means
                   + k * d          # read means for trim norms
                   + iters * 2 * k * d   # sqdist + reweight passes per iter
                   + d)             # write aggregate
    fused = 4 * (m * d + d)         # one streamed read of G, one write of y
    return unfused, fused


def round_kernel_bench() -> dict:
    """Fused vs unfused round across (m, k, d); records BENCH_round_kernel."""
    rng = np.random.default_rng(0)
    rec: dict = {"same_k": [], "paper_scale": [], "linreg_full_round": [],
                 "hbm_model": []}

    # (a) same-(m, k, d) formulation comparison + interpret agreement.
    for (m, k, d) in [(20, 10, 1000), (50, 11, 1000), (50, 11, 10_000),
                      (50, 11, 100_000)]:
        g = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        grouping = make_grouping(m, k)
        unfused = jax.jit(lambda x, k=k: aggregators.gmom_aggregator(
            x, num_batches=k, round_backend="reference", max_iters=32))
        fused = jax.jit(lambda x, grouping=grouping:
                        round_kernel.round_aggregate_ref(
                            x, grouping, max_iters=32))
        tu, tf = ab_time(unfused, fused, g)
        row = {"m": m, "k": k, "d": d, "unfused_us": tu, "fused_us": tf,
               "speedup": tu / tf,
               "max_err": float(jnp.max(jnp.abs(unfused(g) - fused(g))))}
        if d <= 10_000:   # interpret mode is slow; bit-check the small rows
            ker = round_kernel.round_aggregate_kernel(
                g, grouping, interpret=True, max_iters=32)
            row["kernel_bit_identical"] = bool(
                np.array_equal(np.asarray(ker), np.asarray(fused(g))))
        rec["same_k"].append(row)
        print(f"kernel_bench,round_same_k,m={m},k={k},d={d},"
              f"speedup={row['speedup']:.2f}")

    # (b) headline: the paper-scale server config m=50, q=5.  Pre-PR the
    # k | m constraint forced k=25; the fused round runs the paper's k=11.
    m, q = 50, 5
    k_pre = choose_num_batches(m, q)          # 25: smallest divisor >= 11
    k_paper = 11
    for d in [1000, 10_000, 100_000]:
        g = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        grouping = make_grouping(m, k_paper)
        unfused = jax.jit(lambda x: aggregators.gmom_aggregator(
            x, num_batches=k_pre, round_backend="reference", max_iters=32))
        fused = jax.jit(lambda x: round_kernel.round_aggregate_ref(
            x, grouping, max_iters=32))
        tu, tf = ab_time(unfused, fused, g)
        rec["paper_scale"].append({
            "m": m, "q": q, "d": d, "k_unfused": k_pre, "k_fused": k_paper,
            "unfused_us": tu, "fused_us": tf, "speedup": tu / tf})
        print(f"kernel_bench,round_paper_scale,m={m},q={q},d={d},"
              f"k={k_pre}->{k_paper},speedup={tu / tf:.2f}")

    # (c) the whole linreg round (paper §4): per-worker gradients computed
    # inside the fused formulation vs vmap(value_and_grad) + unfused gmom.
    for (n, d) in [(40, 1000), (40, 10_000), (8, 100_000)]:
        x = jnp.asarray(rng.normal(size=(m, n, d)).astype(np.float32))
        t = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
        theta = jnp.zeros((d,), jnp.float32)
        grouping = make_grouping(m, k_paper)

        def unfused_round(th, xx, tt):
            grads, _ = per_worker_grads(regression.squared_loss, th,
                                        (xx, tt))
            return aggregators.gmom_aggregator(
                grads, num_batches=k_pre, round_backend="reference",
                max_iters=32)

        unfused = jax.jit(unfused_round)
        fused = jax.jit(lambda th, xx, tt: round_kernel.linreg_round_fused(
            xx, tt, th, grouping, max_iters=32))
        tu, tf = ab_time(unfused, fused, theta, x, t)
        rec["linreg_full_round"].append({
            "m": m, "n": n, "d": d, "k_unfused": k_pre, "k_fused": k_paper,
            "unfused_us": tu, "fused_us": tf, "speedup": tu / tf})
        print(f"kernel_bench,round_linreg,m={m},n={n},d={d},"
              f"speedup={tu / tf:.2f}")

    # (d) modeled TPU HBM traffic (what VMEM-residency saves per round).
    for (mm, kk, dd) in [(50, 11, 1000), (50, 11, 100_000), (64, 16, 10_000)]:
        unf_b, fus_b = _hbm_bytes_per_round(mm, kk, dd, iters=16)
        rec["hbm_model"].append({
            "m": mm, "k": kk, "d": dd, "weiszfeld_iters": 16,
            "unfused_hbm_bytes": unf_b, "fused_hbm_bytes": fus_b,
            "traffic_ratio": unf_b / fus_b})

    worst = min(r["speedup"] for r in rec["paper_scale"])
    rec["summary"] = {
        "paper_scale_min_speedup": worst,
        "fused_beats_unfused_at_paper_scale": bool(worst > 1.0),
        "note": "paper_scale compares the pre-PR server round (k|m forced "
                "k=25 at m=50, q=5; unfused jnp pipeline) against the "
                "fused round formulation at the paper's exact k=11, which "
                "the membership-matmul kernel design makes representable.",
    }
    save_bench("round_kernel", rec)
    return rec


if __name__ == "__main__":
    main()

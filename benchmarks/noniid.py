"""Benchmark 10 — beyond-paper: non-iid (federated-realistic) workers.

The paper assumes iid samples across workers and notes the extension to
heterogeneous settings only in passing (§1.2: "our results can be extended
to the heterogeneous data sizes setting when the data sizes are of the same
order").  Federated deployments are distribution-heterogeneous, not just
size-heterogeneous — each device's data is scaled/shifted differently.
Sweep a covariate/noise heterogeneity factor h and measure whether GMoM's
robustness degrades gracefully (batch means remain unbiased estimates of
the same population gradient, so the theory's core mechanism should
survive mild heterogeneity with an inflated effective variance).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import save_json
from repro import optim
from repro.core import RobustConfig, make_robust_train_step
from repro.data import regression

DIM, N, M, Q = 50, 40_000, 20, 3


def run(h, attack, aggregator, seed=0):
    key = jax.random.PRNGKey(seed)
    ds = regression.generate(key, dim=DIM, total_samples=N, num_workers=M,
                             heterogeneity=h)
    rc = RobustConfig(num_workers=M, num_byzantine=Q, num_batches=10,
                      attack=attack, aggregator=aggregator)
    opt = optim.sgd(0.4)   # eta slightly below 1/2: hetero inflates M
    step = jax.jit(make_robust_train_step(regression.squared_loss, opt, rc))
    theta = jnp.zeros((DIM,))
    opt_state = opt.init(theta)
    batches = regression.worker_batches(ds)
    for t in range(50):
        theta, opt_state, _ = step(theta, opt_state, batches,
                                   jax.random.PRNGKey(1), t)
    return float(jnp.linalg.norm(theta - ds.theta_star))


def main() -> list[dict]:
    rows = []
    for h in (0.0, 0.2, 0.5, 0.8):
        for aggregator, attack in [("mean", "none"), ("gmom", "sign_flip"),
                                   ("gmom", "inner_product")]:
            err = run(h, attack, aggregator)
            rows.append({"heterogeneity": h, "aggregator": aggregator,
                         "attack": attack, "final_error": err,
                         "converged": bool(err < 1.0)})
            print(f"noniid,h={h},{aggregator},{attack},err={err:.4f}")
    save_json("noniid.json", rows)
    return rows


if __name__ == "__main__":
    main()

"""Benchmark 2 — statistical error scaling (Theorem 5 / Remark 1).

Claims checked:
  (a) error floor ~ sqrt(d k / N): slopes of log(err) vs log(d), log(N), k.
  (b) the k trade-off: larger k tolerates more Byzantine workers but pays a
      sqrt(k) statistical penalty.
  (c) the sqrt(q) gap to the centralized minimax rate sqrt(d/N).
"""

from __future__ import annotations

import math

import numpy as np

from benchmarks.common import run_linreg, save_json


def final_error(**kw):
    errs, ds = run_linreg(rounds=40, **kw)
    return errs[-1]


def slope(xs, ys):
    lx, ly = np.log(np.asarray(xs)), np.log(np.asarray(ys))
    return float(np.polyfit(lx, ly, 1)[0])


def main() -> dict:
    base = dict(num_workers=20, num_byzantine=2, attack="sign_flip",
                aggregator="gmom", num_batches=10)
    out = {}

    # (a) error vs d at fixed N (expect slope ~ 1/2)
    ds_ = [10, 20, 40, 80, 160]
    errs_d = [np.mean([final_error(dim=d, total_samples=40_000, seed=s,
                                   **base) for s in range(3)])
              for d in ds_]
    out["error_vs_d"] = {"d": ds_, "err": errs_d,
                         "slope": slope(ds_, errs_d), "expect": 0.5}
    print(f"error_scaling,d-slope,{out['error_vs_d']['slope']:.3f},expect~0.5")

    # (a) error vs N at fixed d (expect slope ~ -1/2)
    ns = [5_000, 10_000, 20_000, 40_000, 80_000]
    errs_n = [np.mean([final_error(dim=50, total_samples=n, seed=s, **base)
                       for s in range(3)]) for n in ns]
    out["error_vs_N"] = {"N": ns, "err": errs_n,
                         "slope": slope(ns, errs_n), "expect": -0.5}
    print(f"error_scaling,N-slope,{out['error_vs_N']['slope']:.3f},"
          f"expect~-0.5")

    # (b) error vs k under NO attack (pure statistical penalty of batching)
    ks = [1, 2, 4, 10, 20]
    errs_k = [np.mean([final_error(dim=50, total_samples=40_000,
                                   num_workers=20, num_byzantine=0,
                                   attack="none", aggregator="gmom",
                                   num_batches=k, seed=s)
                       for s in range(3)]) for k in ks]
    out["error_vs_k"] = {"k": ks, "err": errs_k,
                         "slope": slope(ks[1:], errs_k[1:]), "expect": 0.5}
    print(f"error_scaling,k-slope,{out['error_vs_k']['slope']:.3f},"
          f"expect~0.5 (k>=2)")

    # (c) gap to the centralized oracle
    from repro.data import regression
    import jax
    key = jax.random.PRNGKey(0)
    dsx = regression.generate(key, dim=50, total_samples=40_000,
                              num_workers=20)
    oracle = regression.centralized_erm(dsx)
    import jax.numpy as jnp
    oracle_err = float(jnp.linalg.norm(oracle - dsx.theta_star))
    robust_err = final_error(dim=50, total_samples=40_000, **base)
    out["oracle_gap"] = {
        "oracle_err": oracle_err, "robust_err": robust_err,
        "ratio": robust_err / oracle_err,
        "sqrt_k_bound": math.sqrt(base["num_batches"]),
    }
    print(f"error_scaling,oracle-gap,{out['oracle_gap']['ratio']:.2f},"
          f"bound~sqrt(k)={out['oracle_gap']['sqrt_k_bound']:.2f}")

    save_json("error_scaling.json", out)
    return out


if __name__ == "__main__":
    main()

"""Benchmark 7 — beyond-paper: Byzantine GD on real transformer LMs.

The paper proves its guarantees for strongly-convex risks; this benchmark
measures the behaviour on the (non-convex) assigned architectures: per
(arch × aggregator × attack), the loss trajectory of a reduced-config LM
trained with the worker-mode Byzantine step.
"""

from __future__ import annotations

import jax

from benchmarks.common import save_json
from repro import optim
from repro.configs import get_config
from repro.core import RobustConfig, byzantine, make_run_rounds
from repro.data.tokens import TokenStream
from repro.models import model as M

ARCHS = ["minitron-4b", "granite-moe-1b-a400m", "rwkv6-7b", "zamba2-2.7b"]
STEPS = 10
M_WORKERS = 8


def run(arch, aggregator, attack, schedule="rotating"):
    cfg = get_config(arch).reduced()
    if cfg.family == "hybrid":
        cfg = cfg.with_(ssm_chunk=8)
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=32,
                         global_batch=16, num_workers=M_WORKERS, seed=0)
    rc = RobustConfig(num_workers=M_WORKERS, num_byzantine=2, attack=attack,
                      aggregator=aggregator, num_batches=8)
    opt = optim.adamw(1e-3)
    loss_fn = lambda p, b: M.loss_fn(p, b, cfg)  # noqa: E731
    sched = byzantine.make_schedule(schedule, num_workers=M_WORKERS,
                                    num_byzantine=2, attack=attack)
    # all STEPS rounds fuse into one lax.scan dispatch
    runner = make_run_rounds(loss_fn, opt, rc, schedule=sched)
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    batch = jax.tree.map(lambda *xs: jax.numpy.stack(xs),
                         *[stream.batch(i) for i in range(STEPS)])
    *_, metrics = runner(params, opt_state, batch, jax.random.PRNGKey(9),
                         per_round_batches=True)
    return [float(v) for v in metrics["loss_median"]]


def main() -> list[dict]:
    rows = []
    for arch in ARCHS:
        for aggregator, attack, schedule in [
                ("mean", "none", "static"), ("mean", "sign_flip", "rotating"),
                ("gmom", "sign_flip", "rotating"),
                ("gmom", "inner_product", "rotating"),
                ("gmom", "alie", "rotating"),
                ("gmom", "norm_stealth", "stealth_then_strike")]:
            losses = run(arch, aggregator, attack, schedule)
            rows.append({"arch": arch, "aggregator": aggregator,
                         "attack": attack, "schedule": schedule,
                         "first": losses[0], "final": losses[-1],
                         "losses": losses})
            print(f"lm_attack,{arch},{aggregator},{attack},{schedule},"
                  f"{losses[0]:.3f}->{losses[-1]:.3f}")
    save_json("lm_attack.json", rows)
    return rows


if __name__ == "__main__":
    main()

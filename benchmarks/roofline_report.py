"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from the dry-run
JSON records (benchmarks/roofline_singlepod.json / roofline_multipod.json).

    PYTHONPATH=src python -m benchmarks.roofline_report
"""

from __future__ import annotations

import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))


def load(name):
    path = os.path.join(HERE, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return json.load(f)


def fmt_row(r):
    peak = (f"{r['peak_memory_bytes'] / 2**30:.1f}"
            if r.get("peak_memory_bytes") else "n/a")
    return (f"| {r['arch']} | {r['shape']} | {r['step']} "
            f"| {r['compute_term']:.2e} | {r['memory_term']:.2e} "
            f"| {r['collective_term']:.2e} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} | {peak} |")


def dominant_fraction(r):
    terms = {"compute": r["compute_term"], "memory": r["memory_term"],
             "collective": r["collective_term"]}
    total = sum(terms.values())
    return max(terms.values()) / total if total else 0.0


def main():
    single = load("roofline_singlepod.json")
    multi = load("roofline_multipod.json")

    print("### §Roofline — single-pod 16x16 (256 chips), per-device terms\n")
    print("| arch | shape | step | compute s | memory s | collective s "
          "| bottleneck | useful-FLOPs | peak GiB/chip |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in single:
        print(fmt_row(r))

    if multi:
        print("\n### §Dry-run — multi-pod 2x16x16 (512 chips) lowering proof\n")
        print("| arch | shape | step | compute s | memory s | collective s "
              "| bottleneck | useful-FLOPs | peak GiB/chip |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in multi:
            print(fmt_row(r))

    if single:
        print("\n### hillclimb candidates (worst roofline profiles)\n")
        worst_frac = sorted(single, key=lambda r: -r["memory_term"]
                            - r["collective_term"])[:3]
        coll = sorted(single, key=lambda r: -r["collective_term"])[:3]
        print("highest memory+collective:",
              [(r["arch"], r["shape"]) for r in worst_frac])
        print("most collective-bound:",
              [(r["arch"], r["shape"]) for r in coll])
        over = [(r["arch"], r["shape"],
                 round(r["peak_memory_bytes"] / 2**30, 1))
                for r in single
                if r.get("peak_memory_bytes")
                and r["peak_memory_bytes"] > 16 * 2**30]
        print("over 16 GiB HBM:", over)


if __name__ == "__main__":
    main()

"""Benchmark 9 — beyond-paper GMoM variants.

(a) global (paper-faithful: one R^d vector) vs per-leaf GMoM — the per-leaf
    variant has cheaper collectives (no cross-leaf norm psums) but weaker
    per-coordinate guarantees; measure the robustness gap.
(b) Weiszfeld iteration budget: robustness vs max_iters (the paper's
    gamma = 1/N needs few iterations; how few is safe under attack?).
(c) grouping scheme ablation: contiguous (paper) vs strided vs seeded —
    any FIXED partition carries the same guarantee.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import run_linreg, save_json
from repro import optim
from repro.core import RobustConfig, make_robust_train_step, theory
from repro.data import regression

DIM, N, M, Q = 50, 40_000, 20, 3


def run_cfg(rc, rounds=40, seed=0):
    key = jax.random.PRNGKey(seed)
    ds = regression.generate(key, dim=DIM, total_samples=N, num_workers=M)
    opt = optim.sgd(0.5)
    step = jax.jit(make_robust_train_step(regression.squared_loss, opt, rc))
    theta = jnp.zeros((DIM,))
    opt_state = opt.init(theta)
    batches = regression.worker_batches(ds)
    for t in range(rounds):
        theta, opt_state, _ = step(theta, opt_state, batches,
                                   jax.random.PRNGKey(1), t)
    return float(jnp.linalg.norm(theta - ds.theta_star))


def main() -> dict:
    out = {}

    # (a) global vs per-leaf
    rows = []
    for agg in ("gmom", "gmom_per_leaf"):
        for attack in ("sign_flip", "inner_product", "colluding_mimic"):
            rc = RobustConfig(num_workers=M, num_byzantine=Q, num_batches=10,
                              attack=attack, aggregator=agg)
            err = run_cfg(rc)
            rows.append({"aggregator": agg, "attack": attack, "err": err})
            print(f"gmom_variants,granularity,{agg},{attack},err={err:.4f}")
    out["granularity"] = rows

    # (b) Weiszfeld budget
    rows = []
    for iters in (1, 2, 4, 8, 32):
        rc = RobustConfig(num_workers=M, num_byzantine=Q, num_batches=10,
                          attack="mean_shift", aggregator="gmom",
                          gmom_max_iters=iters)
        err = run_cfg(rc)
        rows.append({"max_iters": iters, "err": err})
        print(f"gmom_variants,weiszfeld_iters,{iters},err={err:.4f}")
    out["weiszfeld_iters"] = rows

    # (c) grouping scheme
    rows = []
    for scheme in ("contiguous", "strided", "seeded"):
        rc = RobustConfig(num_workers=M, num_byzantine=Q, num_batches=10,
                          attack="sign_flip", aggregator="gmom",
                          grouping_scheme=scheme)
        err = run_cfg(rc)
        rows.append({"scheme": scheme, "err": err})
        print(f"gmom_variants,grouping,{scheme},err={err:.4f}")
    out["grouping"] = rows

    save_json("gmom_variants.json", out)
    return out


if __name__ == "__main__":
    main()

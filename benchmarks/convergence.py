"""Benchmark 1 — exponential convergence under Byzantine attacks
(Theorem 1 / Corollary 1; the paper's central claim).

Linear regression (paper §4): m=50 workers, q=4 Byzantine, k canonical.
Produces log-error traces per (aggregator × attack) and fits the empirical
contraction rate against Corollary 1's 1/2 + sqrt(3)/4 ≈ 0.933.
"""

from __future__ import annotations

import math

from benchmarks.common import run_linreg, save_json
from repro.core import theory
from repro.core.grouping import choose_num_batches

DIM = 100
N = 50_000
M = 50
Q = 4
ROUNDS = 50


def fit_contraction(errs, floor):
    """Per-round contraction while well above the error floor."""
    ratios = []
    for a, b in zip(errs[:-1], errs[1:]):
        if b > 5 * floor and a > 0:
            ratios.append(b / a)
    return sum(ratios) / len(ratios) if ratios else float("nan")


def main() -> list[dict]:
    k = choose_num_batches(M, Q)          # canonical 2(1+eps)q, divides m
    rows = []
    cases = [
        ("mean", "none", 0),
        ("mean", "sign_flip", Q),
        ("gmom", "none", 0),
        ("gmom", "sign_flip", Q),
        ("gmom", "inner_product", Q),
        ("gmom", "mean_shift", Q),
        ("gmom", "colluding_mimic", Q),
        ("gmom", "random_noise", Q),
        ("geomed", "sign_flip", Q),
        ("coordinate_median", "sign_flip", Q),
        ("trimmed_mean", "sign_flip", Q),
        ("krum", "sign_flip", Q),
    ]
    floor_pred = theory.error_floor(DIM, N, k)
    rate_pred = theory.LINEAR_REGRESSION.theorem1_contraction
    for aggregator, attack, q in cases:
        errs, _ = run_linreg(
            dim=DIM, total_samples=N, num_workers=M, num_byzantine=q,
            num_batches=(k if aggregator in ("gmom",) else
                         M if aggregator == "geomed" else k),
            attack=attack, aggregator=aggregator, rounds=ROUNDS)
        final = errs[-1]
        rate = fit_contraction(errs, max(final, 1e-6))
        rows.append({
            "aggregator": aggregator, "attack": attack, "q": q, "k": k,
            "final_error": final,
            "empirical_contraction": rate,
            "theory_contraction": rate_pred,
            "theory_floor_c2=1": floor_pred,
            "diverged": bool(final > errs[0]),
            "errors": errs,
        })
        print(f"convergence,{aggregator},{attack},q={q},"
              f"final={final:.4f},rate={rate:.3f}")
    save_json("convergence.json", rows)
    return rows


if __name__ == "__main__":
    main()

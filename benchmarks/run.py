"""Benchmark harness — one module per paper claim (see DESIGN.md §6).

Prints ``name,metric,...`` CSV lines and writes JSON under
benchmarks/results/.  Roofline tables come from the dry-run
(python -m repro.launch.dryrun --all) and are summarized here if present.

    PYTHONPATH=src python -m benchmarks.run [--only convergence,...]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

BENCHES = ["convergence", "error_scaling", "breakdown", "geomed_cost",
           "communication", "kernel_bench", "lm_attack",
           "selection_rules", "gmom_variants", "noniid"]


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--only", default=None,
                   help="comma-separated subset of " + ",".join(BENCHES))
    args = p.parse_args(argv)
    selected = args.only.split(",") if args.only else BENCHES

    failures = []
    for name in selected:
        print(f"\n===== benchmark: {name} =====", flush=True)
        t0 = time.time()
        try:
            module = __import__(f"benchmarks.{name}", fromlist=["main"])
            module.main()
            print(f"===== {name} done in {time.time() - t0:.1f}s =====",
                  flush=True)
        except Exception:  # noqa: BLE001
            traceback.print_exc()
            failures.append(name)

    if failures:
        print(f"\nFAILED benchmarks: {failures}")
        return 1
    print("\nall benchmarks passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Benchmark 8 — the paper's §6 open question, answered empirically.

Paper §6 (Discussion): "A simple idea to defend against the relaxed
Byzantine faults is to select a subset of received gradients at each
iteration and then take the average ... One selection rule is random
selection and another one is to select the gradients of the small l2 norms.
It would be interesting to investigate the performance of these two
selection rules and compare them with the geometric median."

We implement both (core/aggregators.py) and compare against GMoM under
(a) a large-norm attack (sign_flip ×10), (b) the small-norm omniscient
inner-product attack, (c) no attack (statistical efficiency).
"""

from __future__ import annotations

from benchmarks.common import run_linreg, save_json

DIM, N, M, Q = 50, 40_000, 20, 3


def main() -> list[dict]:
    rows = []
    cases = [
        # (aggregator, attack) — expected verdicts in comments
        ("gmom", "none"),             # reference efficiency
        ("random_select", "none"),    # fine without attack
        ("norm_select", "none"),
        ("gmom", "sign_flip"),        # gmom handles both attack styles
        ("random_select", "sign_flip"),   # fails: attacker survives sampling
        ("norm_select", "sign_flip"),     # works: attack has huge norms
        ("gmom", "inner_product"),
        ("random_select", "inner_product"),
        ("norm_select", "inner_product"),  # FAILS: attack has SMALL norms
    ]
    for aggregator, attack in cases:
        errs, _ = run_linreg(
            dim=DIM, total_samples=N, num_workers=M, num_byzantine=Q,
            num_batches=(10 if aggregator == "gmom" else M),
            attack=attack, aggregator=aggregator, rounds=40,
            trim_multiplier=(3.0 if aggregator == "gmom" else None))
        rows.append({"aggregator": aggregator, "attack": attack,
                     "final_error": errs[-1],
                     "converged": bool(errs[-1] < 1.0)})
        print(f"selection_rules,{aggregator},{attack},"
              f"err={errs[-1]:.4f},converged={errs[-1] < 1.0}")
    save_json("selection_rules.json", rows)
    return rows


if __name__ == "__main__":
    main()

"""Benchmark 8 — the paper's §6 open question, answered empirically.

Paper §6 (Discussion): "A simple idea to defend against the relaxed
Byzantine faults is to select a subset of received gradients at each
iteration and then take the average ... One selection rule is random
selection and another one is to select the gradients of the small l2 norms.
It would be interesting to investigate the performance of these two
selection rules and compare them with the geometric median."

We implement both (core/aggregators.py) and compare against GMoM under
(a) a large-norm attack (sign_flip ×10), (b) the small-norm omniscient
inner-product attack, (c) no attack (statistical efficiency).

The sound combined selection rules (`coord_median`, `coord_trimmed_mean`,
`norm_filter_gmom` — the defense-gap fix) join the comparison under the
full small-norm suite (alie, norm_stealth, inner_product) to demonstrate
empirically what the defense matrix asserts: they converge where the naive
§6 rules diverge.
"""

from __future__ import annotations

from benchmarks.common import run_linreg, save_json

DIM, N, M, Q = 50, 40_000, 20, 3

#: aggregators that run the batched (k = 10) pipeline; the naive selection
#: rules operate on the raw m reports (k = m, no batching to hide in).
BATCHED = ("gmom", "coord_median", "coord_trimmed_mean", "norm_filter_gmom")
SOUND_COMBINED = ("coord_median", "coord_trimmed_mean", "norm_filter_gmom")
SMALL_NORM_ATTACKS = ("alie", "norm_stealth", "inner_product")


def main() -> list[dict]:
    rows = []
    cases = [
        # (aggregator, attack) — expected verdicts in comments
        ("gmom", "none"),             # reference efficiency
        ("random_select", "none"),    # fine without attack
        ("norm_select", "none"),
        ("gmom", "sign_flip"),        # gmom handles both attack styles
        ("random_select", "sign_flip"),   # fails: attacker survives sampling
        ("norm_select", "sign_flip"),     # works: attack has huge norms
        ("gmom", "inner_product"),
        ("random_select", "inner_product"),
        ("norm_select", "inner_product"),  # FAILS: attack has SMALL norms
    ]
    # the sound combined rules: efficiency, the classic large-norm attack,
    # and the full small-norm suite that defeats the naive rules.
    for agg in SOUND_COMBINED:
        cases.append((agg, "none"))
        cases.append((agg, "sign_flip"))
        cases.extend((agg, attack) for attack in SMALL_NORM_ATTACKS)
    for aggregator, attack in cases:
        errs, _ = run_linreg(
            dim=DIM, total_samples=N, num_workers=M, num_byzantine=Q,
            num_batches=(10 if aggregator in BATCHED else M),
            attack=attack, aggregator=aggregator, rounds=40,
            trim_multiplier=(3.0 if aggregator in BATCHED else None))
        rows.append({"aggregator": aggregator, "attack": attack,
                     "final_error": errs[-1],
                     "converged": bool(errs[-1] < 1.0)})
        print(f"selection_rules,{aggregator},{attack},"
              f"err={errs[-1]:.4f},converged={errs[-1] < 1.0}")
    save_json("selection_rules.json", rows)
    return rows


if __name__ == "__main__":
    main()

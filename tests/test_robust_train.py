"""Robust training: worker-mode vs group-mode equivalence + the paper's
linear-regression convergence claims at test scale."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import (RobustConfig, aggregate, make_robust_train_step,
                        per_worker_grads, theory)
from repro.core.aggregators import batch_means, gmom_aggregator
from repro.data import regression
from repro.launch import steps as steps_lib


def test_worker_vs_group_mode_honest_equality():
    """mean-of-worker-means == pooled group mean (the group-mode invariant
    that lets the production path avoid (m, P) gradient memory)."""
    key = jax.random.PRNGKey(0)
    d, N, m, k = 6, 240, 12, 4
    ds = regression.generate(key, dim=d, total_samples=N, num_workers=m)
    theta = jnp.zeros((d,))

    # worker mode: m per-worker grads -> k batch means
    stacked, _ = per_worker_grads(regression.squared_loss, theta,
                                  regression.worker_batches(ds))
    worker_means = batch_means(stacked, k)

    # group mode: k pooled gradients directly
    feats = ds.features.reshape(k, (m // k) * ds.samples_per_worker, d)
    targs = ds.targets.reshape(k, (m // k) * ds.samples_per_worker)

    def group_grad(b):
        return jax.grad(regression.squared_loss)(theta, b)

    group_grads = jax.vmap(group_grad)((feats, targs))
    np.testing.assert_allclose(np.asarray(worker_means),
                               np.asarray(group_grads), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("attack,aggregator,should_converge", [
    ("none", "mean", True),
    ("sign_flip", "mean", False),
    ("sign_flip", "gmom", True),
    # the remaining attack × gmom sweeps are covered (faster, scan-compiled)
    # by tests/test_scenarios.py; keep them reachable via -m ""
    pytest.param("inner_product", "gmom", True, marks=pytest.mark.slow),
    pytest.param("random_noise", "gmom", True, marks=pytest.mark.slow),
    pytest.param("mean_shift", "gmom", True, marks=pytest.mark.slow),
])
def test_linreg_convergence(attack, aggregator, should_converge):
    """Corollary 1: exponential convergence to O(sqrt(dk/N)) under
    2(1+eps)q <= k; Algorithm 1 (mean) fails under a single Byzantine."""
    key = jax.random.PRNGKey(1)
    d, N, m, q = 20, 4000, 20, 3
    ds = regression.generate(key, dim=d, total_samples=N, num_workers=m)
    rc = RobustConfig(num_workers=m, num_byzantine=q, num_batches=10,
                      attack=attack, aggregator=aggregator)
    opt = optim.sgd(theory.LINEAR_REGRESSION.step_size)   # eta = 1/2
    step = jax.jit(make_robust_train_step(regression.squared_loss, opt, rc))
    theta = jnp.zeros((d,))
    opt_state = opt.init(theta)
    batches = regression.worker_batches(ds)
    for t in range(40):
        theta, opt_state, _ = step(theta, opt_state, batches,
                                   jax.random.PRNGKey(2), t)
    err = float(jnp.linalg.norm(theta - ds.theta_star))
    floor = theory.error_floor(d, N, 10, c2=20.0)
    if should_converge:
        assert err < floor, f"err={err} floor={floor}"
    else:
        assert err > 1.0, f"mean unexpectedly robust: err={err}"


def test_contraction_rate_matches_theory():
    """Failure-free GD on the population-like regime contracts at least as
    fast as Theorem 1's rate (1/2 + sqrt(3)/4 for linreg)."""
    key = jax.random.PRNGKey(2)
    d, N, m = 10, 100_000, 10     # huge N => near-population gradients
    ds = regression.generate(key, dim=d, total_samples=N, num_workers=m)
    rc = RobustConfig(num_workers=m, num_byzantine=0, num_batches=1,
                      aggregator="mean", attack="none")
    opt = optim.sgd(0.5)
    step = jax.jit(make_robust_train_step(regression.squared_loss, opt, rc))
    theta = jnp.zeros((d,))
    opt_state = opt.init(theta)
    errs = []
    batches = regression.worker_batches(ds)
    for t in range(10):
        errs.append(float(jnp.linalg.norm(theta - ds.theta_star)))
        theta, opt_state, _ = step(theta, opt_state, batches,
                                   jax.random.PRNGKey(0), t)
    rate = theory.LINEAR_REGRESSION.theorem1_contraction   # ≈ 0.933
    # empirical per-step contraction (while far from the floor)
    emp = errs[5] / errs[4]
    assert emp <= rate + 0.02, f"contraction {emp} vs theory {rate}"


def test_rotating_byzantine_sets():
    """The paper's hardest case: B_t changes every round."""
    key = jax.random.PRNGKey(3)
    d, N, m, q = 10, 2000, 20, 3
    ds = regression.generate(key, dim=d, total_samples=N, num_workers=m)
    rc = RobustConfig(num_workers=m, num_byzantine=q, num_batches=10,
                      attack="sign_flip", aggregator="gmom",
                      rotate_byzantine=True)
    opt = optim.sgd(0.5)
    step = jax.jit(make_robust_train_step(regression.squared_loss, opt, rc))
    theta = jnp.zeros((d,))
    opt_state = opt.init(theta)
    batches = regression.worker_batches(ds)
    for t in range(40):
        theta, opt_state, _ = step(theta, opt_state, batches,
                                   jax.random.PRNGKey(4), t)
    err = float(jnp.linalg.norm(theta - ds.theta_star))
    assert err < 1.0


def test_registry_driven_kwarg_dispatch():
    """aggregate_reported threads config fields by registry metadata (the
    needs_* flags on @register), not by hardcoded aggregator-name lists: a
    newly registered rule declaring the flags receives the kwargs with zero
    dispatch-site edits — the regression this pins is a new aggregator
    silently getting no q and no randomness."""
    from repro.core import aggregators
    from repro.core.robust_train import aggregate_reported
    seen: dict = {}

    @aggregators.register("_test_dummy", "test-only dummy",
                          needs_num_byzantine=True, needs_key=True,
                          needs_grouping=True)
    def dummy(stacked, **kw):
        seen.update(kw)
        return jax.tree.map(lambda g: jnp.mean(g, axis=0), stacked)

    try:
        cfg = RobustConfig(num_workers=8, num_byzantine=2, num_batches=4,
                           aggregator="_test_dummy")
        aggregate_reported({"w": jnp.ones((8, 3))}, cfg,
                           key=jax.random.PRNGKey(0))
        assert seen["num_byzantine"] == 2
        assert seen["num_batches"] == 4
        assert seen["epsilon"] == cfg.epsilon
        assert seen["grouping_scheme"] == cfg.grouping_scheme
        assert seen["trim_multiplier"] == cfg.trim_multiplier
        assert seen["max_iters"] == cfg.gmom_max_iters
        assert seen["tol"] == cfg.gmom_tol
        assert seen["round_backend"] == cfg.round_backend
        assert seen["key"] is not None
    finally:
        aggregators._REGISTRY.pop("_test_dummy")


def test_flagless_aggregator_receives_no_kwargs():
    """The complement: a rule with no needs_* flags gets a bare call — no
    stray kwargs to swallow, so simple aggregators need no **_kw at all."""
    from repro.core import aggregators
    from repro.core.robust_train import aggregate_reported
    seen: dict = {}

    @aggregators.register("_test_bare", "test-only bare dummy")
    def bare(stacked, **kw):
        seen.update(kw)
        return jax.tree.map(lambda g: jnp.mean(g, axis=0), stacked)

    try:
        cfg = RobustConfig(num_workers=8, num_byzantine=2,
                           aggregator="_test_bare")
        aggregate_reported({"w": jnp.ones((8, 3))}, cfg,
                           key=jax.random.PRNGKey(0))
        assert seen == {}
    finally:
        aggregators._REGISTRY.pop("_test_bare")


def test_tolerance_condition_helpers():
    assert theory.tolerance_ok(20, 10, 4)          # 2.2*4 = 8.8 <= 10
    assert not theory.tolerance_ok(20, 8, 4)       # 8.8 > 8
    from repro.core.grouping import choose_num_batches
    assert choose_num_batches(20, 0) == 1
    k = choose_num_batches(20, 4)
    assert k >= 2 * 1.1 * 4 and 20 % k == 0


def test_group_mode_train_step_runs():
    """The production (group-mode) step on the linreg problem."""
    from repro.configs.base import InputShape
    key = jax.random.PRNGKey(5)
    d, N, k = 8, 1600, 4
    ds = regression.generate(key, dim=d, total_samples=N, num_workers=k)
    rc = RobustConfig(num_workers=k, num_byzantine=1, num_batches=k,
                      attack="sign_flip", aggregator="gmom")
    opt = optim.sgd(0.5)

    import repro.models.model  # noqa: F401 (steps imports model lazily)
    # group-mode step over a toy "model" = the regression loss
    from repro.core.byzantine import get_attack, sample_byzantine_mask
    from repro.core.geometric_median import geometric_median_pytree

    def train_step(theta, opt_state, batch, key, t):
        def gloss(th, b):
            return regression.squared_loss(th, b)
        losses, grads = jax.vmap(
            lambda b: jax.value_and_grad(gloss)(theta, b))(batch)
        mask = sample_byzantine_mask(key, k, 1, rotate=True, round_index=t)
        reported = get_attack("sign_flip")(grads, mask, key)
        agg = geometric_median_pytree(reported)
        updates, opt_state = opt.update(agg, opt_state, theta)
        return theta + updates, opt_state, jnp.mean(losses)

    theta = jnp.zeros((d,))
    opt_state = opt.init(theta)
    batch = regression.worker_batches(ds)
    step = jax.jit(train_step)
    for t in range(30):
        theta, opt_state, _ = step(theta, opt_state, batch,
                                   jax.random.PRNGKey(6), t)
    assert float(jnp.linalg.norm(theta - ds.theta_star)) < 1.0

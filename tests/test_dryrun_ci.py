"""CI-scale dry-run: the real sharding/lowering pipeline on an 8-virtual-
device mesh in a subprocess (the 512-way flag must not leak into this
process — jax locks device count at first init)."""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.models.meshctx import set_mesh
    from repro.configs import get_config
    from repro.configs.base import InputShape
    from repro.core import RobustConfig
    from repro.launch import mesh as mesh_lib, sharding, steps
    from repro import optim
    from repro.roofline import analysis

    arch = "{arch}"
    kind = "{kind}"
    mesh = mesh_lib.make_debug_mesh(data=2, model=2, pod=2)
    cfg = get_config(arch).reduced()
    with set_mesh(mesh):
        params_s = steps.abstract_params(cfg)
        pshard = sharding.param_shardings(params_s, mesh, cfg)
        if kind == "train":
            shape = InputShape("t", seq_len=64, global_batch=32, kind="train")
            batch = steps.train_batch_struct(cfg, shape, 4)
            rc = RobustConfig(num_workers=4, num_byzantine=1, num_batches=4,
                              attack="sign_flip", gmom_max_iters=4)
            opt = optim.adamw(1e-3)
            opt_s = steps.abstract_opt_state(opt, params_s)
            oshard = sharding.opt_state_shardings(opt_s, params_s, mesh, cfg)
            bshard = sharding.batch_shardings(batch, mesh)
            fn = steps.make_group_train_step(cfg, rc, opt, microbatches=2)
            rep = sharding.replicated(mesh)
            lowered = jax.jit(fn, in_shardings=(pshard, oshard, bshard,
                                                rep, rep),
                              donate_argnums=(0, 1)).lower(
                params_s, opt_s, batch,
                jax.ShapeDtypeStruct((2,), jnp.uint32),
                jax.ShapeDtypeStruct((), jnp.int32))
        else:
            shape = InputShape("d", seq_len=128, global_batch=8,
                               kind="decode")
            tok, pos, state = steps.decode_input_struct(cfg, shape)
            sshard = sharding.decode_state_shardings(state, mesh, cfg, 8)
            bspec = sharding.serve_batch_spec(mesh, 8)
            baxis = bspec[0] if len(bspec) else None
            fn = steps.make_serve_step(cfg)
            lowered = jax.jit(
                fn, in_shardings=(pshard, sshard,
                                  jax.NamedSharding(mesh, P(baxis, None)),
                                  jax.NamedSharding(mesh, P(baxis))),
                donate_argnums=(1,)).lower(params_s, state, tok, pos)
        compiled = lowered.compile()
        cost = analysis.collective_bytes(compiled.as_text())
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
        print("OK", sum(cost.values()))
""")


@pytest.mark.parametrize("arch,kind", [
    ("minitron-4b", "train"),
    ("granite-moe-1b-a400m", "train"),
    ("rwkv6-7b", "train"),
    ("zamba2-2.7b", "train"),
    ("seamless-m4t-medium", "train"),
    ("internvl2-26b", "train"),
    ("minitron-4b", "decode"),
    ("rwkv6-7b", "decode"),
    ("kimi-k2-1t-a32b", "decode"),
])
def test_debug_mesh_lowering(arch, kind):
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(arch=arch, kind=kind)],
        capture_output=True, text=True, timeout=900,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, (res.stdout[-1000:], res.stderr[-3000:])
    assert "OK" in res.stdout

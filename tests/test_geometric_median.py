"""Property tests for the geometric median (paper §2.1, Lemma 1).

``hypothesis`` is optional: when installed the properties run under its
strategies; otherwise the same checks run over a parametrized set of
deterministic seeds so the core properties are always exercised (the tier-1
environment does not ship hypothesis).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import geometric_median, geometric_median_pytree, \
    trim_weights, batch_mean_norms
from repro.core.theory import c_alpha

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

FALLBACK_SEEDS = list(range(5))


def _random_points(seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 13))
    d = int(rng.integers(1, 7))
    return (rng.normal(size=(n, d)) * 10).astype(np.float32)


def property_test(*, needs_shift=False, needs_seed=False):
    """Run the check under hypothesis when available, else over seeds.

    The wrapped check takes ``pts`` (and optionally ``shift``/``seed``).
    """
    def deco(check):
        if HAVE_HYPOTHESIS:
            if needs_shift:
                return given(points_strategy,
                             st.lists(st.floats(-50, 50, allow_nan=False,
                                                width=32),
                                      min_size=6, max_size=6))(check)
            if needs_seed:
                return given(points_strategy,
                             st.integers(0, 2**31 - 1))(check)
            return given(points_strategy)(check)

        @pytest.mark.parametrize("seed", FALLBACK_SEEDS)
        def fallback(seed):
            pts = _random_points(seed)
            rng = np.random.default_rng(seed + 1000)
            if needs_shift:
                check(pts, list(rng.uniform(-50, 50, size=6)))
            elif needs_seed:
                check(pts, int(rng.integers(0, 2**31 - 1)))
            else:
                check(pts)
        fallback.__name__ = check.__name__
        fallback.__doc__ = check.__doc__
        return fallback
    return deco


if HAVE_HYPOTHESIS:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
    points_strategy = st.builds(
        lambda seed, n, d: np.random.default_rng(seed)
        .normal(size=(n, d)).astype(np.float32) * 10,
        st.integers(0, 2**31 - 1), st.integers(2, 12), st.integers(1, 6))


@property_test()
def test_objective_not_worse_than_mean(pts):
    """geomed minimizes sum of distances => objective <= mean's objective."""
    gm = geometric_median(jnp.asarray(pts), max_iters=128, tol=1e-10)
    mean = pts.mean(axis=0)

    def obj(y):
        return float(np.sum(np.linalg.norm(pts - y, axis=1)))

    assert obj(np.asarray(gm)) <= obj(mean) + 1e-3 * (1 + abs(obj(mean)))


@property_test(needs_shift=True)
def test_translation_equivariance(pts, shift):
    shift = np.array(shift[:pts.shape[1]], np.float32)
    g1 = np.asarray(geometric_median(jnp.asarray(pts), max_iters=96))
    g2 = np.asarray(geometric_median(jnp.asarray(pts + shift), max_iters=96))
    np.testing.assert_allclose(g1 + shift, g2, atol=2e-2)


@property_test(needs_seed=True)
def test_permutation_invariance(pts, seed):
    perm = np.random.default_rng(seed).permutation(pts.shape[0])
    g1 = np.asarray(geometric_median(jnp.asarray(pts)))
    g2 = np.asarray(geometric_median(jnp.asarray(pts[perm])))
    np.testing.assert_allclose(g1, g2, atol=1e-3)


@property_test()
def test_within_bounding_box(pts):
    """geomed lies in the convex hull => inside the bounding box."""
    g = np.asarray(geometric_median(jnp.asarray(pts), max_iters=128))
    lo, hi = pts.min(axis=0), pts.max(axis=0)
    assert np.all(g >= lo - 1e-2) and np.all(g <= hi + 1e-2)


def test_single_point_and_mean_reduction():
    pts = jnp.array([[3.0, -2.0, 5.0]])
    np.testing.assert_allclose(np.asarray(geometric_median(pts)),
                               [3.0, -2.0, 5.0], atol=1e-6)


def test_lemma1_robustness():
    """Lemma 1 (gamma=0): if > (1-alpha) n points lie in B(0, r), then
    ||geomed|| <= C_alpha r."""
    rng = np.random.default_rng(0)
    n, d, alpha, r = 20, 8, 0.25, 1.0
    n_in = int((1 - alpha) * n) + 1
    inliers = rng.normal(size=(n_in, d))
    inliers = inliers / np.linalg.norm(inliers, axis=1, keepdims=True) \
        * rng.uniform(0, r, (n_in, 1))
    outliers = rng.normal(size=(n - n_in, d)) * 1e4
    pts = jnp.asarray(np.vstack([inliers, outliers]), jnp.float32)
    g = geometric_median(pts, max_iters=256, tol=1e-10)
    assert float(jnp.linalg.norm(g)) <= c_alpha(alpha) * r + 1e-3


def test_median_1d_matches_numpy_median_interval():
    """In 1-D the geometric median is a median."""
    pts = jnp.array([[1.0], [2.0], [3.0], [10.0], [11.0]])
    g = float(geometric_median(pts, max_iters=512, tol=1e-12)[0])
    assert 2.9 <= g <= 3.1


def test_pytree_matches_flat():
    rng = np.random.default_rng(1)
    pts = rng.normal(size=(7, 10)).astype(np.float32)
    flat = geometric_median(jnp.asarray(pts), max_iters=128)
    tree = {"a": jnp.asarray(pts[:, :4]),
            "b": {"c": jnp.asarray(pts[:, 4:])}}
    gt = geometric_median_pytree(tree, max_iters=128)
    merged = np.concatenate([np.asarray(gt["a"]),
                             np.asarray(gt["b"]["c"])])
    np.testing.assert_allclose(np.asarray(flat), merged, atol=1e-4)


def test_weights_zero_excludes_points():
    pts = jnp.array([[0.0, 0.0], [0.1, 0.0], [-0.1, 0.0], [1e6, 1e6]])
    w = jnp.array([1.0, 1.0, 1.0, 0.0])
    g = geometric_median(pts, weights=w, max_iters=256)
    assert float(jnp.linalg.norm(g)) < 0.2


def test_trim_weights():
    norms = jnp.array([1.0, 1.1, 0.9, 1.05, 500.0])
    w = trim_weights(norms, multiplier=3.0)
    np.testing.assert_array_equal(np.asarray(w), [1, 1, 1, 1, 0])
    # never all-zero
    w2 = trim_weights(jnp.array([1e9, 1e9]), multiplier=0.0)
    assert float(jnp.sum(w2)) > 0


def test_batch_mean_norms():
    tree = {"a": jnp.array([[3.0, 0.0], [0.0, 0.0]]),
            "b": jnp.array([[4.0], [0.0]])}
    norms = batch_mean_norms(tree)
    np.testing.assert_allclose(np.asarray(norms), [5.0, 0.0], atol=1e-6)


def test_jit_and_grad_safe():
    pts = jnp.asarray(np.random.default_rng(2).normal(size=(6, 4)),
                      jnp.float32)
    g = jax.jit(lambda p: geometric_median(p))(pts)
    assert g.shape == (4,)
    assert bool(jnp.all(jnp.isfinite(g)))

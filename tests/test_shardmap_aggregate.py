"""make_shardmap_aggregate (hand-scheduled GMoM collectives) vs the GSPMD
``aggregate`` path on a fake 8-device CPU mesh — leaf-for-leaf equality,
on both the reference jnp tail and the fused round-kernel backend
(``round_backend="fused_interpret"``: the Pallas kernel in interpret mode).

Runs in a subprocess because the virtual-device flag must be set before jax
initializes (same pattern as test_parallel_numerics)."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import RobustConfig, aggregate, aggregators, \\
        make_shardmap_aggregate
    from repro.models.meshctx import shard_map

    m, k = 8, 4
    mesh = jax.make_mesh((8,), ("data",))
    cfg = RobustConfig(num_workers=m, num_byzantine=1, num_batches=k,
                       attack="none", aggregator="gmom",
                       gmom_max_iters=32, gmom_tol=1e-7)

    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    stacked = {"w": jax.random.normal(ks[0], (m, 16), jnp.float32),
               "b": {"x": jax.random.normal(ks[1], (m, 4, 3), jnp.float32)}}

    # --- GSPMD path: plain aggregate() jitted with the worker axis sharded
    in_shardings = jax.tree.map(
        lambda x: NamedSharding(mesh, P(*(("data",) + (None,) * (x.ndim - 1)))),
        stacked)
    gspmd = jax.jit(
        lambda s: aggregate(s, cfg, key=key, round_index=0),
        in_shardings=(in_shardings,))(stacked)

    # --- hand-scheduled path: per-rank grads (no worker axis) via shard_map
    agg_local = make_shardmap_aggregate(cfg, mesh)
    specs = jax.tree.map(
        lambda x: P(*(("data",) + (None,) * (x.ndim - 1))), stacked)
    out_specs = jax.tree.map(lambda x: P(*((None,) * (x.ndim - 1))), stacked)
    fn = shard_map(
        lambda s: agg_local(jax.tree.map(lambda x: x[0], s)),
        mesh=mesh, in_specs=(specs,), out_specs=out_specs, check_rep=False)
    handsched = jax.jit(fn)(stacked)

    # --- single-device oracle
    oracle = aggregators.gmom_aggregator(
        stacked, num_batches=k, num_byzantine=1,
        trim_multiplier=cfg.trim_multiplier, max_iters=cfg.gmom_max_iters,
        tol=cfg.gmom_tol)

    for a, b, c in zip(jax.tree.leaves(gspmd), jax.tree.leaves(handsched),
                       jax.tree.leaves(oracle)):
        assert a.shape == b.shape == c.shape, (a.shape, b.shape, c.shape)
        assert float(jnp.max(jnp.abs(a - b))) < 1e-5, "gspmd vs shard_map"
        assert float(jnp.max(jnp.abs(b - c))) < 1e-5, "shard_map vs oracle"

    # --- fused backend: the PR-3 round kernel dispatched through
    # RobustConfig.round_backend (the trim+Weiszfeld tail runs in the Pallas
    # interpreter on the psum'd means; identity k=m grouping in-kernel)
    import dataclasses
    cfg_fused = dataclasses.replace(cfg, round_backend="fused_interpret")
    agg_fused = make_shardmap_aggregate(cfg_fused, mesh)
    fn_fused = shard_map(
        lambda s: agg_fused(jax.tree.map(lambda x: x[0], s)),
        mesh=mesh, in_specs=(specs,), out_specs=out_specs, check_rep=False)
    handsched_fused = jax.jit(fn_fused)(stacked)

    oracle_fused = aggregators.gmom_aggregator(
        stacked, num_batches=k, num_byzantine=1,
        trim_multiplier=cfg.trim_multiplier, max_iters=cfg.gmom_max_iters,
        tol=cfg.gmom_tol, round_backend="fused_interpret")

    for b, f, of in zip(jax.tree.leaves(handsched),
                        jax.tree.leaves(handsched_fused),
                        jax.tree.leaves(oracle_fused)):
        assert b.shape == f.shape == of.shape, (b.shape, f.shape, of.shape)
        assert float(jnp.max(jnp.abs(f - b))) < 1e-5, \\
            "fused shard_map vs reference shard_map"
        assert float(jnp.max(jnp.abs(f - of))) < 1e-5, \\
            "fused shard_map vs fused oracle"
    print("OK")
""")


def test_shardmap_gmom_matches_gspmd_aggregate():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, (res.stdout[-800:], res.stderr[-4000:])
    assert "OK" in res.stdout


# ---------------------------------------------------------------------------
# the shard-local contract: sharded (shard_map over the MODEL axis) vs
# gathered (the single-device "virtual" blocked oracle) aggregation must be
# BIT-identical for every registered rule × even/uneven grouping × dtype.

BLOCKED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.core import RobustConfig, aggregators, aggregate_reported, \\
        make_sharded_aggregate
    from repro.core.shard_aggregation import ShardSpec
    from repro.models.meshctx import shard_map

    m, S = 8, 8
    mesh = jax.make_mesh((S,), ("model",))
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 3)
    base = {"w": jax.random.normal(ks[0], (m, 16), jnp.float32),
            "b": {"x": jax.random.normal(ks[1], (m, 4, 8), jnp.float32)},
            "s": jax.random.normal(ks[2], (m,), jnp.float32)}

    def in_spec(x):
        if x.ndim == 1:
            return P(None)                       # (m,) — replicated
        return P(*((None,) * (x.ndim - 1) + ("model",)))

    def out_spec(x):
        if x.ndim == 0:
            return P()
        return P(*((None,) * (x.ndim - 1) + ("model",)))

    in_specs = jax.tree.map(in_spec, base)
    checked = 0
    for name in aggregators.available():
        # rules with a native wire codec run TWICE: once on raw floats and
        # once through their compressed production path (encode happens
        # inside aggregate_reported on both sides; the encode itself is
        # shard-local, so the bitwise contract must survive it)
        native = aggregators.get_aggregator(name).native_codec
        for codec in ("none",) + ((native,) if native else ()):
          for k in (4, 3):                        # even / uneven grouping
            for dt in (jnp.float32, jnp.bfloat16):
                stacked = jax.tree.map(lambda x: x.astype(dt), base)
                cfg = RobustConfig(
                    num_workers=m, num_byzantine=1, num_batches=k,
                    attack="none", aggregator=name, compression=codec,
                    gmom_max_iters=8, gmom_tol=1e-7)

                virtual = ShardSpec(num_shards=S, mode="virtual",
                                    axis="model")
                gathered = jax.jit(lambda s: aggregate_reported(
                    s, cfg, key=key, shard_spec=virtual))(stacked)

                agg = make_sharded_aggregate(cfg, mesh)
                out_specs = jax.tree.map(
                    out_spec, jax.eval_shape(
                        lambda s: aggregate_reported(s, cfg, key=key),
                        stacked))
                fn = shard_map(agg, mesh=mesh, in_specs=(in_specs, P(None)),
                               out_specs=out_specs, check_rep=False)
                sharded = jax.jit(fn)(stacked, key)

                for pa, b in zip(
                        jax.tree_util.tree_flatten_with_path(gathered)[0],
                        jax.tree.leaves(sharded)):
                    path, a = pa
                    assert a.shape == b.shape and a.dtype == b.dtype, \\
                        (name, codec, k, str(dt), str(path), a.shape, b.shape)
                    assert np.array_equal(np.asarray(a), np.asarray(b)), (
                        "sharded != gathered (bitwise)", name, codec, k,
                        str(dt), str(path),
                        float(np.max(np.abs(np.asarray(a, np.float64)
                                            - np.asarray(b, np.float64)))))
                checked += 1
    print("OK", checked)
""")


def test_every_aggregator_sharded_vs_gathered_bit_identical():
    """shard_map-mode aggregation on 8 model shards returns the same BITS
    as the gathered virtual-mode blocked oracle, for every registered
    aggregator × {even k=4, uneven k=3} grouping × {f32, bf16} — the
    testable form of the acceptance criterion "sharded and gathered
    aggregation are bit-identical for every registered rule"."""
    res = subprocess.run(
        [sys.executable, "-c", BLOCKED_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, (res.stdout[-800:], res.stderr[-4000:])
    assert "OK" in res.stdout

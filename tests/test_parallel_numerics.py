"""Distributed-numerics equality: every shard_map region and the full model
must produce identical results with and without a mesh (subprocess with 8
virtual devices; this is what makes the 512-chip dry-run trustworthy)."""

import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.models.meshctx import set_mesh

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    key = jax.random.PRNGKey(0)

    # --- sequence-parallel attention == dense ------------------------------
    from repro.models import attention
    from repro.models.attention import AttentionSpec
    spec = AttentionSpec(d_model=64, num_heads=6, num_kv_heads=2, head_dim=16,
                         qkv_bias=True, qk_norm=True)
    p = attention.init(key, spec, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128, 64))
    ref = attention.apply(p, spec, x)
    def loss_sp(p, x):
        return jnp.sum(attention.apply_sequence_parallel(
            p, spec, x, q_block=32, kv_block=32) ** 2)
    g_ref = jax.grad(lambda p, x: jnp.sum(attention.apply(p, spec, x)**2))(p, x)
    with set_mesh(mesh):
        sp = jax.jit(lambda pp, xx: attention.apply_sequence_parallel(
            pp, spec, xx, q_block=32, kv_block=32))(p, x)
        g_sp = jax.jit(jax.grad(loss_sp))(p, x)
    assert float(jnp.max(jnp.abs(ref - sp))) < 1e-4, "SP attention fwd"
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_sp)):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-3, "SP attention grad"

    # --- expert-parallel MoE == dense --------------------------------------
    from repro.models import moe
    mspec = moe.MoESpec(d_model=32, d_ff=64, num_experts=8,
                        experts_per_token=2, capacity_factor=8.0)
    mp = moe.init(key, mspec, dtype=jnp.float32)
    xm = jax.random.normal(jax.random.PRNGKey(2), (8, 16, 32))
    out_ref, aux_ref = moe._apply_dense(mp, mspec, xm)
    def mloss(p, xx):
        o, a = moe.apply(p, mspec, xx)
        return jnp.sum(o ** 2) + a
    gm_ref = jax.grad(mloss)(mp, xm)
    with set_mesh(mesh):
        out_ep, aux_ep = jax.jit(lambda p, xx: moe.apply(p, mspec, xx))(mp, xm)
        gm_ep = jax.jit(jax.grad(mloss))(mp, xm)
    assert float(jnp.max(jnp.abs(out_ref - out_ep))) < 1e-5, "EP fwd"
    assert abs(float(aux_ref) - float(aux_ep)) < 1e-5, "EP aux"
    for a, b in zip(jax.tree.leaves(gm_ref), jax.tree.leaves(gm_ep)):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-3, "EP grad"

    # --- Megatron SP+TP swiglu == local -------------------------------------
    from repro.models import layers
    sp_params = layers.swiglu_init(jax.random.PRNGKey(3), 64, 128,
                                   dtype=jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(4), (4, 128, 64))
    ref_s = layers._swiglu_local(sp_params["w_gate"], sp_params["w_up"],
                                 sp_params["w_down"], xs)
    def sloss(p, xx):
        return jnp.sum(layers.swiglu(p, xx) ** 2)
    gs_ref = jax.grad(lambda p, xx: jnp.sum(layers._swiglu_local(
        p["w_gate"], p["w_up"], p["w_down"], xx) ** 2))(sp_params, xs)
    with set_mesh(mesh):
        out_s = jax.jit(lambda p, xx: layers.swiglu(p, xx))(sp_params, xs)
        gs = jax.jit(jax.grad(sloss))(sp_params, xs)
    assert float(jnp.max(jnp.abs(ref_s - out_s))) < 1e-4, "swiglu fwd"
    for a, b in zip(jax.tree.leaves(gs_ref), jax.tree.leaves(gs)):
        scale = float(jnp.max(jnp.abs(a))) + 1e-9
        assert float(jnp.max(jnp.abs(a - b))) / scale < 1e-4, "swiglu grad"

    # --- sharded chunked WKV == sequential scan -----------------------------
    from repro.models import rwkv
    B, T, H, hd = 8, 128, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    r, k, v = (jax.random.normal(ks[i], (B, T, H, hd)) for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, hd))))
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    S0 = jax.random.normal(jax.random.PRNGKey(6), (B, H, hd, hd))
    y_ref, f_ref = rwkv.wkv_scan(r, k, v, w, u, S0)
    with set_mesh(mesh):
        y, f = jax.jit(rwkv._wkv_dispatch)(r, k, v, w, u, S0)
    assert float(jnp.max(jnp.abs(y_ref - y))) < 1e-3, "wkv"
    assert float(jnp.max(jnp.abs(f_ref - f))) < 1e-3, "wkv state"

    # --- full reduced model: loss under mesh == loss without ---------------
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("minitron-4b").reduced()
    params = M.init(jax.random.PRNGKey(7), cfg)
    tok = jax.random.randint(jax.random.PRNGKey(8), (8, 64),
                             0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, -1)}
    l_ref = float(M.loss_fn(params, batch, cfg))
    with set_mesh(mesh):
        l_mesh = float(jax.jit(
            lambda p, b: M.loss_fn(p, b, cfg))(params, batch))
    assert abs(l_ref - l_mesh) < 1e-3, (l_ref, l_mesh)
    print("OK")
""")


def test_parallel_numerics():
    res = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=1200,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, (res.stdout[-800:], res.stderr[-4000:])
    assert "OK" in res.stdout

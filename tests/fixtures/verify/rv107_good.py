# repro: train-scan
"""Fixture: StalenessBuffer with int32 ages everywhere (clean)."""
from typing import Any, NamedTuple

import jax.numpy as jnp


class StalenessBuffer(NamedTuple):
    grads: Any
    age: Any
    bound: Any


def make_buffer(grads, m, bound):
    return StalenessBuffer(grads, jnp.full((m,), bound + 1, jnp.int32),
                           jnp.asarray(bound, jnp.int32))


def tick(buf, fresh):
    return StalenessBuffer(
        buf.grads, jnp.where(fresh, 0, buf.age + 1).astype(jnp.int32),
        buf.bound)

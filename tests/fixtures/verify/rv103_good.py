"""Fixture: environment mutation confined to runtime calls (clean)."""
import os


def arm_host_devices(count):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={count}")


READ_ONLY = os.environ.get("XLA_FLAGS", "")   # reads are fine

# repro: train-scan
"""Fixture: StalenessBuffer built with float ages (RV107)."""
from typing import Any, NamedTuple

import jax.numpy as jnp


class StalenessBuffer(NamedTuple):
    grads: Any
    age: Any
    bound: Any


def make_buffer(grads, m, bound):
    # age starts as the float default dtype and is updated with float
    # arithmetic — drifts away from exact integers under accumulation
    return StalenessBuffer(grads, jnp.zeros((m,)), jnp.asarray(bound))


def tick(buf, fresh):
    return StalenessBuffer(buf.grads, jnp.where(fresh, 0.0, buf.age + 1.0),
                           buf.bound)

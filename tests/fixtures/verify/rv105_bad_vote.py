# repro: robust-stat
"""Fixture: majority-vote accumulation without f32 counts (RV105 x2)."""
import jax.numpy as jnp


def negative_votes(stacked):
    return jnp.sum(jnp.signbit(stacked), axis=0)    # bool counts, no up-cast


def vote_margin(stacked):
    return jnp.mean(jnp.sign(stacked), axis=0)      # accumulates in g.dtype

"""Fixture: PRNG keys threaded or confined to entry points (clean)."""
import jax


def from_seed(seed):
    return jax.random.PRNGKey(seed)


def main():
    key = jax.random.PRNGKey(0)   # entry point — exempt
    return key


if __name__ == "__main__":
    k = jax.random.PRNGKey(1)     # main guard — exempt
    main()

"""Fixture: suppression naming an unknown rule ID (RV100; the RV102
finding survives because ignore[RV999] does not cover it)."""
import jax

FIXED = jax.random.PRNGKey(0)  # repro: ignore[RV999] wrong rule id

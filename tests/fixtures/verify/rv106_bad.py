# repro: train-scan
"""Fixture: scan carry smuggling state past TrainState (RV106 x2)."""
import jax


def run(body, params, opt_state, staleness_buffer, xs):
    carry = jax.lax.scan(
        body, (params, opt_state, staleness_buffer, params[0]), xs)
    return carry

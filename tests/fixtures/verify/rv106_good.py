# repro: train-scan
"""Fixture: scan carry backed entirely by TrainState fields (clean)."""
import jax


def run(body, params, opt_state, astate, xs):
    carry = jax.lax.scan(body, (params, opt_state, astate), xs)
    return carry

# repro: robust-stat
"""Fixture: robust-stat reductions without f32 accumulation (RV105 x2)."""
import jax.numpy as jnp


def batch_means(stacked):
    return jnp.mean(stacked, axis=0)        # no visible f32 up-cast


def gram(a, b):
    return jnp.dot(a, b.T)                  # no preferred_element_type

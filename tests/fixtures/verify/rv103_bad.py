"""Fixture: import-time environment mutation (RV103 x3)."""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

if os.environ.get("CI"):
    os.environ.setdefault("JAX_PLATFORMS", "cpu")


class Config:
    os.environ["REPRO_MODE"] = "fixture"

# repro: bit-stable
"""Fixture: bit-stable module with only fixed-order reductions (clean)."""
import jax.numpy as jnp


def chain_total(parts):
    acc = parts[0]
    for p in parts[1:]:
        acc = acc + p
    return acc


def per_member_norms(stacked):
    # last-axis reduction — not the member axis; in scope but allowed
    return jnp.sum(stacked.astype(jnp.float32) ** 2, axis=-1)

"""Fixture: suppression without justification — RV102 is dropped but the
RV100 meta-finding keeps the build red (no silent baseline)."""
import jax

FIXED = jax.random.PRNGKey(0)  # repro: ignore[RV102]

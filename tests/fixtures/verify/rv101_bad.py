# repro: bit-stable
"""Fixture: member-axis jnp.sum in a bit-stable module (one RV101).

The operand is visibly f32 (astype) so RV105 stays quiet — the fixture
isolates the reassociation rule from the accumulation rule."""
import jax.numpy as jnp


def bad_partial(parts):
    return jnp.sum(parts.astype(jnp.float32), axis=0)

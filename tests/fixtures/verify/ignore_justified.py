"""Fixture: a justified suppression (clean — finding dropped, no RV100)."""
import jax

# repro: ignore[RV102] fixture demonstrates the escape hatch; key unused
FIXED = jax.random.PRNGKey(0)

"""Fixture: register calls with missing/invalid metadata (RV104 x3)."""
from repro.core import aggregators


@aggregators.register("no_metadata")
def no_metadata(stacked, **_kw):            # missing description AND contract
    return stacked


@aggregators.register("bad_contract", "has a description",
                      shard_contract="shardwise")   # not a valid contract
def bad_contract(stacked, **_kw):
    return stacked

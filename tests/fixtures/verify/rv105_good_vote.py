# repro: robust-stat
"""Fixture: f32-accumulated majority-vote counts (clean)."""
import jax.numpy as jnp


def negative_votes(stacked):
    return jnp.sum(jnp.signbit(stacked).astype(jnp.float32), axis=0)


def vote_margin(stacked):
    return jnp.mean(jnp.sign(stacked).astype(jnp.float32), axis=0)

# repro: robust-stat
"""Fixture: f32-accumulated robust-stat reductions (clean)."""
import jax.numpy as jnp


def batch_means(stacked):
    return jnp.mean(stacked.astype(jnp.float32), axis=0)


def gram(a, b):
    return jnp.dot(a, b.T, preferred_element_type=jnp.float32)

"""Fixture: fully declared register call (clean)."""
from repro.core import aggregators


@aggregators.register("declared", "coordinate-wise mean with metadata",
                      shard_contract="coordinate_wise")
def declared(stacked, **_kw):
    return stacked

"""Fixture: literal PRNGKey seeds outside entry points (RV102 x2)."""
import jax

FIXED_KEY = jax.random.PRNGKey(0)


def helper():
    return jax.random.key(42)

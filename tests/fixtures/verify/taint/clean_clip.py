"""Precision fixture for the Layer C taint tests.

This dummy READS report-tainted values everywhere — the median report
norm as a clip envelope, the coordinate median as the base value — but
only ever inside bounded ops, so a precise analysis must report it clean:
zero RV301 (the declared ``order_stat`` sanitizer is on every path) and
zero RV303 (the declaration matches the discovered kinds).  This is the
``norm_filter_gmom`` pattern reduced to its essence: a robust threshold
derived FROM the tainted reports is not a leak.
"""

import jax
import jax.numpy as jnp

from repro.core import aggregators
from repro.core.geometric_median import batch_mean_norms

NAME = "_clean_clip"


@aggregators.register(
    NAME,
    "test-only: coordinate median clamped into a median-norm envelope — "
    "tainted reads only inside bounded ops (taint-precision fixture)",
    needs_shard_spec=True, shard_contract="norm_based",
    sanitization_point="order_stat")
def _clean_clip_aggregator(stacked_grads, *, shard_spec=None, **_kw):
    norms = batch_mean_norms(stacked_grads, shard_spec=shard_spec)
    med = jnp.median(norms)   # tainted, but order-statistic bounded
    base = aggregators.coordinate_median_aggregator(stacked_grads)
    return jax.tree.map(
        lambda g: jnp.clip(g, -med, med).astype(g.dtype), base)


def unregister():
    aggregators._REGISTRY.pop(NAME, None)

"""Deliberately-leaky dummy aggregator for the Layer C taint tests.

It runs the full GMoM pipeline — so the Weiszfeld sanitizer IS on the
dataflow — and then adds a per-worker amax back onto the output: exactly
the "tainted codec scale applied post-aggregation" bug class RV301
exists to reject.  Importing this module registers ``_leaky_scale``
(underscore-prefixed: the verify CLI skips it unless explicitly named);
call :func:`unregister` in a ``finally`` block.
"""

import jax
import jax.numpy as jnp

from repro.core import aggregators

NAME = "_leaky_scale"


@aggregators.register(
    NAME,
    "test-only: GMoM then adds a report-derived amax scale AFTER the "
    "Weiszfeld sanitizer (the RV301 bug class)",
    needs_num_byzantine=True, needs_grouping=True, needs_shard_spec=True,
    shard_contract="norm_based", sanitization_point="weiszfeld")
def _leaky_scale_aggregator(stacked_grads, **kw):
    agg = aggregators.gmom_aggregator(stacked_grads, **kw)
    # the leak: an int8-codec-style per-worker amax, derived from the raw
    # reports and mixed into the output post-aggregation.
    leak = sum(jnp.max(jnp.abs(l.astype(jnp.float32)))
               for l in jax.tree.leaves(stacked_grads))
    return jax.tree.map(
        lambda g: (g + (1e-6 * leak).astype(g.dtype)).astype(g.dtype), agg)


def unregister():
    aggregators._REGISTRY.pop(NAME, None)

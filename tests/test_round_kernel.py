"""Fused Pallas round kernel (kernels/geomed/round.py) validation.

Three layers of guarantees:

(a) bit-equality — the kernel in interpret mode and its tile-mirroring jnp
    reference produce EXACTLY the same bytes for every grouping scheme and
    every (m, k, d) in the tier-1 matrix, including the uneven paper-scale
    m=50, k=11 partition (this is the acceptance bar for the fused lowering:
    no silent numerical drift between backends' formulations);
(b) semantics — the fused path agrees with the unfused jnp gmom pipeline
    (batch means -> Remark-2 trim -> pytree Weiszfeld) to float tolerance,
    for flat and nested gradient pytrees, and the in-kernel-gradient linreg
    variant agrees with vmap(value_and_grad) + gmom;
(c) system — a checked-in golden scenario trace replayed with
    round_backend="fused_interpret" reproduces the recorded trajectory.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators
from repro.core.grouping import assignment_matrix, make_grouping
from repro.core.robust_train import per_worker_grads
from repro.data import regression
from repro.kernels.geomed import round as round_kernel

# the tier-1 (m, k, d) matrix: even + uneven (paper-scale m=50, k=11),
# single-tile + multi-tile + unaligned d.
MKD_MATRIX = [
    (12, 6, 64),
    (20, 10, 1000),
    (50, 11, 777),        # uneven: the paper's experimental geometry
    (8, 4, 2048),
    pytest.param((64, 16, 4096), marks=pytest.mark.slow),
]
SCHEMES = ("contiguous", "strided", "seeded")


def _stacked(m, d, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(m, d)).astype(np.float32) + 1.0)


# ---------------------------------------------------------------------------
# (a) bit-equality: kernel (interpret) vs jnp reference

@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("mkd", MKD_MATRIX)
def test_round_kernel_bit_identical_to_ref(mkd, scheme):
    m, k, d = mkd
    g = _stacked(m, d, seed=m * d)
    grouping = make_grouping(m, k, scheme=scheme)
    ker = round_kernel.round_aggregate_kernel(g, grouping, interpret=True,
                                              max_iters=16)
    ref = round_kernel.round_aggregate_ref(g, grouping, max_iters=16)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))


@pytest.mark.parametrize("trim", [None, 1.0, 3.0])
def test_round_kernel_bit_identical_across_trim(trim):
    g = _stacked(16, 700, seed=7)
    # one huge outlier row so trim=1.0 actually zeroes a batch
    g = g.at[0].mul(100.0)
    grouping = make_grouping(16, 8)
    ker = round_kernel.round_aggregate_kernel(
        g, grouping, interpret=True, trim_multiplier=trim, max_iters=16)
    ref = round_kernel.round_aggregate_ref(
        g, grouping, trim_multiplier=trim, max_iters=16)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))


def test_linreg_round_kernel_bit_identical_to_ref():
    rng = np.random.default_rng(3)
    m, n, d, k = 12, 16, 300, 6
    x = jnp.asarray(rng.normal(size=(m, n, d)).astype(np.float32))
    t = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    theta = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    grouping = make_grouping(m, k)
    ker = round_kernel.linreg_round_kernel(x, t, theta, grouping,
                                           interpret=True, max_iters=16)
    ref = round_kernel.linreg_round_ref(x, t, theta, grouping, max_iters=16)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))


def test_median_small_matches_jnp_median():
    rng = np.random.default_rng(11)
    for k in (2, 3, 8, 11, 16):
        x = jnp.asarray(rng.normal(size=(k,)).astype(np.float32))
        np.testing.assert_allclose(
            float(round_kernel._median_small(x)), float(jnp.median(x)),
            rtol=1e-6)
        # ties must not break the rank-selection
        x_t = jnp.concatenate([x[: k // 2], x[: k - k // 2]])
        np.testing.assert_allclose(
            float(round_kernel._median_small(x_t)), float(jnp.median(x_t)),
            rtol=1e-6)


def test_round_kernel_rejects_over_budget_blocks():
    g = _stacked(4, 128)
    grouping = make_grouping(4, 2)
    with pytest.raises(ValueError, match="VMEM budget"):
        round_kernel._check_vmem(64, 64 * round_kernel.TILE_D)
    del g, grouping


# ---------------------------------------------------------------------------
# (b) semantics: fused vs the unfused jnp gmom pipeline

@pytest.mark.parametrize("scheme", SCHEMES)
@pytest.mark.parametrize("mkd", [(12, 6, 64), (20, 10, 1000), (50, 11, 777)])
def test_fused_gmom_matches_reference_flat(mkd, scheme):
    """Semantic agreement between the two independent pipelines (fused
    kernel vs pre-existing jnp reference) for EVERY grouping scheme — this
    is the non-circular check that the membership matrix and the
    reference's permute/reshape agree on the partition."""
    m, k, d = mkd
    g = _stacked(m, d, seed=1)
    ref = aggregators.gmom_aggregator(g, num_batches=k,
                                      grouping_scheme=scheme,
                                      round_backend="reference")
    fus = aggregators.gmom_aggregator(g, num_batches=k,
                                      grouping_scheme=scheme,
                                      round_backend="fused_interpret")
    assert fus.shape == ref.shape and fus.dtype == ref.dtype
    np.testing.assert_allclose(np.asarray(fus), np.asarray(ref),
                               rtol=1e-4, atol=1e-5)


def test_strided_batches_are_residue_classes():
    """Ground truth for the partition itself, independent of any
    aggregation code: the strided scheme puts worker w in batch w % k."""
    m, k = 12, 4
    grouping = make_grouping(m, k, scheme="strided")
    assert grouping.batches() == [[w for w in range(m) if w % k == l]
                                  for l in range(k)]
    s = assignment_matrix(grouping)
    for l in range(k):
        assert set(np.nonzero(s[l])[0]) == {w for w in range(m)
                                            if w % k == l}


def test_fused_gmom_matches_reference_pytree():
    rng = np.random.default_rng(2)
    s = {"w": jnp.asarray(rng.normal(size=(12, 5)).astype(np.float32) + 1.0),
         "b": {"x": jnp.asarray(
             rng.normal(size=(12, 2, 3)).astype(np.float32) + 1.0)}}
    ref = aggregators.gmom_aggregator(s, num_batches=6,
                                      round_backend="reference")
    fus = aggregators.gmom_aggregator(s, num_batches=6,
                                      round_backend="fused_interpret")
    assert jax.tree.structure(fus) == jax.tree.structure(ref)
    for a, b in zip(jax.tree.leaves(fus), jax.tree.leaves(ref)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_linreg_round_matches_unfused_ad_path():
    """The in-kernel gradient (raw batches in, aggregate out) equals
    vmap(value_and_grad) -> gmom to float tolerance — the whole round."""
    rng = np.random.default_rng(5)
    m, n, d, k = 20, 16, 400, 10
    x = jnp.asarray(rng.normal(size=(m, n, d)).astype(np.float32))
    t = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
    theta = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    grads, _ = per_worker_grads(regression.squared_loss, theta, (x, t))
    unfused = aggregators.gmom_aggregator(grads, num_batches=k,
                                          round_backend="reference",
                                          max_iters=16)
    fused = round_kernel.linreg_round_ref(x, t, theta,
                                          make_grouping(m, k), max_iters=16)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-4, atol=1e-5)


def test_uneven_batch_means_are_group_means():
    """The membership-matmul path (k does not divide m) computes exactly the
    per-group means of the permuted workers."""
    m, k = 10, 3
    g = _stacked(m, 7, seed=9)
    grouping = make_grouping(m, k, scheme="strided")
    means = aggregators.batch_means(g, k, scheme="strided")
    assert means.shape == (k, 7)
    s = assignment_matrix(grouping)
    for l, members in enumerate(grouping.batches()):
        assert sorted(np.nonzero(s[l])[0].tolist()) == sorted(members)
        np.testing.assert_allclose(
            np.asarray(means[l]),
            np.mean(np.asarray(g)[members], axis=0), rtol=1e-6)


def test_choose_num_batches_uneven_opt_in():
    """Default (prefer_even) keeps the historical divisor-based canonical k
    (golden-trace stable); prefer_even=False reaches the paper's exact
    experimental geometry m=50, q=5 -> k=11."""
    from repro.core.grouping import choose_num_batches
    assert choose_num_batches(50, 5) == 25                      # divisor
    assert choose_num_batches(50, 5, prefer_even=False) == 11   # paper
    assert choose_num_batches(20, 0) == 1


def test_shardmap_aggregate_rejects_uneven_k():
    """The hand-scheduled collective assumes the even contiguous partition;
    uneven k must fail loudly, not silently drop workers."""
    from repro.core.robust_train import RobustConfig, make_shardmap_aggregate
    cfg = RobustConfig(num_workers=50, num_byzantine=5, num_batches=11)
    with pytest.raises(ValueError, match=r"requires k \| m"):
        make_shardmap_aggregate(cfg, mesh=None)


def test_resolve_round_backend():
    resolve = aggregators.resolve_round_backend
    # explicit values pass through regardless of backend
    for b in ("reference", "fused", "fused_interpret"):
        assert resolve(b, num_batches=8) == b
    with pytest.raises(ValueError, match="round_backend"):
        resolve("nope", num_batches=8)
    # auto on a non-TPU host (this CI) resolves to the reference path
    if jax.default_backend() != "tpu":
        assert resolve("auto", num_batches=8, total_dim=1000) == "reference"
        assert resolve(None, num_batches=8) == "reference"


# ---------------------------------------------------------------------------
# (c) system: golden-trace replay through the fused path

def test_golden_replay_through_fused_path():
    """One checked-in golden scenario, re-run with the gmom hot path
    dispatched through the Pallas round kernel (interpret mode), reproduces
    the recorded trajectory.  Tolerance: the fused formulation computes in
    f32 with a different (but fixed) reduction order, so traces agree to
    float precision rather than byte-for-byte."""
    from repro import sim
    from repro.sim import goldens
    name = "linreg/gmom/sign_flip/rotating"
    trace = sim.run_scenario(name, round_backend="fused_interpret")
    gold = goldens.load_golden(name)
    np.testing.assert_allclose(np.array(trace["est_error"]),
                               np.array(gold["est_error"]),
                               rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(trace["final_est_error"],
                               gold["final_est_error"], rtol=1e-3)
    assert trace["byz_count"] == gold["byz_count"]

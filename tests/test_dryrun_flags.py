"""force_host_device_count flag hygiene (tier-1, no jax backend init):
idempotency, duplicate-flag normalization, and the already-initialized
guard.  The environment is monkeypatched — the live backend is never
touched (conftest pins JAX_PLATFORMS=cpu for the rest of the suite)."""

import pytest

from repro.launch import dryrun


@pytest.fixture
def uninitialized(monkeypatch):
    monkeypatch.setattr(dryrun, "_jax_backend_initialized", lambda: False)


def _flag_values(monkeypatch_env):
    import re
    return re.findall(r"--xla_force_host_platform_device_count=(\d+)",
                      monkeypatch_env)


def test_sets_flag_from_empty(monkeypatch, uninitialized):
    import os
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    dryrun.force_host_device_count(8)
    assert os.environ["XLA_FLAGS"] == \
        "--xla_force_host_platform_device_count=8"


def test_repeat_invocation_is_idempotent(monkeypatch, uninitialized):
    import os
    monkeypatch.delenv("XLA_FLAGS", raising=False)
    dryrun.force_host_device_count(8)
    first = os.environ["XLA_FLAGS"]
    dryrun.force_host_device_count(8)
    assert os.environ["XLA_FLAGS"] == first
    assert len(_flag_values(os.environ["XLA_FLAGS"])) == 1


def test_normalizes_preexisting_duplicates(monkeypatch, uninitialized):
    import os
    monkeypatch.setenv(
        "XLA_FLAGS",
        "--xla_dump_to=/tmp/x "
        "--xla_force_host_platform_device_count=8 "
        "--xla_force_host_platform_device_count=4")
    dryrun.force_host_device_count(2)
    vals = _flag_values(os.environ["XLA_FLAGS"])
    # exactly one occurrence, at the max of requested and pre-existing
    assert vals == ["8"]
    # unrelated flags survive
    assert "--xla_dump_to=/tmp/x" in os.environ["XLA_FLAGS"]


def test_takes_max_of_existing_and_requested(monkeypatch, uninitialized):
    import os
    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_force_host_platform_device_count=4")
    dryrun.force_host_device_count(512)
    assert _flag_values(os.environ["XLA_FLAGS"]) == ["512"]


def test_initialized_backend_with_enough_devices_is_noop(monkeypatch):
    import os
    monkeypatch.setattr(dryrun, "_jax_backend_initialized", lambda: True)
    monkeypatch.setattr(dryrun.jax, "device_count", lambda: 8)
    monkeypatch.setenv("XLA_FLAGS", "")
    dryrun.force_host_device_count(8)
    assert os.environ["XLA_FLAGS"] == ""


def test_initialized_backend_with_too_few_devices_raises(monkeypatch):
    monkeypatch.setattr(dryrun, "_jax_backend_initialized", lambda: True)
    monkeypatch.setattr(dryrun.jax, "device_count", lambda: 1)
    with pytest.raises(RuntimeError, match="already initialized"):
        dryrun.force_host_device_count(8)

"""Bit-exact adversarial resume.

The paper's guarantee covers ONE uninterrupted trajectory under a possibly
stateful adversary; these tests pin the contract that makes restarts safe: a
run interrupted at any checkpoint boundary and resumed from the saved
``TrainState`` (params + opt_state + attack_state + round + PRNG key +
metrics history) is bit-identical to the uninterrupted run — for every
schedule, including the stateful ``stealth_then_strike``.
"""

import os

import jax
import jax.numpy as jnp
import msgpack
import numpy as np
import pytest

from repro import checkpoint, optim, sim
from repro.core import (RobustConfig, byzantine, init_train_state,
                        make_run_rounds, restore_train_state,
                        save_train_state)
from repro.core.train_state import advance, history_rows
from repro.data import regression
from repro.launch.train import resume_train_state
from repro.sim import goldens

RESUME_SCHEDULES = ("static", "rotating", "stealth_then_strike")


def _setup(schedule_name, *, d=10, N=1600, m=16, q=3, seed=1):
    ds = regression.generate(jax.random.PRNGKey(seed), dim=d,
                             total_samples=N, num_workers=m)
    rc = RobustConfig(num_workers=m, num_byzantine=q, num_batches=8,
                      attack="sign_flip", aggregator="gmom")
    schedule = byzantine.make_schedule(schedule_name, num_workers=m,
                                       num_byzantine=q, attack="sign_flip")
    # adamw, not the paper's sgd: its (mu, nu, step) moments are exactly the
    # state a params-only resume silently dropped.
    opt = optim.adamw(1e-2)
    run = make_run_rounds(regression.squared_loss, opt, rc,
                          schedule=schedule)
    theta0 = jnp.zeros((d,))
    state0 = init_train_state(theta0, opt.init(theta0),
                              jax.random.PRNGKey(7), schedule=schedule)
    return run, state0, regression.worker_batches(ds), opt, schedule


def _assert_tree_equal(a, b, msg=""):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"{msg}: structure {ta} vs {tb}"
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


@pytest.mark.parametrize("schedule_name", RESUME_SCHEDULES)
def test_resume_is_bit_identical(schedule_name, tmp_path):
    """save-at-k / restore / continue == straight run: params, opt moments,
    attack state, round counter, and the full metrics trace."""
    run, state0, batches, opt, schedule = _setup(schedule_name)
    rounds, k = 20, 8

    straight, _ = advance(run, state0, batches, num_rounds=rounds)

    mid, _ = advance(run, state0, batches, num_rounds=k)
    save_train_state(str(tmp_path), mid)
    del mid                                   # the "crash"

    theta0 = jnp.zeros_like(state0.params)
    restored = restore_train_state(str(tmp_path), k, theta0,
                                   opt.init(theta0), schedule=schedule)
    assert int(restored.round_index) == k
    resumed, _ = advance(run, restored, batches, num_rounds=rounds - k)

    _assert_tree_equal(resumed.params, straight.params, "params")
    _assert_tree_equal(resumed.opt_state, straight.opt_state, "opt_state")
    _assert_tree_equal(resumed.attack_state, straight.attack_state,
                       "attack_state")
    _assert_tree_equal(resumed.base_key, straight.base_key, "base_key")
    assert int(resumed.round_index) == rounds
    assert history_rows(resumed.history) == history_rows(straight.history)


@pytest.mark.parametrize("schedule_name", byzantine.available_schedules())
def test_every_schedule_init_state_roundtrips(schedule_name, tmp_path):
    """AttackSchedule.init_state() pytrees are checkpointable: fixed
    structure, array leaves only, byte-stable through save/restore."""
    schedule = byzantine.make_schedule(schedule_name, num_workers=8,
                                       num_byzantine=2, attack="sign_flip")
    astate = schedule.init_state()
    for leaf in jax.tree.leaves(astate):
        assert hasattr(leaf, "dtype") and hasattr(leaf, "shape"), \
            f"{schedule_name}: non-array attack-state leaf {leaf!r}"
    checkpoint.save(str(tmp_path), 0, {"attack_state": astate})
    restored = checkpoint.restore(str(tmp_path), 0,
                                  {"attack_state": schedule.init_state()})
    _assert_tree_equal(restored["attack_state"], astate, schedule_name)

    # apply() must preserve the structure/dtypes (the checkpoint contract)
    stacked = {"w": jnp.ones((8, 4))}
    _, _, new_state = schedule.apply(stacked, jax.random.PRNGKey(0),
                                     jnp.asarray(0), astate)
    assert jax.tree_util.tree_structure(new_state) == \
        jax.tree_util.tree_structure(astate)
    for a, b in zip(jax.tree.leaves(new_state), jax.tree.leaves(astate)):
        assert a.dtype == b.dtype and a.shape == b.shape


def test_restore_rejects_dtype_mismatch(tmp_path):
    checkpoint.save(str(tmp_path), 0, {"w": jnp.ones((3,), jnp.float32)})
    with pytest.raises(ValueError, match="dtype mismatch"):
        checkpoint.restore(str(tmp_path), 0,
                           {"w": jnp.zeros((3,), jnp.bfloat16)})
    cast = checkpoint.restore(str(tmp_path), 0,
                              {"w": jnp.zeros((3,), jnp.bfloat16)},
                              allow_cast=True)
    assert cast["w"].dtype == jnp.bfloat16


def _make_legacy(directory, step, params):
    """A pre-versioning params-only checkpoint (no format_version key)."""
    checkpoint.save(directory, step, params)
    manifest_path = os.path.join(directory, f"step_{step:08d}",
                                 "manifest.msgpack")
    with open(manifest_path, "rb") as f:
        manifest = msgpack.unpackb(f.read())
    manifest.pop("format_version")
    with open(manifest_path, "wb") as f:
        f.write(msgpack.packb(manifest))


def test_manifest_format_version(tmp_path):
    checkpoint.save(str(tmp_path), 3, {"w": jnp.ones((2,))})
    assert checkpoint.read_manifest(str(tmp_path), 3)["format_version"] \
        == checkpoint.FORMAT_VERSION
    _make_legacy(str(tmp_path / "legacy"), 3, {"w": jnp.ones((2,))})
    assert checkpoint.read_manifest(str(tmp_path / "legacy"),
                                    3)["format_version"] == 1
    with pytest.raises(ValueError, match="legacy"):
        restore_train_state(str(tmp_path / "legacy"), 3, {"w": jnp.zeros(2)},
                            ())
    # a bare params tree saved through the current API is v2 but NOT a
    # TrainState — restore_train_state must refuse rather than KeyError
    with pytest.raises(ValueError, match="not a TrainState"):
        restore_train_state(str(tmp_path), 3, {"w": jnp.zeros(2)}, ())


def test_driver_resume_full_and_legacy(tmp_path, capsys):
    """launch.train.resume_train_state: full checkpoints restore the whole
    state; legacy params-only checkpoints restore params with a loud
    warning and fresh optimizer/adversary state."""
    opt = optim.adamw(1e-2)
    schedule = byzantine.make_schedule("stealth_then_strike", num_workers=4,
                                       num_byzantine=1, attack="sign_flip")
    params = {"w": jnp.arange(4, dtype=jnp.float32)}
    zeros = {"w": jnp.zeros((4,), jnp.float32)}
    key = jax.random.PRNGKey(3)

    # no checkpoint dir -> fresh state at round 0
    state, start = resume_train_state(None, params, opt.init(params),
                                      schedule, key)
    assert start == 0 and not state.history

    full_dir = str(tmp_path / "full")
    state = state._replace(
        round_index=jnp.asarray(5, jnp.int32),
        attack_state={"init_norm": jnp.asarray(2.5, jnp.float32),
                      "ema_norm": jnp.asarray(0.5, jnp.float32),
                      "struck": jnp.asarray(True)},
        history={"loss_median": np.arange(5, dtype=np.float32)})
    save_train_state(full_dir, state)
    restored, start = resume_train_state(full_dir, zeros, opt.init(zeros),
                                         schedule, jax.random.PRNGKey(0))
    assert start == 5
    _assert_tree_equal(restored.params, params)
    _assert_tree_equal(restored.attack_state, state.attack_state)
    _assert_tree_equal(restored.base_key, key)
    assert history_rows(restored.history) == history_rows(state.history)
    assert "restored full TrainState" in capsys.readouterr().out

    legacy_dir = str(tmp_path / "legacy")
    _make_legacy(legacy_dir, 7, params)
    restored, start = resume_train_state(legacy_dir, zeros, opt.init(zeros),
                                         schedule, key)
    out = capsys.readouterr().out
    assert "legacy params-only" in out
    assert "restarts with fresh adversary state" not in out
    assert start == 7 and int(restored.round_index) == 7
    _assert_tree_equal(restored.params, params)
    _assert_tree_equal(restored.opt_state, opt.init(zeros))   # fresh
    _assert_tree_equal(restored.attack_state, schedule.init_state())

    # a bare params tree saved with the CURRENT checkpoint.save (v2, no
    # train_state payload tag) takes the same compat path, not a crash
    bare_dir = str(tmp_path / "bare")
    checkpoint.save(bare_dir, 9, params)
    restored, start = resume_train_state(bare_dir, zeros, opt.init(zeros),
                                         schedule, key)
    assert "legacy params-only" in capsys.readouterr().out
    assert start == 9
    _assert_tree_equal(restored.params, params)


def test_replay_scenario_resume_matches_single_scan(tmp_path):
    """Engine-level contract: an interrupted-then-resumed checkpointed
    replay serializes to the same bytes as the uninterrupted scan."""
    name = "linreg/gmom/sign_flip/stealth_then_strike"
    straight = goldens.trace_bytes(sim.run_scenario(name, rounds=8))
    d = str(tmp_path / "ckpt")
    sim.replay_scenario(name, d, rounds=4, ckpt_every=3)     # "crash" at 4
    assert checkpoint.latest_step(d) == 4
    trace = sim.replay_scenario(name, d, rounds=8, ckpt_every=3)
    assert goldens.trace_bytes(trace) == straight
    # replaying an already-complete checkpoint just returns the trace
    again = sim.replay_scenario(name, d, rounds=8, ckpt_every=3)
    assert goldens.trace_bytes(again) == straight

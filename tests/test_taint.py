"""Layer C taint analysis: the influence-lattice engine unit tests, the
per-aggregator certificate table (the PR-5 soundness split rediscovered
from dataflow), precision fixtures (tainted reads inside bounded ops must
NOT fire), the deliberately-leaky dummy rejection in both shard modes
(subprocess: forced 8-device host mesh), the multi-round trace, the SARIF
CLI surface, and the ignore audit."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.verify import influence

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TAINT_FIXTURES = os.path.join(REPO, "tests", "fixtures", "verify", "taint")

RAW_REPORT = influence.raw("report")
CLEAN = influence.CLEAN_LABEL


def labels_of(fn, in_labels, *example_args):
    jaxpr = jax.make_jaxpr(fn)(*example_args)
    return influence.run_jaxpr(jaxpr, in_labels)


# --------------------------------------------------------------------------
# influence engine: per-primitive transfer rules

def test_sort_demotes_to_order_stat():
    x = jnp.zeros((8,))
    (out,) = labels_of(lambda g: jnp.median(g), [RAW_REPORT], x)
    assert out.level == influence.BOUNDED
    assert "order_stat" in out.kinds and out.sources == {"report"}


def test_mul_by_mask_does_not_launder():
    """The norm_select unsoundness: masking a raw report by a 0/1 mask
    (even one derived through an order statistic) rescales it — RAW."""
    x = jnp.zeros((8, 4))

    def f(g):
        norms = jnp.sqrt(jnp.sum(jnp.square(g), axis=1))
        keep = norms <= jnp.median(norms)
        return jnp.sum(g * keep[:, None], axis=0) / jnp.sum(keep)

    (out,) = labels_of(f, [RAW_REPORT], x)
    assert out.level == influence.RAW


def test_sum_and_mean_stay_raw():
    x = jnp.zeros((8, 4))
    (out,) = labels_of(lambda g: jnp.mean(g, axis=0), [RAW_REPORT], x)
    assert out.level == influence.RAW and out.kinds == frozenset()


def test_reduce_max_scale_stays_raw():
    """An int8-codec amax scale derived from the report is RAW — the
    dequantize-by-tainted-scale bug class."""
    x = jnp.zeros((8, 4))
    (out,) = labels_of(
        lambda g: jnp.max(jnp.abs(g)) * jnp.ones((4,)), [RAW_REPORT], x)
    assert out.level == influence.RAW


def test_gather_with_tainted_index_is_rank_select():
    x = jnp.zeros((8, 4))

    def f(g):
        norms = jnp.sum(jnp.square(g), axis=1)
        return g[jnp.argmin(norms)]

    (out,) = labels_of(f, [RAW_REPORT], x)
    assert out.level == influence.BOUNDED
    assert "rank_select" in out.kinds


def test_gather_with_clean_index_passes_label_through():
    x = jnp.zeros((8, 4))
    (out,) = labels_of(lambda g: g[0], [RAW_REPORT], x)
    assert out.level == influence.RAW


def test_select_n_over_clean_constants_is_sign_vote():
    x = jnp.zeros((8, 4))

    def f(g):
        votes = jnp.sum(jnp.sign(g).astype(jnp.float32), axis=0)
        return jnp.where(votes >= 0, 1.0, -1.0)

    (out,) = labels_of(f, [RAW_REPORT], x)
    assert out.level == influence.BOUNDED
    assert "sign_vote" in out.kinds


def test_select_n_with_tainted_branch_joins():
    x = jnp.zeros((8, 4))

    def f(g):
        s = jnp.sum(g, axis=0)
        return jnp.where(s >= 0, s, -1.0)

    (out,) = labels_of(f, [RAW_REPORT], x)
    assert out.level == influence.RAW


def test_clamp_against_clean_bounds_demotes_to_clip():
    x = jnp.zeros((8, 4))
    (out,) = labels_of(
        lambda g: jax.lax.clamp(-1.0, jnp.sum(g, axis=0), 1.0),
        [RAW_REPORT], x)
    assert out.level == influence.BOUNDED and "clip" in out.kinds


def test_bool_outputs_cap_and_chains_stay_bounded():
    x = jnp.zeros((8,))

    def f(g):
        a = g > 0.0
        b = g < 1.0
        return jnp.sum(jnp.logical_and(a, b).astype(jnp.int32))

    (out,) = labels_of(f, [RAW_REPORT], x)
    assert out.level == influence.BOUNDED
    assert out.kinds == frozenset()   # a bool is not a sanitizer


def test_while_fixpoint_terminates_and_carries_taint():
    x = jnp.zeros((4,))

    def f(g):
        def body(c):
            i, acc = c
            return i + 1, acc + g
        return jax.lax.while_loop(lambda c: c[0] < 10, body,
                                  (0, jnp.zeros((4,))))[1]

    (out,) = labels_of(f, [RAW_REPORT], x)
    assert out.level == influence.RAW


def test_arity_mismatch_raises():
    jaxpr = jax.make_jaxpr(lambda a, b: a + b)(1.0, 2.0)
    with pytest.raises(ValueError, match="arity"):
        influence.run_jaxpr(jaxpr, [CLEAN])


# --------------------------------------------------------------------------
# the certificate table: PR-5 soundness split from dataflow alone

EXPECTED = {
    # ROBUST family — BOUNDED with the declared sanitizer on the dataflow
    "coord_median": (influence.BOUNDED, {"order_stat"}),
    "coord_trimmed_mean": (influence.BOUNDED, {"order_stat"}),
    "coordinate_median": (influence.BOUNDED, {"order_stat"}),
    "trimmed_mean": (influence.BOUNDED, {"order_stat"}),
    "geomed": (influence.BOUNDED, {"weiszfeld"}),
    "gmom_per_leaf": (influence.BOUNDED, {"weiszfeld"}),
    "gmom": (influence.BOUNDED, {"order_stat", "weiszfeld"}),
    "int8_gmom": (influence.BOUNDED, {"order_stat", "weiszfeld"}),
    "norm_filter_gmom": (influence.BOUNDED, {"order_stat", "weiszfeld"}),
    "krum": (influence.BOUNDED, {"order_stat", "rank_select"}),
    "sign_sgd_majority": (influence.BOUNDED, {"sign_vote"}),
    # KNOWN-UNSOUND family — RAW no matter what robust ops appear upstream
    "mean": (influence.RAW, set()),
    "random_select": (influence.RAW, set()),
    "norm_select": (influence.RAW, {"order_stat"}),
    "norm_clip_mean": (influence.RAW, {"order_stat"}),
}

KNOWN_UNSOUND = {"mean", "norm_select", "norm_clip_mean"}


def test_certificate_table_unsharded():
    from repro.core import aggregators
    from repro.verify import taint
    names = [n for n in aggregators.available() if not n.startswith("_")]
    assert set(names) == set(EXPECTED), "table drifted from the registry"
    for name in names:
        rep = taint.classify_aggregator(name)
        level, kinds = EXPECTED[name]
        assert (rep.level, set(rep.kinds)) == (level, kinds), \
            (name, rep.level, sorted(rep.kinds))


def test_soundness_split_rediscovered_from_dataflow():
    """The acceptance-criteria core: ROBUST ⊆ bounded and the PR-5
    KNOWN-UNSOUND set ⊆ unbounded, with zero name-based special cases in
    the engine — and every bounded rule's declaration matches a
    discovered kind."""
    from repro.core import aggregators
    from repro.verify import taint
    for name in (n for n in aggregators.available()
                 if not n.startswith("_")):
        rep = taint.classify_aggregator(name)
        declared = aggregators.get_aggregator(name).sanitization_point
        if name in KNOWN_UNSOUND:
            assert not rep.bounded, name
            assert declared is None, name
        if declared is not None:
            assert rep.bounded and declared in rep.kinds, \
                (name, declared, sorted(rep.kinds))


def test_certificates_clean_of_findings():
    from repro.core import aggregators
    from repro.verify import taint
    for name in (n for n in aggregators.available()
                 if not n.startswith("_")):
        assert taint.check_aggregator_taint(name) == [], name


# --------------------------------------------------------------------------
# fixture corpus: the leaky dummy fires, the precision dummy does not

def _load_fixture(modname):
    import importlib
    if TAINT_FIXTURES not in sys.path:
        sys.path.insert(0, TAINT_FIXTURES)
    return importlib.import_module(modname)


def test_leaky_dummy_rejected_rv301_unsharded():
    from repro.verify import contracts, taint
    mod = _load_fixture("leaky_scale")
    try:
        fs = taint.check_aggregator_taint(mod.NAME)
        assert fs and all(f.rule == "RV301" for f in fs), \
            [f.format() for f in fs]
        assert any("sanitization_point='weiszfeld'" in f.message
                   for f in fs)
        assert all(f.path == f"<aggregator:{mod.NAME}>" for f in fs)
    finally:
        mod.unregister()
        contracts.clear_trace_cache()


def test_clean_clip_zero_false_positives():
    """Precision: a dummy that READS tainted values everywhere (median
    norm envelope, coordinate-median base) but only inside bounded ops
    must produce zero RV301/RV303."""
    from repro.verify import contracts, taint
    mod = _load_fixture("clean_clip")
    try:
        rep = taint.classify_aggregator(mod.NAME)
        assert rep.bounded and "order_stat" in rep.kinds
        assert taint.check_aggregator_taint(mod.NAME) == []
    finally:
        mod.unregister()
        contracts.clear_trace_cache()


def test_norm_filter_gmom_precision():
    """The production analogue of the precision fixture: its norm filter
    reads every raw report, yet the certificate stays bounded."""
    from repro.verify import taint
    rep = taint.classify_aggregator("norm_filter_gmom")
    assert rep.bounded
    assert taint.check_aggregator_taint("norm_filter_gmom") == []


def test_undeclared_but_bounded_dummy_fires_rv303():
    """A rule whose dataflow IS robust but whose registration forgot the
    declaration: the certificate comparison flags the stale metadata."""
    from repro.core import aggregators
    from repro.verify import contracts, taint

    @aggregators.register("_test_undeclared_median",
                          "test-only: coordinate median with no declared "
                          "sanitization_point")
    def _undeclared(stacked, **_kw):
        return aggregators.coordinate_median_aggregator(stacked)

    try:
        fs = taint.check_aggregator_taint("_test_undeclared_median")
        assert [f.rule for f in fs] == ["RV303"], [f.format() for f in fs]
        assert "stale" in fs[0].message
    finally:
        aggregators._REGISTRY.pop("_test_undeclared_median", None)
        contracts.clear_trace_cache()


# --------------------------------------------------------------------------
# the multi-round trace

def test_round_trace_green():
    from repro.verify import taint
    assert taint.check_round_taint() == []


def test_round_trace_section_labels():
    from repro.verify import taint
    rows = taint.classify_round()
    by_section = {}
    for section, _path, label in rows:
        by_section.setdefault(section, []).append(label)
    # reports reach params only through the bounded aggregator channel
    assert all(l.level == influence.BOUNDED
               for l in by_section["params"])
    assert all(l.level < influence.RAW for l in by_section["opt_state"])
    assert all(l.level < influence.RAW for l in by_section["metrics"])
    # ages couple rounds through timing only — never report values
    for l in by_section["stale_buffer.age"]:
        assert "report" not in l.sources
    # the buffered last reports are adversary memory: necessarily RAW
    assert any(l.level == influence.RAW
               for l in by_section["stale_buffer.grads"])


def test_round_red_paths_fire(monkeypatch):
    """RV301/RV302 finding logic over fabricated round labels: a RAW
    params leaf, a RAW metrics leaf, and a report-steered age."""
    from repro.verify import taint
    rows = [
        ("params", "['w']", influence.raw("report")),
        ("metrics", "['agg_grad_norm']", influence.raw("report")),
        ("stale_buffer.age", "", influence.Label(
            level=influence.BOUNDED, kinds=frozenset({"order_stat"}),
            sources=frozenset({"report"}))),
        ("attack_state", "['ema_norm']", influence.raw("attack_state")),
    ]
    monkeypatch.setattr(taint, "classify_round", lambda **_kw: rows)
    fs = taint.check_round_taint()
    assert sorted(f.rule for f in fs) == ["RV301", "RV302", "RV302"]
    assert any("params['w']" in f.message for f in fs)
    assert any("report VALUES" in f.message for f in fs)
    assert all(f.path == taint.ROUND_ANCHOR for f in fs)


# --------------------------------------------------------------------------
# shard_map parity (subprocess: the virtual-device flag must be set
# before jax initializes)

SHARD_MAP_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, {fixtures!r})
    from repro.verify import contracts, influence, taint

    # parity: one aggregator per sanitizer family keeps its certificate
    # under the shard_map lowering (psum partials, per-shard bodies)
    for name, kind in [("gmom", "weiszfeld"), ("coord_median", "order_stat"),
                       ("krum", "rank_select"),
                       ("sign_sgd_majority", "sign_vote")]:
        rep = taint.classify_aggregator(name, mode="shard_map")
        assert rep.bounded and kind in rep.kinds, \\
            (name, rep.level, sorted(rep.kinds))
        assert taint.check_aggregator_taint(name, mode="shard_map") == []

    rep = taint.classify_aggregator("mean", mode="shard_map")
    assert rep.level == influence.RAW

    # the leaky dummy is rejected under shard_map too
    import leaky_scale
    try:
        fs = taint.check_aggregator_taint(leaky_scale.NAME,
                                          mode="shard_map")
        assert fs and all(f.rule == "RV301" for f in fs), \\
            [f.format() for f in fs]
        assert all("shard_map" in f.message for f in fs)
    finally:
        leaky_scale.unregister()
        contracts.clear_trace_cache()
    print("OK")
""").format(fixtures=TAINT_FIXTURES)


def test_shard_map_parity_and_leaky_rejection():
    res = subprocess.run(
        [sys.executable, "-c", SHARD_MAP_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, (res.stdout[-800:], res.stderr[-4000:])
    assert "OK" in res.stdout


# --------------------------------------------------------------------------
# nightly: the full aggregator × codec × mode matrix (RV301 on every
# cell, the declared↔discovered comparison on canonical cells only)

FULL_MATRIX_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    from repro.verify import taint
    fs = taint.run_taint(full_matrix=True, log=lambda *a, **k: None)
    assert fs == [], [f.format() for f in fs]
    print("OK")
""")


@pytest.mark.slow
def test_full_matrix_clean():
    res = subprocess.run(
        [sys.executable, "-c", FULL_MATRIX_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, (res.stdout[-800:], res.stderr[-4000:])
    assert "OK" in res.stdout


# --------------------------------------------------------------------------
# CLI: SARIF serialization + the ignore audit

def _run_cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.verify", *args],
        capture_output=True, text=True, timeout=300,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))


def fx(name):
    return os.path.join(REPO, "tests", "fixtures", "verify", name)


def test_cli_sarif_stdout_is_machine_parseable():
    res = _run_cli("--layer", "a", "--strict", "--format", "sarif",
                   "--paths", fx("rv102_bad.py"))
    assert res.returncode == 1, (res.stdout, res.stderr)
    doc = json.loads(res.stdout)       # progress went to stderr
    assert doc["version"] == "2.1.0"
    results = doc["runs"][0]["results"]
    assert {r["ruleId"] for r in results} == {"RV102"}
    loc = results[0]["locations"][0]["physicalLocation"]
    assert loc["region"]["startLine"] >= 1
    rules = doc["runs"][0]["tool"]["driver"]["rules"]
    assert [r["id"] for r in rules] == ["RV102"]
    assert "[verify]" in res.stderr and "[verify]" not in res.stdout


def test_cli_sarif_output_file_written_even_under_strict(tmp_path):
    out = tmp_path / "verify.sarif"
    res = _run_cli("--layer", "a", "--strict", "--format", "sarif",
                   "--output", str(out), "--paths", fx("rv102_bad.py"))
    assert res.returncode == 1
    doc = json.loads(out.read_text())
    assert doc["runs"][0]["results"]


def test_cli_audit_ignores_clean_tree():
    res = _run_cli("--audit-ignores")
    assert res.returncode == 0, (res.stdout, res.stderr)
    assert "0 stale" in res.stdout
    # the one real escape hatch in the tree is listed with its reason
    assert "launch/steps.py" in res.stdout
    assert "eval_shape only traces" in res.stdout


def test_cli_audit_ignores_fails_on_stale_rule_id():
    res = _run_cli("--audit-ignores", "--paths", fx("ignore_unknown.py"))
    assert res.returncode == 1, (res.stdout, res.stderr)
    assert "STALE" in res.stdout


def test_cli_taint_layer_only():
    res = _run_cli("--layer", "c", "--strict", "--aggregators",
                   "coord_median", "mean")
    assert res.returncode == 0, (res.stdout, res.stderr)
    assert "layer C" in res.stdout

"""Pallas kernel validation: interpret-mode vs pure-jnp oracles
(shape/dtype sweeps + hypothesis when available, seed sweeps otherwise)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import geometric_median
from repro.kernels.attention import flash, ref as attn_ref
from repro.kernels.geomed import geomed, ops as geomed_ops, \
    ref as geomed_ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    settings.register_profile("kernels", max_examples=10, deadline=None)
    settings.load_profile("kernels")


# ---------------------------------------------------------------------------
# geomed kernel

@pytest.mark.parametrize(
    "k,d", [(2, 64), (8, 1000),
            pytest.param(16, 4096, marks=pytest.mark.slow),
            pytest.param(64, 512, marks=pytest.mark.slow),
            (5, 777)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_geomed_sqdist_sweep(k, d, dtype):
    key = jax.random.PRNGKey(k * d)
    Z = jax.random.normal(key, (k, d), dtype)
    y = jax.random.normal(jax.random.fold_in(key, 1), (d,), dtype)
    out = geomed.sqdist(Z, y, interpret=True)
    expected = geomed_ref.weiszfeld_distances_ref(Z, y)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=2e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("k,d", [(4, 512), (8, 1000), (32, 2048)])
def test_geomed_step_sweep(k, d):
    key = jax.random.PRNGKey(d)
    Z = jax.random.normal(key, (k, d), jnp.float32)
    y = jax.random.normal(jax.random.fold_in(key, 1), (d,), jnp.float32)
    w = jax.random.uniform(jax.random.fold_in(key, 2), (k,)) + 0.1
    out = geomed.weiszfeld_step(Z, y, w, interpret=True)
    expected = geomed_ref.weiszfeld_step_ref(Z, y, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               rtol=1e-4, atol=1e-5)


def _geomed_cases():
    if HAVE_HYPOTHESIS:
        return given(st.integers(2, 12), st.integers(1, 200),
                     st.integers(0, 2**31 - 1))
    return pytest.mark.parametrize(
        "k,d,seed", [(2, 1, 0), (3, 17, 1), (8, 64, 2), (12, 200, 3),
                     (5, 100, 4)])


@_geomed_cases()
def test_geomed_full_vs_core(k, d, seed):
    Z = jnp.asarray(
        np.random.default_rng(seed).normal(size=(k, d)).astype(np.float32))
    kernel = geomed_ops.geometric_median_kernel(Z, interpret=True,
                                                max_iters=64)
    core = geometric_median(Z, max_iters=64)
    np.testing.assert_allclose(np.asarray(kernel), np.asarray(core),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# flash attention kernel

ATTN_CASES = [
    # (B, Tq, Tk, H, KV, hd, causal, window)
    (2, 64, 64, 4, 2, 32, True, None),
    pytest.param((1, 128, 128, 8, 8, 64, True, None),
                 marks=pytest.mark.slow),
    (2, 100, 100, 4, 1, 32, True, None),        # unaligned T
    pytest.param((1, 256, 256, 4, 2, 64, True, 64),   # sliding window
                 marks=pytest.mark.slow),
    (2, 64, 64, 4, 4, 32, False, None),         # bidirectional
    (1, 96, 96, 6, 2, 16, True, 32),            # window + GQA + odd heads
]


@pytest.mark.parametrize("case", ATTN_CASES)
@pytest.mark.parametrize(
    "dtype", [jnp.float32,
              pytest.param(jnp.bfloat16, marks=pytest.mark.slow)])
def test_flash_attention_sweep(case, dtype):
    B, Tq, Tk, H, KV, hd, causal, window = case
    key = jax.random.PRNGKey(hash(case) % (2**31))
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, Tq, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, Tk, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, Tk, KV, hd), dtype)
    out = flash.flash_attention(q, k, v, causal=causal,
                                sliding_window=window,
                                block_q=32, block_kv=32, interpret=True)
    expected = attn_ref.flash_attention_ref(q, k, v, causal=causal,
                                            sliding_window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=tol, rtol=tol)


def _flash_cases():
    if HAVE_HYPOTHESIS:
        return given(st.integers(1, 2), st.sampled_from([16, 48, 64]),
                     st.sampled_from([(4, 2), (4, 4), (2, 1)]),
                     st.booleans(), st.integers(0, 2**31 - 1))
    return pytest.mark.parametrize(
        "B,T,heads,causal,seed",
        [(1, 16, (4, 2), True, 0), (2, 48, (4, 4), False, 1),
         pytest.param(1, 64, (2, 1), True, 2, marks=pytest.mark.slow)])


@_flash_cases()
def test_flash_attention_property(B, T, heads, causal, seed):
    H, KV = heads
    hd = 16
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(B, T, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)).astype(np.float32))
    out = flash.flash_attention(q, k, v, causal=causal, block_q=16,
                                block_kv=16, interpret=True)
    expected = attn_ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=1e-4, rtol=1e-4)


def test_flash_attention_rows_are_convex_combos():
    """Each output row is a convex combination of v rows => bounded by
    [min(v), max(v)] per feature."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 32, 2, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 32, 2, 16))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 32, 2, 16))
    out = flash.flash_attention(q, k, v, causal=False, block_q=16,
                                block_kv=16, interpret=True)
    lo = jnp.min(v) - 1e-4
    hi = jnp.max(v) + 1e-4
    assert float(jnp.min(out)) >= float(lo)
    assert float(jnp.max(out)) <= float(hi)

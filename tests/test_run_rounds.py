"""Scan-compiled multi-round trainer: step-for-step equivalence with the
Python-loop trainer, wall-clock speedup, and schedule-state carry."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import (RobustConfig, byzantine, make_robust_train_step,
                        make_run_rounds)
from repro.data import regression


def _linreg(d=20, N=4000, m=20, seed=1):
    ds = regression.generate(jax.random.PRNGKey(seed), dim=d,
                             total_samples=N, num_workers=m)
    return ds, regression.worker_batches(ds)


def test_scan_reproduces_loop_exactly():
    """run_rounds must equal the per-step jit loop bit-for-bit: same keys
    (fold_in(key, t) per round), same mask, same attack, same aggregation."""
    d, N, m, q = 20, 4000, 20, 3
    ds, batches = _linreg(d, N, m)
    rc = RobustConfig(num_workers=m, num_byzantine=q, num_batches=10,
                      attack="sign_flip", aggregator="gmom")
    opt = optim.sgd(0.5)
    base_key = jax.random.PRNGKey(7)
    rounds = 20

    step = jax.jit(make_robust_train_step(regression.squared_loss, opt, rc))
    theta = jnp.zeros((d,))
    opt_state = opt.init(theta)
    loop_metrics = []
    for t in range(rounds):
        theta, opt_state, mt = step(theta, opt_state, batches,
                                    jax.random.fold_in(base_key, t), t)
        loop_metrics.append(mt)

    run = make_run_rounds(regression.squared_loss, opt, rc)
    theta0 = jnp.zeros((d,))
    theta_s, _, _, _, metrics = run(theta0, opt.init(theta0), batches,
                                    base_key, num_rounds=rounds)

    np.testing.assert_array_equal(np.asarray(theta), np.asarray(theta_s))
    for k in ("loss_mean", "loss_median", "agg_grad_norm"):
        np.testing.assert_array_equal(
            np.asarray(jnp.stack([mt[k] for mt in loop_metrics])),
            np.asarray(metrics[k]), err_msg=k)


def test_scan_speedup_over_loop():
    """One scan dispatch for a multi-round CPU scenario must beat the
    per-step dispatch loop by >= 3x wall-clock (typically much more: the
    loop pays Python+dispatch overhead every round, the scan pays it
    once — 100 rounds keeps the margin wide even on loaded CI boxes)."""
    d, N, m, q = 10, 1000, 20, 3
    ds, batches = _linreg(d, N, m, seed=2)
    rc = RobustConfig(num_workers=m, num_byzantine=q, num_batches=10,
                      attack="sign_flip", aggregator="gmom")
    opt = optim.sgd(0.5)
    base_key = jax.random.PRNGKey(0)
    rounds = 100
    theta0 = jnp.zeros((d,))

    step = jax.jit(make_robust_train_step(regression.squared_loss, opt, rc))
    run = make_run_rounds(regression.squared_loss, opt, rc)

    # warm both compilations before timing
    jax.block_until_ready(step(theta0, opt.init(theta0), batches,
                               base_key, 0)[0])
    jax.block_until_ready(run(theta0, opt.init(theta0), batches, base_key,
                              num_rounds=rounds)[0])

    def time_loop():
        th, st = theta0, opt.init(theta0)
        t0 = time.perf_counter()
        for t in range(rounds):
            th, st, _ = step(th, st, batches,
                             jax.random.fold_in(base_key, t), t)
        jax.block_until_ready(th)
        return time.perf_counter() - t0

    def time_scan():
        t0 = time.perf_counter()
        out = run(theta0, opt.init(theta0), batches, base_key,
                  num_rounds=rounds)
        jax.block_until_ready(out[0])
        return time.perf_counter() - t0

    # best-of-3 to damp CI noise
    t_loop = min(time_loop() for _ in range(3))
    t_scan = min(time_scan() for _ in range(3))
    assert t_loop >= 3.0 * t_scan, \
        f"scan not >=3x faster: loop={t_loop * 1e3:.1f}ms " \
        f"scan={t_scan * 1e3:.1f}ms"


def test_per_round_batches_mode():
    """Leading-axis batches: round t consumes slice t (streaming regime)."""
    d, N, m = 8, 800, 8
    rounds = 6
    rc = RobustConfig(num_workers=m, num_byzantine=1, num_batches=4,
                      attack="sign_flip", aggregator="gmom")
    opt = optim.sgd(0.5)
    key = jax.random.PRNGKey(3)
    per_round = []
    for t in range(rounds):
        ds = regression.generate(jax.random.fold_in(key, t), dim=d,
                                 total_samples=N, num_workers=m)
        per_round.append(regression.worker_batches(ds))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_round)

    run = make_run_rounds(regression.squared_loss, opt, rc)
    theta0 = jnp.zeros((d,))
    theta, _, _, _, metrics = run(theta0, opt.init(theta0), stacked, key,
                                  per_round_batches=True)
    assert metrics["loss_median"].shape == (rounds,)
    assert bool(jnp.all(jnp.isfinite(theta)))

    # chunked (3 + 3) with start_round continuation == one 6-round call
    first3 = jax.tree.map(lambda x: x[:3], stacked)
    last3 = jax.tree.map(lambda x: x[3:], stacked)
    th, st, astate, _, _ = run(theta0, opt.init(theta0), first3, key,
                               per_round_batches=True)
    th, _, _, _, _ = run(th, st, last3, key, start_round=3,
                         attack_state=astate, per_round_batches=True)
    np.testing.assert_array_equal(np.asarray(theta), np.asarray(th))


def test_stealth_schedule_state_carries_through_scan():
    """stealth_then_strike must stay quiet early, then latch and attack —
    visible in the per-round byz_count metric from a single scan."""
    d, N, m, q = 20, 4000, 20, 3
    ds, batches = _linreg(d, N, m)
    rc = RobustConfig(num_workers=m, num_byzantine=q, num_batches=10,
                      attack="sign_flip", aggregator="gmom")
    sched = byzantine.make_schedule(
        "stealth_then_strike", num_workers=m, num_byzantine=q,
        attack="sign_flip")
    opt = optim.sgd(0.5)
    run = make_run_rounds(regression.squared_loss, opt, rc, schedule=sched)
    theta0 = jnp.zeros((d,))
    _, _, astate, _, metrics = run(theta0, opt.init(theta0), batches,
                                   jax.random.PRNGKey(5), num_rounds=30)
    counts = np.asarray(metrics["byz_count"])
    assert counts[0] == 0, "must start honest"
    assert counts[-1] == q, "must end striking"
    strike_at = int(np.argmax(counts > 0))
    assert 0 < strike_at < 30
    # latch: once striking, never stops
    assert np.all(counts[strike_at:] == q)
    assert bool(astate["struck"])


def test_ramp_up_schedule_monotone_q():
    d, N, m, q = 10, 1000, 20, 4
    ds, batches = _linreg(d, N, m, seed=4)
    rc = RobustConfig(num_workers=m, num_byzantine=q, num_batches=10,
                      attack="sign_flip", aggregator="gmom")
    sched = byzantine.make_schedule("ramp_up", num_workers=m,
                                    num_byzantine=q, attack="sign_flip",
                                    ramp_rounds=12)
    opt = optim.sgd(0.5)
    run = make_run_rounds(regression.squared_loss, opt, rc, schedule=sched)
    theta0 = jnp.zeros((d,))
    _, _, _, _, metrics = run(theta0, opt.init(theta0), batches,
                              jax.random.PRNGKey(6), num_rounds=20)
    counts = np.asarray(metrics["byz_count"])
    assert np.all(np.diff(counts) >= 0)
    assert counts[0] == 1 and counts[-1] == q


def test_coordinated_switch_changes_attack_at_round():
    """Before switch_round the colluders sign_flip (huge norms); after they
    run the small-norm inner_product attack — visible in reported norms."""
    m, q, d = 8, 2, 6
    sched = byzantine.make_schedule(
        "coordinated_switch", num_workers=m, num_byzantine=q,
        attack="sign_flip", attack_b="zero", switch_round=5, rotate=False)
    stacked = {"w": jnp.ones((m, d))}
    state = sched.init_state()
    key = jax.random.PRNGKey(0)
    before, mask, state = sched.apply(stacked, key, jnp.asarray(2), state)
    after, _, _ = sched.apply(stacked, key, jnp.asarray(7), state)
    np.testing.assert_allclose(np.asarray(before["w"][0]), -10.0)  # sign_flip
    np.testing.assert_allclose(np.asarray(after["w"][0]), 0.0)     # zero
    np.testing.assert_allclose(np.asarray(after["w"][q:]), 1.0)    # honest

"""repro.verify — Layer A fixture corpus (exact rule IDs + spans), the
escape-hatch policy, the CLI exit-code contract, the VMEM drift gate, and
the Layer-B shard-contract analyzer (subprocess: forced 8-device host
mesh) including rejection of a deliberately mis-declared aggregator."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "fixtures", "verify")


def fx(name):
    return os.path.join(FIXTURES, name)


def lint(name):
    from repro.verify import lint_file
    return lint_file(fx(name))


def ids_lines(findings):
    return [(f.rule, f.line) for f in findings]


# --------------------------------------------------------------------------
# Layer A: one bad + one clean fixture per rule, exact IDs and lines

def test_rv101_bad_exact_span():
    fs = lint("rv101_bad.py")
    assert ids_lines(fs) == [("RV101", 10)]
    f = fs[0]
    assert f.col == 11 and f.end_line == 10        # the call expression
    assert "axis=0" in f.message and "chain" in f.message


def test_rv101_good_clean():
    assert lint("rv101_good.py") == []


def test_rv102_bad_both_constructors():
    fs = lint("rv102_bad.py")
    assert ids_lines(fs) == [("RV102", 4), ("RV102", 8)]
    assert "jax.random.PRNGKey(0)" in fs[0].message
    assert "jax.random.key(42)" in fs[1].message


def test_rv102_good_entry_points_exempt():
    assert lint("rv102_good.py") == []


def test_rv103_bad_import_time_mutations():
    fs = lint("rv103_bad.py")
    assert [f.rule for f in fs] == ["RV103"] * 3
    assert [f.line for f in fs] == [4, 7, 11]      # top-level / if / class


def test_rv103_good_runtime_only():
    assert lint("rv103_good.py") == []


def test_rv104_bad_missing_and_invalid_metadata():
    fs = lint("rv104_bad.py")
    assert [f.rule for f in fs] == ["RV104"] * 3
    # two findings on the bare register (no description, no contract),
    # one on the invalid contract literal
    assert [f.line for f in fs] == [5, 5, 10]
    assert "description" in fs[0].message
    assert "shard_contract" in fs[1].message
    assert "literal" in fs[2].message


def test_rv104_good_clean():
    assert lint("rv104_good.py") == []


def test_rv105_bad_mean_and_dot():
    fs = lint("rv105_bad.py")
    assert ids_lines(fs) == [("RV105", 7), ("RV105", 11)]
    assert "axis=0" in fs[0].message
    assert "preferred_element_type" in fs[1].message


def test_rv105_good_clean():
    assert lint("rv105_good.py") == []


def test_rv105_bad_vote_accumulation():
    """Majority-vote counting is a robust-stat reduction too: summing raw
    sign bits over the member axis without a visible f32 up-cast trips the
    same rule as batch means."""
    fs = lint("rv105_bad_vote.py")
    assert ids_lines(fs) == [("RV105", 7), ("RV105", 11)]
    assert all("axis=0" in f.message for f in fs)


def test_rv105_good_vote_clean():
    assert lint("rv105_good_vote.py") == []


def test_rv106_bad_carry_outside_train_state():
    fs = lint("rv106_bad.py")
    assert [f.rule for f in fs] == ["RV106"] * 2
    assert "staleness_buffer" in fs[0].message
    assert "not a plain name" in fs[1].message


def test_rv106_good_clean():
    assert lint("rv106_good.py") == []


def test_rv107_bad_float_ages():
    fs = lint("rv107_bad.py")
    assert [f.rule for f in fs] == ["RV107"] * 2
    assert [f.line for f in fs] == [17, 21]
    assert all("integer" in f.message for f in fs)


def test_rv107_good_clean():
    assert lint("rv107_good.py") == []


def test_rv107_flags_buffer_not_train_state_resident():
    """The second leg: constructing a StalenessBuffer while TrainState has
    no stale_buffer field is the lost-carry bug class for the async path."""
    from repro.verify.ast_rules import rv107
    from repro.verify.rules import SourceContext
    with open(fx("rv107_good.py")) as f:
        ctx = SourceContext(fx("rv107_good.py"), f.read())
    fs = rv107(ctx, fields=("params", "opt_state", "attack_state"))
    assert any("stale_buffer" in f.message and f.rule == "RV107"
               for f in fs)
    # with the real TrainState (which has the field) the same file is clean
    assert rv107(ctx) == []


# --------------------------------------------------------------------------
# escape hatch: suppression drops the finding, but only WITH justification

def test_ignore_justified_is_silent():
    assert lint("ignore_justified.py") == []


def test_ignore_without_justification_raises_rv100():
    fs = lint("ignore_unjustified.py")
    assert ids_lines(fs) == [("RV100", 5)]
    assert "justification" in fs[0].message


def test_ignore_unknown_rule_id_raises_rv100_and_keeps_finding():
    fs = lint("ignore_unknown.py")
    assert sorted(f.rule for f in fs) == ["RV100", "RV102"]


def test_every_rule_documented_in_catalog():
    from repro.verify.rules import RULES
    for rid in ("RV100", "RV101", "RV102", "RV103", "RV104", "RV105",
                "RV106", "RV107", "RV201", "RV202", "RV203", "RV204",
                "RV301", "RV302", "RV303"):
        assert rid in RULES
        assert RULES[rid].motivation


def test_train_state_fields_parse():
    from repro.verify.ast_rules import train_state_fields
    fields = train_state_fields()
    assert "params" in fields and "opt_state" in fields
    assert "attack_state" in fields and "base_key" in fields
    assert "stale_buffer" in fields


# --------------------------------------------------------------------------
# CLI exit codes

def _run_cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "repro.verify", *args],
        capture_output=True, text=True, timeout=300,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))


def test_cli_strict_fails_on_bad_fixture():
    res = _run_cli("--layer", "a", "--strict", "--paths",
                   fx("rv102_bad.py"))
    assert res.returncode == 1, (res.stdout, res.stderr)
    assert "RV102" in res.stdout


def test_cli_nonstrict_reports_but_passes():
    res = _run_cli("--layer", "a", "--paths", fx("rv102_bad.py"))
    assert res.returncode == 0, (res.stdout, res.stderr)
    assert "RV102" in res.stdout


def test_cli_strict_clean_on_good_fixture():
    res = _run_cli("--layer", "a", "--strict", "--paths",
                   fx("rv102_good.py"))
    assert res.returncode == 0, (res.stdout, res.stderr)


def test_cli_strict_clean_on_src_tree():
    """Satellite 6's acceptance: zero Layer-A findings on the real tree."""
    res = _run_cli("--layer", "a", "--strict")
    assert res.returncode == 0, (res.stdout, res.stderr)


def test_ci_wires_verifier_into_both_lanes():
    yaml = pytest.importorskip("yaml")
    with open(os.path.join(REPO, ".github", "workflows", "ci.yml")) as f:
        wf = yaml.safe_load(f)
    import json
    tier1 = json.dumps(wf["jobs"]["tier1"])
    slow = json.dumps(wf["jobs"]["slow"])
    assert "repro.verify --strict" in tier1
    assert "repro.verify --strict --full-matrix" in slow
    # Layer C rides both lanes: native-codec cells in tier-1, the full
    # aggregator × codec matrix nightly — and tier-1 publishes SARIF
    assert "--taint" in tier1 and "--taint" in slow
    assert "--format sarif" in tier1
    assert "upload-sarif" in tier1


def test_cli_list_rules():
    res = _run_cli("--list-rules")
    assert res.returncode == 0
    for rid in ("RV101", "RV204"):
        assert rid in res.stdout


# --------------------------------------------------------------------------
# Layer B / RV204: VMEM budget drift gate (in-process — no mesh needed)

def test_vmem_audit_clean():
    from repro.verify.vmem import check_vmem_budget
    assert check_vmem_budget() == []


def test_vmem_audit_catches_budget_over_device(monkeypatch):
    from repro.kernels.geomed import round as round_mod
    from repro.verify.vmem import check_vmem_budget
    monkeypatch.setattr(round_mod, "DEVICE_VMEM_BYTES",
                        round_mod.VMEM_BUDGET_BYTES // 2)
    fs = check_vmem_budget()
    assert any(f.rule == "RV204" and "DEVICE_VMEM_BYTES" in f.message
               for f in fs)


def test_vmem_audit_catches_formula_drift(monkeypatch):
    from repro.kernels.geomed import round as round_mod
    from repro.verify.vmem import check_vmem_budget
    # dispatcher suddenly over-promises: everything "fits"
    monkeypatch.setattr(round_mod, "fits_vmem",
                        lambda m, k, d, tile_d=round_mod.TILE_D: True)
    fs = check_vmem_budget()
    assert any(f.rule == "RV204" and "drifted" in f.message for f in fs)


# --------------------------------------------------------------------------
# Layer B: contract analyzer on the 8-device debug mesh (subprocess — the
# virtual-device flag must be set before jax initializes).  gmom
# (norm_based) vs coord_median (coordinate_wise) covers both contract
# shapes; the mis-declared dummy proves the analyzer actually rejects.

LAYER_B_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.core import aggregators
    from repro.verify import contracts
    from repro.verify.collectives import jaxpr_collectives

    # coordinate_wise: zero collectives in jaxpr AND compiled HLO
    fs = contracts.check_aggregator("coord_median", num_shards=4)
    assert fs == [], [f.format() for f in fs]

    # norm_based: collectives present but d-independent
    fn1, args1 = contracts._sharded_fn("gmom", 4, 1, seed=0)
    uses = jaxpr_collectives(jax.make_jaxpr(fn1)(*args1))
    assert uses, "gmom should need cross-shard partial reductions"
    fs = contracts.check_aggregator("gmom", num_shards=4)
    assert fs == [], [f.format() for f in fs]

    # deliberately mis-declared: claims coordinate_wise, psums anyway
    @aggregators.register("_test_misdeclared",
                          "claims coordinate_wise but psums over the mesh",
                          shard_contract="coordinate_wise")
    def _misdeclared(stacked, **_kw):
        def leaf(g):
            s = jax.lax.psum(jnp.sum(g.astype(jnp.float32), axis=0),
                             "model")
            return (s / g.shape[0]).astype(g.dtype)
        return jax.tree.map(leaf, stacked)

    try:
        fs = contracts.check_aggregator("_test_misdeclared", num_shards=4)
        assert any(f.rule == "RV201" for f in fs), \\
            [f.format() for f in fs]
        jaxpr_hit = any("jaxpr" in f.message for f in fs
                        if f.rule == "RV201")
        hlo_hit = any("HLO" in f.message for f in fs if f.rule == "RV201")
        assert jaxpr_hit and hlo_hit, [f.format() for f in fs]
    finally:
        aggregators._REGISTRY.pop("_test_misdeclared", None)
    print("OK")
""")


def test_layer_b_contracts_and_misdeclared_rejection():
    res = subprocess.run(
        [sys.executable, "-c", LAYER_B_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, (res.stdout[-800:], res.stderr[-4000:])
    assert "OK" in res.stdout


# --------------------------------------------------------------------------
# Layer B × compression: aggregators with a native wire codec are traced
# through their COMPRESSED production path (harness_cfg switches the codec
# on), so the contract claims cover the encode + consume pipeline — and a
# mis-declared compressed rule is rejected just like a float one.

LAYER_B_COMPRESSED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.core import aggregators, compression
    from repro.verify import contracts

    # sign_sgd_majority: packing + vote must keep the coordinate_wise
    # promise — ZERO cross-shard collectives on the compressed path
    fs = contracts.check_aggregator("sign_sgd_majority", num_shards=4)
    assert fs == [], [f.format() for f in fs]

    # int8_gmom: the per-worker scale combine plus the gmom reductions
    # must stay d-independent (norm_based)
    fs = contracts.check_aggregator("int8_gmom", num_shards=4)
    assert fs == [], [f.format() for f in fs]

    # deliberately mis-declared compressed rule: consumes the sign wire
    # natively and claims coordinate_wise, but psums the vote outcome
    # over the mesh — must be rejected in BOTH views (jaxpr and HLO)
    @aggregators.register("_test_misdeclared_wire",
                          "claims coordinate_wise on the sign wire but "
                          "psums vote counts over the mesh",
                          shard_contract="coordinate_wise",
                          native_codec="sign")
    def _misdeclared_wire(payload, *, like=None, **_kw):
        out = compression.majority_vote_packed(payload, like)
        def leaf(g):
            s = jax.lax.psum(jnp.sum(g.astype(jnp.float32)), "model")
            return (g.astype(jnp.float32) + s).astype(g.dtype)
        return jax.tree.map(leaf, out)

    try:
        fs = contracts.check_aggregator("_test_misdeclared_wire",
                                        num_shards=4)
        assert any(f.rule == "RV201" for f in fs), \\
            [f.format() for f in fs]
        jaxpr_hit = any("jaxpr" in f.message for f in fs
                        if f.rule == "RV201")
        hlo_hit = any("HLO" in f.message for f in fs if f.rule == "RV201")
        assert jaxpr_hit and hlo_hit, [f.format() for f in fs]
    finally:
        aggregators._REGISTRY.pop("_test_misdeclared_wire", None)
    print("OK")
""")


def test_layer_b_compressed_contracts_and_misdeclared_wire():
    res = subprocess.run(
        [sys.executable, "-c", LAYER_B_COMPRESSED_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, (res.stdout[-800:], res.stderr[-4000:])
    assert "OK" in res.stdout

"""Attack zoo invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import byzantine


def _stacked(m=8, d=4):
    return {"w": jnp.ones((m, d)),
            "b": {"x": jnp.full((m, 2), 2.0)}}


@pytest.mark.parametrize("attack", byzantine.available())
def test_honest_rows_untouched(attack):
    """Attacks may only modify rows where the mask is True (the paper's
    constraint: Byzantine machines lie in their reports; honest machines'
    reports arrive intact)."""
    m = 8
    s = _stacked(m)
    mask = jnp.array([True, False] * 4)
    out = byzantine.get_attack(attack)(s, mask, jax.random.PRNGKey(0))
    for leaf_out, leaf_in in zip(jax.tree.leaves(out), jax.tree.leaves(s)):
        honest = np.asarray(leaf_out)[~np.asarray(mask)]
        expected = np.asarray(leaf_in)[~np.asarray(mask)]
        np.testing.assert_array_equal(honest, expected)


@pytest.mark.parametrize("attack", byzantine.available())
def test_shapes_and_dtypes_preserved(attack):
    s = _stacked()
    mask = jnp.array([True] * 2 + [False] * 6)
    out = byzantine.get_attack(attack)(s, mask, jax.random.PRNGKey(1))
    assert jax.tree.structure(out) == jax.tree.structure(s)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(s)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_none_attack_identity():
    s = _stacked()
    out = byzantine.none_attack(s, jnp.ones((8,), bool), jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(s)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_mask_exactly_q():
    for q in [0, 1, 3, 8]:
        mask = byzantine.sample_byzantine_mask(
            jax.random.PRNGKey(0), 8, q, rotate=True, round_index=5)
        assert int(jnp.sum(mask)) == q


def test_mask_rotates_across_rounds():
    masks = [np.asarray(byzantine.sample_byzantine_mask(
        jax.random.PRNGKey(0), 16, 4, rotate=True, round_index=r))
        for r in range(8)]
    assert any(not np.array_equal(masks[0], m) for m in masks[1:])


def test_mask_fixed_mode():
    mask = byzantine.sample_byzantine_mask(
        jax.random.PRNGKey(0), 8, 3, rotate=False)
    np.testing.assert_array_equal(
        np.asarray(mask), [True] * 3 + [False] * 5)


def test_sign_flip_flips():
    s = {"w": jnp.ones((4, 3))}
    mask = jnp.array([True, False, False, False])
    out = byzantine.sign_flip_attack(s, mask, jax.random.PRNGKey(0),
                                     scale=10.0)
    np.testing.assert_allclose(np.asarray(out["w"][0]), -10.0)


def test_mean_shift_skews_average():
    m = 8
    s = {"w": jnp.ones((m, 3))}
    mask = jnp.arange(m) < 2
    out = byzantine.mean_shift_attack(s, mask, jax.random.PRNGKey(0),
                                      scale=100.0)
    mean = jnp.mean(out["w"], axis=0)
    assert float(jnp.min(mean)) > 50.0   # mean moved by ~scale


def test_omniscient_attacks_jit():
    s = _stacked()
    mask = jnp.array([True] * 2 + [False] * 6)
    for name in ["inner_product", "colluding_mimic", "anti_aggregation"]:
        fn = byzantine.get_attack(name)
        out = jax.jit(lambda s_, m_, k_: fn(s_, m_, k_))(
            s, mask, jax.random.PRNGKey(2))
        assert bool(jnp.all(jnp.isfinite(out["w"])))

"""Every registered aggregator × every registered attack.

Two layers of guarantees, both from the paper:
(a) mechanics — aggregating corrupted reports preserves the parameter
    pytree's structure, shapes, and dtypes for EVERY (aggregator, attack);
(b) tolerance — with q <= (m-1)/2 faults (and 2(1+eps)q <= k batches for
    GMoM), every *robust* aggregator keeps the aggregate within bounded
    distance of the honest mean, while plain ``mean`` (Algorithm 1) is
    dragged arbitrarily far by a single attack (§1.3).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RobustConfig, aggregate, aggregators, byzantine

M = 12           # workers
Q = 2            # byzantine: q <= (m-1)/2 and 2(1+eps)q = 4.4 <= k = 6
K = 6            # batches
LOC = 1.0        # honest gradients ~ N(LOC, 0.05) per coordinate

# Aggregators with a bounded-deviation guarantee at q <= (m-1)/2.  The
# selection rules (paper §6) and norm clipping are *not* in this set: the
# omniscient adversary defeats random_select (it sees the server's bits),
# small-norm attacks slip through norm_select/norm_clip_mean by design.
ROBUST = ("gmom", "gmom_per_leaf", "geomed", "coordinate_median",
          "trimmed_mean", "krum")

# KNOWN-UNSOUND defenses, deliberately excluded from ROBUST and loudly
# documented (their docstrings carry the warning; test below enforces it):
# norm_select / norm_clip_mean pass the shape/dtype mechanics but are NOT
# bounded under the small-norm attacks (alie, norm_stealth, inner_product).
# The full fix — the paper §6 discussion's combined selection rules against
# adaptive attacks — is the "Defense gap found by the matrix tests" ROADMAP
# item, not this PR.
KNOWN_UNSOUND = ("norm_select", "norm_clip_mean")
SMALL_NORM_ATTACKS = ("alie", "norm_stealth")


def _stacked(m=M, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray((rng.normal(size=(m, 5)) * 0.05 + LOC), jnp.float32),
        "b": {"x": jnp.asarray((rng.normal(size=(m, 2, 3)) * 0.05 + LOC),
                               jnp.float32)},
    }


def _dist_from_honest_mean(out, honest_mean):
    return float(jnp.sqrt(sum(
        jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(honest_mean)))))


def _cfg(aggregator, attack):
    # few Weiszfeld iterations: the matrix is 11 aggregators × 10 attacks of
    # eager evaluation, and a dozen iterations converge at this scale.
    return RobustConfig(num_workers=M, num_byzantine=Q, num_batches=K,
                        aggregator=aggregator, attack=attack,
                        gmom_max_iters=20, gmom_tol=1e-6)


@pytest.mark.parametrize("attack", byzantine.available())
@pytest.mark.parametrize("aggregator", aggregators.available())
def test_shapes_dtypes_preserved(aggregator, attack):
    s = _stacked()
    cfg = _cfg(aggregator, attack)
    out = aggregate(s, cfg, key=jax.random.PRNGKey(0), round_index=0)
    assert jax.tree.structure(out) == jax.tree.structure(s)
    for o, i in zip(jax.tree.leaves(out), jax.tree.leaves(s)):
        assert o.shape == i.shape[1:], (aggregator, attack)
        assert o.dtype == i.dtype, (aggregator, attack)
        assert bool(jnp.all(jnp.isfinite(o))), (aggregator, attack)


@pytest.mark.parametrize("attack", byzantine.available())
@pytest.mark.parametrize("aggregator", ROBUST)
def test_robust_aggregators_stay_bounded(aggregator, attack):
    """Paper tolerance claim: bounded deviation from the honest mean under
    every attack at q <= (m-1)/2."""
    s = _stacked()
    honest_mean = aggregators.mean_aggregator(s)
    cfg = _cfg(aggregator, attack)
    out = aggregate(s, cfg, key=jax.random.PRNGKey(1), round_index=0)
    dist = _dist_from_honest_mean(out, honest_mean)
    assert dist < 0.75, f"{aggregator} under {attack}: dist={dist}"


@pytest.mark.parametrize("attack", ["sign_flip", "mean_shift",
                                    "random_noise"])
def test_mean_breaks(attack):
    """Algorithm 1 has breakdown point 0: one adversarial round moves the
    mean arbitrarily."""
    s = _stacked()
    honest_mean = aggregators.mean_aggregator(s)
    cfg = _cfg("mean", attack)
    out = aggregate(s, cfg, key=jax.random.PRNGKey(2), round_index=0)
    dist = _dist_from_honest_mean(out, honest_mean)
    assert dist > 5.0, f"mean unexpectedly robust under {attack}: {dist}"


@pytest.mark.parametrize("aggregator", KNOWN_UNSOUND)
def test_known_unsound_defenses_carry_the_warning(aggregator):
    """The defense matrix documents these as bounded-LOOKING but unsound:
    the gap must be visible in the docstring and registry description, not
    silent."""
    agg = aggregators.get_aggregator(aggregator)
    assert "known-unsound" in (agg.fn.__doc__ or "").lower(), aggregator
    assert "KNOWN-UNSOUND" in agg.description, aggregator


@pytest.mark.skip(reason=(
    "KNOWN DEFENSE GAP, deliberately visible: norm_select/norm_clip_mean "
    "are NOT in the bounded set under small-norm attacks (alie, "
    "norm_stealth) — the adversary's crafted rows rank below/clip inside "
    "the honest envelope and survive into the average.  Unskip when the "
    "paper §6 combined selection rules land (ROADMAP: 'Defense gap found "
    "by the matrix tests')."))
@pytest.mark.parametrize("attack", SMALL_NORM_ATTACKS)
@pytest.mark.parametrize("aggregator", KNOWN_UNSOUND)
def test_selection_rules_bounded_under_small_norm_attacks(aggregator,
                                                          attack):
    s = _stacked()
    honest_mean = aggregators.mean_aggregator(s)
    out = aggregate(s, _cfg(aggregator, attack), key=jax.random.PRNGKey(1),
                    round_index=0)
    assert _dist_from_honest_mean(out, honest_mean) < 0.75


def test_norm_stealth_evades_trimming_but_not_gmom():
    """The adaptive attack hides under the Remark-2 trim threshold (all trim
    weights stay 1) yet GMoM still tolerates it via the median."""
    from repro.core.geometric_median import batch_mean_norms, trim_weights
    s = _stacked()
    mask = jnp.arange(M) < Q
    reported = byzantine.get_attack("norm_stealth")(
        s, mask, jax.random.PRNGKey(3))
    means = aggregators.batch_means(reported, K)
    w = trim_weights(batch_mean_norms(means), multiplier=3.0)
    np.testing.assert_array_equal(np.asarray(w), np.ones(K))  # no trim fires
    out = aggregators.gmom_aggregator(reported, num_batches=K,
                                      num_byzantine=Q)
    dist = _dist_from_honest_mean(out, aggregators.mean_aggregator(s))
    assert dist < 0.75


def test_alie_shifts_mean_by_z_std():
    """ALIE's report sits mean - z·std per coordinate: small enough to pass
    outlier filters, biased enough to hurt the mean."""
    s = _stacked()
    mask = jnp.arange(M) < Q
    reported = byzantine.get_attack("alie")(s, mask, jax.random.PRNGKey(4))
    # crafted rows all equal, and within ~2 std of the honest mean
    crafted = np.asarray(reported["w"])[:Q]
    np.testing.assert_allclose(crafted[0], crafted[1], atol=1e-6)
    honest = np.asarray(s["w"])[Q:]
    z_dist = np.abs(crafted[0] - honest.mean(0)) / (honest.std(0) + 1e-9)
    assert float(z_dist.max()) < 4.0

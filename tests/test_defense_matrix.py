"""Every registered aggregator × every registered attack.

Two layers of guarantees, both from the paper:
(a) mechanics — aggregating corrupted reports preserves the parameter
    pytree's structure, shapes, and dtypes for EVERY (aggregator, attack);
(b) tolerance — with q <= (m-1)/2 faults (and 2(1+eps)q <= k batches for
    GMoM), every *robust* aggregator keeps the aggregate within bounded
    distance of the honest mean, while plain ``mean`` (Algorithm 1) is
    dragged arbitrarily far by a single attack (§1.3).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RobustConfig, aggregate, aggregators, byzantine

M = 12           # workers
Q = 2            # byzantine: q <= (m-1)/2 and 2(1+eps)q = 4.4 <= k = 6
K = 6            # batches
LOC = 1.0        # honest gradients ~ N(LOC, 0.05) per coordinate

# Aggregators with a bounded-deviation guarantee at q <= (m-1)/2.  The
# naive selection rules (paper §6) and norm clipping are *not* in this set:
# the omniscient adversary defeats random_select (it sees the server's
# bits), small-norm attacks slip through norm_select/norm_clip_mean by
# design.  The SOUND combined selection rules below ARE members: they close
# the defense gap the matrix found.
SOUND_COMBINED = ("coord_median", "coord_trimmed_mean", "norm_filter_gmom")
ROBUST = ("gmom", "gmom_per_leaf", "geomed", "coordinate_median",
          "trimmed_mean", "krum") + SOUND_COMBINED

# KNOWN-UNSOUND defenses, PERMANENTLY excluded from ROBUST and loudly
# documented (their docstrings carry the warning; tests below enforce both):
# norm_select / norm_clip_mean pass the shape/dtype mechanics but are NOT
# bounded under the small-norm attacks (alie, norm_stealth, inner_product).
# The fix is NOT to patch them — it is the sound combined rules
# (SOUND_COMBINED above), which the previously-skipped gap test now gates.
# These two stay registered as the paper-§6 baselines whose failure the
# selection_rules benchmark demonstrates; they must never silently rejoin
# ROBUST (test_legacy_selection_rules_stay_unsound pins it).
KNOWN_UNSOUND = ("norm_select", "norm_clip_mean")
SMALL_NORM_ATTACKS = ("alie", "norm_stealth", "inner_product")


def _stacked(m=M, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray((rng.normal(size=(m, 5)) * 0.05 + LOC), jnp.float32),
        "b": {"x": jnp.asarray((rng.normal(size=(m, 2, 3)) * 0.05 + LOC),
                               jnp.float32)},
    }


def _dist_from_honest_mean(out, honest_mean):
    return float(jnp.sqrt(sum(
        jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(honest_mean)))))


def _cfg(aggregator, attack):
    # few Weiszfeld iterations: the matrix is 13 aggregators × 11 attacks of
    # eager evaluation, and a dozen iterations converge at this scale.
    return RobustConfig(num_workers=M, num_byzantine=Q, num_batches=K,
                        aggregator=aggregator, attack=attack,
                        gmom_max_iters=20, gmom_tol=1e-6)


@pytest.mark.parametrize("attack", byzantine.available())
@pytest.mark.parametrize("aggregator", aggregators.available())
def test_shapes_dtypes_preserved(aggregator, attack):
    s = _stacked()
    cfg = _cfg(aggregator, attack)
    out = aggregate(s, cfg, key=jax.random.PRNGKey(0), round_index=0)
    assert jax.tree.structure(out) == jax.tree.structure(s)
    for o, i in zip(jax.tree.leaves(out), jax.tree.leaves(s)):
        assert o.shape == i.shape[1:], (aggregator, attack)
        assert o.dtype == i.dtype, (aggregator, attack)
        assert bool(jnp.all(jnp.isfinite(o))), (aggregator, attack)


@pytest.mark.parametrize("attack", byzantine.available())
@pytest.mark.parametrize("aggregator", ROBUST)
def test_robust_aggregators_stay_bounded(aggregator, attack):
    """Paper tolerance claim: bounded deviation from the honest mean under
    every attack at q <= (m-1)/2."""
    s = _stacked()
    honest_mean = aggregators.mean_aggregator(s)
    cfg = _cfg(aggregator, attack)
    out = aggregate(s, cfg, key=jax.random.PRNGKey(1), round_index=0)
    dist = _dist_from_honest_mean(out, honest_mean)
    assert dist < 0.75, f"{aggregator} under {attack}: dist={dist}"


@pytest.mark.parametrize("attack", ["sign_flip", "mean_shift",
                                    "random_noise"])
def test_mean_breaks(attack):
    """Algorithm 1 has breakdown point 0: one adversarial round moves the
    mean arbitrarily."""
    s = _stacked()
    honest_mean = aggregators.mean_aggregator(s)
    cfg = _cfg("mean", attack)
    out = aggregate(s, cfg, key=jax.random.PRNGKey(2), round_index=0)
    dist = _dist_from_honest_mean(out, honest_mean)
    assert dist > 5.0, f"mean unexpectedly robust under {attack}: {dist}"


@pytest.mark.parametrize("aggregator", KNOWN_UNSOUND)
def test_known_unsound_defenses_carry_the_warning(aggregator):
    """The defense matrix documents these as bounded-LOOKING but unsound:
    the gap must be visible in the docstring and registry description, not
    silent."""
    agg = aggregators.get_aggregator(aggregator)
    assert "known-unsound" in (agg.fn.__doc__ or "").lower(), aggregator
    assert "KNOWN-UNSOUND" in agg.description, aggregator


# Formerly @pytest.mark.skip("KNOWN DEFENSE GAP..."): the naive §6 rules
# (norm_select / norm_clip_mean) are not bounded under the small-norm
# attacks, and for three PRs this test existed only as a skipped marker of
# that gap.  The sound combined selection rules (coord_median,
# coord_trimmed_mean, norm_filter_gmom — see their section in
# core/aggregators.py) close it: the test now runs UNSKIPPED against them,
# asserting the same bounded envelope the matrix asserts for gmom, across
# both fault schedules.  The legacy rules stay excluded — see
# test_legacy_selection_rules_stay_unsound below.
@pytest.mark.parametrize("schedule", ["static", "rotating"])
@pytest.mark.parametrize("attack", SMALL_NORM_ATTACKS)
@pytest.mark.parametrize("aggregator", SOUND_COMBINED)
def test_selection_rules_bounded_under_small_norm_attacks(aggregator,
                                                          attack, schedule):
    s = _stacked()
    honest_mean = aggregators.mean_aggregator(s)
    cfg = dataclasses.replace(_cfg(aggregator, attack),
                              rotate_byzantine=(schedule == "rotating"))
    for round_index in range(3):   # rotating moves the byzantine set
        out = aggregate(s, cfg, key=jax.random.PRNGKey(1),
                        round_index=round_index)
        dist = _dist_from_honest_mean(out, honest_mean)
        assert dist < 0.75, (f"{aggregator} under {attack}/{schedule} "
                             f"round {round_index}: dist={dist}")


@pytest.mark.parametrize("aggregator", KNOWN_UNSOUND)
def test_legacy_selection_rules_stay_unsound(aggregator):
    """The gap stays documented, not silently forgotten: the naive §6 rules
    remain OUT of ROBUST, and the small-norm attack suite still defeats
    them (max deviation over the suite escapes the bounded envelope).  If
    this test ever fails because the deviation shrank, someone changed the
    legacy rules — the sound combined rules are the supported fix; these
    two are kept as the paper-§6 baselines whose failure is the point."""
    assert aggregator not in ROBUST
    s = _stacked()
    honest_mean = aggregators.mean_aggregator(s)
    worst = max(
        _dist_from_honest_mean(
            aggregate(s, _cfg(aggregator, attack), key=jax.random.PRNGKey(1),
                      round_index=0), honest_mean)
        for attack in SMALL_NORM_ATTACKS)
    assert worst > 0.75, (
        f"{aggregator} survived the whole small-norm suite (worst={worst}) "
        "— if it became sound, move it into ROBUST deliberately")


def test_norm_stealth_evades_trimming_but_not_gmom():
    """The adaptive attack hides under the Remark-2 trim threshold (all trim
    weights stay 1) yet GMoM still tolerates it via the median."""
    from repro.core.geometric_median import batch_mean_norms, trim_weights
    s = _stacked()
    mask = jnp.arange(M) < Q
    reported = byzantine.get_attack("norm_stealth")(
        s, mask, jax.random.PRNGKey(3))
    means = aggregators.batch_means(reported, K)
    w = trim_weights(batch_mean_norms(means), multiplier=3.0)
    np.testing.assert_array_equal(np.asarray(w), np.ones(K))  # no trim fires
    out = aggregators.gmom_aggregator(reported, num_batches=K,
                                      num_byzantine=Q)
    dist = _dist_from_honest_mean(out, aggregators.mean_aggregator(s))
    assert dist < 0.75


def test_alie_shifts_mean_by_z_std():
    """ALIE's report sits mean - z·std per coordinate: small enough to pass
    outlier filters, biased enough to hurt the mean."""
    s = _stacked()
    mask = jnp.arange(M) < Q
    reported = byzantine.get_attack("alie")(s, mask, jax.random.PRNGKey(4))
    # crafted rows all equal, and within ~2 std of the honest mean
    crafted = np.asarray(reported["w"])[:Q]
    np.testing.assert_allclose(crafted[0], crafted[1], atol=1e-6)
    honest = np.asarray(s["w"])[Q:]
    z_dist = np.abs(crafted[0] - honest.mean(0)) / (honest.std(0) + 1e-9)
    assert float(z_dist.max()) < 4.0

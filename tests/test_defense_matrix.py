"""Every registered aggregator × every registered attack.

Two layers of guarantees, both from the paper:
(a) mechanics — aggregating corrupted reports preserves the parameter
    pytree's structure, shapes, and dtypes for EVERY (aggregator, attack);
(b) tolerance — with q <= (m-1)/2 faults (and 2(1+eps)q <= k batches for
    GMoM), every *robust* aggregator keeps the aggregate within bounded
    distance of the honest mean, while plain ``mean`` (Algorithm 1) is
    dragged arbitrarily far by a single attack (§1.3).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RobustConfig, aggregate, aggregators, byzantine

M = 12           # workers
Q = 2            # byzantine: q <= (m-1)/2 and 2(1+eps)q = 4.4 <= k = 6
K = 6            # batches
LOC = 1.0        # honest gradients ~ N(LOC, 0.05) per coordinate

# Aggregators with a bounded-deviation guarantee at q <= (m-1)/2.  The
# naive selection rules (paper §6) and norm clipping are *not* in this set:
# the omniscient adversary defeats random_select (it sees the server's
# bits), small-norm attacks slip through norm_select/norm_clip_mean by
# design.  The SOUND combined selection rules below ARE members: they close
# the defense gap the matrix found.
SOUND_COMBINED = ("coord_median", "coord_trimmed_mean", "norm_filter_gmom")
# int8_gmom is the gmom pipeline behind a dequantize step; uncompressed (as
# the matrix runs it) the two are the same estimator, so it inherits the
# bounded-deviation guarantee.  sign_sgd_majority is deliberately NOT here:
# its output is a ±1 sign vector, not a mean estimate, so the metric
# envelope below does not apply — its guarantee is vote correctness, pinned
# by the dedicated sign-vote section at the bottom of this file.
ROBUST = ("gmom", "gmom_per_leaf", "geomed", "coordinate_median",
          "trimmed_mean", "krum", "int8_gmom") + SOUND_COMBINED

# KNOWN-UNSOUND defenses, PERMANENTLY excluded from ROBUST and loudly
# documented (their docstrings carry the warning; tests below enforce both):
# norm_select / norm_clip_mean pass the shape/dtype mechanics but are NOT
# bounded under the small-norm attacks (alie, norm_stealth, inner_product).
# The fix is NOT to patch them — it is the sound combined rules
# (SOUND_COMBINED above), which the previously-skipped gap test now gates.
# These two stay registered as the paper-§6 baselines whose failure the
# selection_rules benchmark demonstrates; they must never silently rejoin
# ROBUST (test_legacy_selection_rules_stay_unsound pins it).
KNOWN_UNSOUND = ("norm_select", "norm_clip_mean")
SMALL_NORM_ATTACKS = ("alie", "norm_stealth", "inner_product")


def _stacked(m=M, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray((rng.normal(size=(m, 5)) * 0.05 + LOC), jnp.float32),
        "b": {"x": jnp.asarray((rng.normal(size=(m, 2, 3)) * 0.05 + LOC),
                               jnp.float32)},
    }


def _dist_from_honest_mean(out, honest_mean):
    return float(jnp.sqrt(sum(
        jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(honest_mean)))))


def _cfg(aggregator, attack):
    # few Weiszfeld iterations: the matrix is 13 aggregators × 11 attacks of
    # eager evaluation, and a dozen iterations converge at this scale.
    return RobustConfig(num_workers=M, num_byzantine=Q, num_batches=K,
                        aggregator=aggregator, attack=attack,
                        gmom_max_iters=20, gmom_tol=1e-6)


@pytest.mark.parametrize("attack", byzantine.available())
@pytest.mark.parametrize("aggregator", aggregators.available())
def test_shapes_dtypes_preserved(aggregator, attack):
    s = _stacked()
    cfg = _cfg(aggregator, attack)
    out = aggregate(s, cfg, key=jax.random.PRNGKey(0), round_index=0)
    assert jax.tree.structure(out) == jax.tree.structure(s)
    for o, i in zip(jax.tree.leaves(out), jax.tree.leaves(s)):
        assert o.shape == i.shape[1:], (aggregator, attack)
        assert o.dtype == i.dtype, (aggregator, attack)
        assert bool(jnp.all(jnp.isfinite(o))), (aggregator, attack)


@pytest.mark.parametrize("attack", byzantine.available())
@pytest.mark.parametrize("aggregator", ROBUST)
def test_robust_aggregators_stay_bounded(aggregator, attack):
    """Paper tolerance claim: bounded deviation from the honest mean under
    every attack at q <= (m-1)/2."""
    s = _stacked()
    honest_mean = aggregators.mean_aggregator(s)
    cfg = _cfg(aggregator, attack)
    out = aggregate(s, cfg, key=jax.random.PRNGKey(1), round_index=0)
    dist = _dist_from_honest_mean(out, honest_mean)
    assert dist < 0.75, f"{aggregator} under {attack}: dist={dist}"


@pytest.mark.parametrize("attack", ["sign_flip", "mean_shift",
                                    "random_noise"])
def test_mean_breaks(attack):
    """Algorithm 1 has breakdown point 0: one adversarial round moves the
    mean arbitrarily."""
    s = _stacked()
    honest_mean = aggregators.mean_aggregator(s)
    cfg = _cfg("mean", attack)
    out = aggregate(s, cfg, key=jax.random.PRNGKey(2), round_index=0)
    dist = _dist_from_honest_mean(out, honest_mean)
    assert dist > 5.0, f"mean unexpectedly robust under {attack}: {dist}"


@pytest.mark.parametrize("aggregator", KNOWN_UNSOUND)
def test_known_unsound_defenses_carry_the_warning(aggregator):
    """The defense matrix documents these as bounded-LOOKING but unsound:
    the gap must be visible in the docstring and registry description, not
    silent."""
    agg = aggregators.get_aggregator(aggregator)
    assert "known-unsound" in (agg.fn.__doc__ or "").lower(), aggregator
    assert "KNOWN-UNSOUND" in agg.description, aggregator


# Formerly @pytest.mark.skip("KNOWN DEFENSE GAP..."): the naive §6 rules
# (norm_select / norm_clip_mean) are not bounded under the small-norm
# attacks, and for three PRs this test existed only as a skipped marker of
# that gap.  The sound combined selection rules (coord_median,
# coord_trimmed_mean, norm_filter_gmom — see their section in
# core/aggregators.py) close it: the test now runs UNSKIPPED against them,
# asserting the same bounded envelope the matrix asserts for gmom, across
# both fault schedules.  The legacy rules stay excluded — see
# test_legacy_selection_rules_stay_unsound below.
@pytest.mark.parametrize("schedule", ["static", "rotating"])
@pytest.mark.parametrize("attack", SMALL_NORM_ATTACKS)
@pytest.mark.parametrize("aggregator", SOUND_COMBINED)
def test_selection_rules_bounded_under_small_norm_attacks(aggregator,
                                                          attack, schedule):
    s = _stacked()
    honest_mean = aggregators.mean_aggregator(s)
    cfg = dataclasses.replace(_cfg(aggregator, attack),
                              rotate_byzantine=(schedule == "rotating"))
    for round_index in range(3):   # rotating moves the byzantine set
        out = aggregate(s, cfg, key=jax.random.PRNGKey(1),
                        round_index=round_index)
        dist = _dist_from_honest_mean(out, honest_mean)
        assert dist < 0.75, (f"{aggregator} under {attack}/{schedule} "
                             f"round {round_index}: dist={dist}")


@pytest.mark.parametrize("aggregator", KNOWN_UNSOUND)
def test_legacy_selection_rules_stay_unsound(aggregator):
    """The gap stays documented, not silently forgotten: the naive §6 rules
    remain OUT of ROBUST, and the small-norm attack suite still defeats
    them (max deviation over the suite escapes the bounded envelope).  If
    this test ever fails because the deviation shrank, someone changed the
    legacy rules — the sound combined rules are the supported fix; these
    two are kept as the paper-§6 baselines whose failure is the point."""
    assert aggregator not in ROBUST
    s = _stacked()
    honest_mean = aggregators.mean_aggregator(s)
    worst = max(
        _dist_from_honest_mean(
            aggregate(s, _cfg(aggregator, attack), key=jax.random.PRNGKey(1),
                      round_index=0), honest_mean)
        for attack in SMALL_NORM_ATTACKS)
    assert worst > 0.75, (
        f"{aggregator} survived the whole small-norm suite (worst={worst}) "
        "— if it became sound, move it into ROBUST deliberately")


def test_norm_stealth_evades_trimming_but_not_gmom():
    """The adaptive attack hides under the Remark-2 trim threshold (all trim
    weights stay 1) yet GMoM still tolerates it via the median."""
    from repro.core.geometric_median import batch_mean_norms, trim_weights
    s = _stacked()
    mask = jnp.arange(M) < Q
    reported = byzantine.get_attack("norm_stealth")(
        s, mask, jax.random.PRNGKey(3))
    means = aggregators.batch_means(reported, K)
    w = trim_weights(batch_mean_norms(means), multiplier=3.0)
    np.testing.assert_array_equal(np.asarray(w), np.ones(K))  # no trim fires
    out = aggregators.gmom_aggregator(reported, num_batches=K,
                                      num_byzantine=Q)
    dist = _dist_from_honest_mean(out, aggregators.mean_aggregator(s))
    assert dist < 0.75


def test_alie_shifts_mean_by_z_std():
    """ALIE's report sits mean - z·std per coordinate: small enough to pass
    outlier filters, biased enough to hurt the mean."""
    s = _stacked()
    mask = jnp.arange(M) < Q
    reported = byzantine.get_attack("alie")(s, mask, jax.random.PRNGKey(4))
    # crafted rows all equal, and within ~2 std of the honest mean
    crafted = np.asarray(reported["w"])[:Q]
    np.testing.assert_allclose(crafted[0], crafted[1], atol=1e-6)
    honest = np.asarray(s["w"])[Q:]
    z_dist = np.abs(crafted[0] - honest.mean(0)) / (honest.std(0) + 1e-9)
    assert float(z_dist.max()) < 4.0


# ---------------------------------------------------------------------------
# signSGD majority vote (Jin et al. '19).  The vote outputs ±1 per
# coordinate, so "bounded deviation from the honest mean" is the wrong
# guarantee; the right one is VOTE CORRECTNESS — the output sign matches
# the honest majority sign.  With the matrix's honest data (~N(1.0, 0.05)
# per coordinate) every honest vote is +1, so a correct vote is exactly
# the all-+1 tree.

SIGN_VOTE_ATTACKS = ("sign_flip", "sign_flip_targeted", "alie",
                     "norm_stealth")


def _assert_all_plus_one(out, ctx):
    for leaf in jax.tree.leaves(out):
        assert bool(jnp.all(leaf == 1.0)), (ctx, np.asarray(leaf))


@pytest.mark.parametrize("schedule", ["static", "rotating"])
@pytest.mark.parametrize("attack", SIGN_VOTE_ATTACKS)
def test_sign_majority_vote_correct_under_attack(attack, schedule):
    """q = 2 of m = 12 against the thick-margin honest population: no
    attack in the suite — including the vote-native targeted one — can
    swing any coordinate, under either fault schedule."""
    s = _stacked()
    cfg = dataclasses.replace(_cfg("sign_sgd_majority", attack),
                              rotate_byzantine=(schedule == "rotating"))
    for round_index in range(3):
        out = aggregate(s, cfg, key=jax.random.PRNGKey(1),
                        round_index=round_index)
        _assert_all_plus_one(out, (attack, schedule, round_index))


@pytest.mark.parametrize("q", [1, 2, 3, 4, 5])
def test_sign_majority_tolerates_up_to_half_sign_flippers(q):
    """Paper-style tolerance bound for the vote: plain (blind) sign_flip
    at every q <= (m-1)/2 never flips a thick-margin coordinate — the
    byzantine workers contribute exactly q negative votes against 12 - q
    honest positives."""
    s = _stacked()
    mask = jnp.arange(M) < q
    reported = byzantine.get_attack("sign_flip")(s, mask,
                                                 jax.random.PRNGKey(5))
    _assert_all_plus_one(aggregators.sign_sgd_majority_aggregator(reported),
                         q)


def test_sign_flip_targeted_break_point_pinned():
    """PR-5 KNOWN-UNSOUND discipline: the cell where the native adversary
    defeats the vote is PINNED, not skipped.  A crafted coordinate with 9
    positive / 3 negative reports (m = 12) flips exactly when the
    adversary owns the q >= 4 thinnest votes — 2*(3 + q) > 12 — i.e. the
    break point q* = 4 sits BELOW the generic q <= (m-1)/2 = 5 tolerance:
    majority vote is only sound up to the honest margin, and this test
    fails loudly if either the attack or the vote rule moves that point.
    The thick-margin coordinate (12 positive) stays correct at every q."""
    thin = [1.0] * 9 + [-1.0] * 3
    s = {"w": jnp.asarray(np.stack([thin, [1.0] * M], axis=1), jnp.float32)}
    attack = byzantine.get_attack("sign_flip_targeted")
    for q in range(1, 6):
        mask = jnp.arange(M) < q            # masks positive-voting workers
        reported = attack(s, mask, jax.random.PRNGKey(6))
        vote = np.asarray(aggregators.sign_sgd_majority_aggregator(
            reported)["w"])
        expected_thin = -1.0 if q >= 4 else 1.0
        assert vote[0] == expected_thin, (q, vote)
        assert vote[1] == 1.0, (q, vote)


def test_sign_flip_targeted_hides_in_honest_norm_envelope():
    """What makes the targeted adversary dangerous: its reports sit at
    honest-mean magnitude (no norm filter sees them), while plain
    sign_flip's -10x reports stick far outside the envelope."""
    s = _stacked()
    mask = jnp.arange(M) < Q
    key = jax.random.PRNGKey(7)
    rep_t = byzantine.get_attack("sign_flip_targeted")(s, mask, key)
    rep_f = byzantine.get_attack("sign_flip")(s, mask, key)

    def row_norm(tree, i):
        return float(jnp.sqrt(sum(
            jnp.sum(jnp.square(leaf[i].astype(jnp.float32)))
            for leaf in jax.tree.leaves(tree))))

    honest = float(np.mean([row_norm(s, i) for i in range(Q, M)]))
    assert abs(row_norm(rep_t, 0) - honest) < 0.25 * honest
    assert row_norm(rep_f, 0) > 5.0 * honest


@pytest.mark.parametrize("attack", SIGN_VOTE_ATTACKS)
def test_sign_majority_native_wire_matches_float_vote(attack):
    """Voting on the packed 1-bit wire (compression="sign", the native
    codec path through aggregate_reported) is bit-identical to voting on
    the float reports: packing is lossless for signs."""
    s = _stacked()
    cfg = _cfg("sign_sgd_majority", attack)
    out = aggregate(s, cfg, key=jax.random.PRNGKey(1), round_index=0)
    out_c = aggregate(s, dataclasses.replace(cfg, compression="sign"),
                      key=jax.random.PRNGKey(1), round_index=0)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(out_c)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_int8_gmom_bounded_on_quantized_wire():
    """int8_gmom's actual deployment shape: reports cross the 8-bit
    stochastic wire, the rule dequantizes (per-worker scales) and runs
    gmom — still inside the bounded envelope under attack, because the
    per-coordinate quantization error is at most one scale step
    (~amax/127) and gmom medians out the byzantine rows' large scales."""
    s = _stacked()
    honest_mean = aggregators.mean_aggregator(s)
    cfg = dataclasses.replace(_cfg("int8_gmom", "sign_flip"),
                              compression="int8_stochastic")
    out = aggregate(s, cfg, key=jax.random.PRNGKey(1), round_index=0)
    dist = _dist_from_honest_mean(out, honest_mean)
    assert dist < 0.75, dist


# --------------------------------------------------------------------------
# Adversarial staleness: the async attack surface (docs/ASYNC.md).
#
# byzantine_max_stale is the timing adversary: Byzantine workers choose
# zero staleness (fresh poison at full weight every round) while honest
# workers are staggered to the bound tau, so honest mass decays as
# discount^age and the effective contamination fraction rises with tau —
# the q <= (m-1)/2 budget erodes without a single extra corrupted VALUE.
# The campaign below is the real multi-round pipeline (merge_reports ->
# age-discounted aggregate_reported), measured in steady state: the
# cold-start transient (empty buffer, most honest workers hard-dropped)
# is a one-time startup effect, not the attack.

STALE_DISCOUNT = 0.7          # RobustConfig.staleness_discount default
STALE_BOUNDED_TAU = (0, 1)    # every ROBUST aggregator holds the envelope
STALE_ROUNDS = 8              # steady-state rounds measured past warmup


def _stale_campaign_worst_dist(aggregator, tau, *, attack="sign_flip"):
    """Worst steady-state deviation from the honest mean over a
    byzantine_max_stale campaign (warmup = tau + 1 rounds excluded)."""
    from repro.core import aggregate_reported, staleness as st

    cfg = dataclasses.replace(
        _cfg(aggregator, attack), arrival="byzantine_max_stale",
        staleness_bound=tau, staleness_discount=STALE_DISCOUNT)
    arr = st.arrival_from_config(cfg)
    params = jax.tree.map(lambda l: l[0], _stacked(seed=0))
    buf = st.init_buffer(params, M, tau)
    atk = byzantine.get_attack(attack)
    warm = tau + 1
    worst = 0.0
    for t in range(warm + STALE_ROUNDS):
        key = jax.random.PRNGKey(100 + t)
        s = _stacked(seed=t)
        mask = byzantine.sample_byzantine_mask(key, M, Q, rotate=False,
                                               round_index=t)
        fresh = arr.arrive(key, t, mask)
        merged, buf = st.merge_reports(buf, atk(s, mask, key), fresh)
        out = aggregate_reported(
            merged, cfg, key=key,
            staleness=(buf.age, buf.bound, cfg.staleness_discount))
        if t >= warm:
            dist = _dist_from_honest_mean(out,
                                          aggregators.mean_aggregator(s))
            worst = max(worst, dist)
    return worst


@pytest.mark.parametrize("tau", STALE_BOUNDED_TAU)
@pytest.mark.parametrize("aggregator", ROBUST)
def test_robust_aggregators_bounded_under_byzantine_max_stale(aggregator,
                                                              tau):
    """At tau <= 1 the honest-mass erosion is mild (gamma^1 = 0.7) and
    every ROBUST aggregator keeps the same 0.75 envelope the synchronous
    matrix asserts — bounded-staleness asynchrony inside this regime does
    not cost the paper's tolerance guarantee."""
    dist = _stale_campaign_worst_dist(aggregator, tau)
    assert dist < 0.75, \
        f"{aggregator} under byzantine_max_stale tau={tau}: dist={dist}"


def test_byzantine_max_stale_break_point_pinned():
    """The KNOWN-UNSOUND discipline for the timing adversary: the tau
    where stale-poisoning wins is PINNED, not skipped.  gmom (batch means
    dilute the reweighting across k groups) holds through tau = 2 and
    breaks at tau* = 3; geomed (k = m, raw worker rows — no batch-mean
    dilution) breaks a full notch earlier, at tau = 2.  If a change moves
    these cells, re-measure and re-document the break point in
    docs/ASYNC.md — never widen the envelope to make it pass."""
    assert _stale_campaign_worst_dist("gmom", 2) < 0.75
    broken = _stale_campaign_worst_dist("gmom", 3)
    assert broken > 1.0, \
        f"gmom tau=3 unexpectedly bounded ({broken}) — the pinned break " \
        "point moved; re-measure and update docs/ASYNC.md"
    geomed_broken = _stale_campaign_worst_dist("geomed", 2)
    assert geomed_broken > 0.75, \
        f"geomed tau=2 unexpectedly bounded ({geomed_broken}) — the " \
        "pinned break point moved; re-measure and update docs/ASYNC.md"

"""Aggregator registry: semantics + robustness under every attack."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators, byzantine, RobustConfig, aggregate


def _stacked(m=8, d=5, seed=0, loc=1.0, scale=0.05):
    rng = np.random.default_rng(seed)
    g = (rng.normal(size=(m, d)) * scale + loc).astype(np.float32)
    return {"w": jnp.asarray(g)}


def test_registry_contents():
    names = aggregators.available()
    for expected in ["mean", "gmom", "geomed", "coordinate_median",
                     "trimmed_mean", "krum", "norm_clip_mean",
                     "gmom_per_leaf", "random_select", "norm_select",
                     "coord_median", "coord_trimmed_mean",
                     "norm_filter_gmom"]:
        assert expected in names
    with pytest.raises(KeyError):
        aggregators.get_aggregator("nope")


def test_gmom_k1_equals_mean():
    s = _stacked()
    gm = aggregators.gmom_aggregator(s, num_batches=1)
    mean = aggregators.mean_aggregator(s)
    np.testing.assert_allclose(np.asarray(gm["w"]), np.asarray(mean["w"]),
                               atol=1e-6)


def test_gmom_km_equals_geomed():
    s = _stacked(m=6)
    gm = aggregators.gmom_aggregator(s, num_batches=6, trim_multiplier=None,
                                     max_iters=128, tol=1e-10)
    ge = aggregators.geomed_aggregator(s, max_iters=128, tol=1e-10)
    np.testing.assert_allclose(np.asarray(gm["w"]), np.asarray(ge["w"]),
                               atol=1e-4)


def test_batch_means_structure():
    s = _stacked(m=8)
    means = aggregators.batch_means(s, 4)
    assert means["w"].shape == (4, 5)
    np.testing.assert_allclose(
        np.asarray(means["w"][0]), np.asarray(jnp.mean(s["w"][:2], axis=0)),
        atol=1e-6)


def test_mean_breaks_all_robust_survive():
    """The paper's core comparison: one Byzantine machine skews the mean
    arbitrarily (§1.3 BGD), but GMoM & friends stay near the honest value."""
    m, d = 8, 5
    s = _stacked(m, d)
    mask = jnp.arange(m) < 2
    corrupted = byzantine.sign_flip_attack(s, mask, jax.random.PRNGKey(0),
                                           scale=100.0)
    mean = aggregators.mean_aggregator(corrupted)
    assert float(jnp.linalg.norm(mean["w"] - 1.0)) > 5.0
    for name in ["gmom", "geomed", "coordinate_median", "trimmed_mean",
                 "krum"]:
        agg = aggregators.get_aggregator(name)
        out = agg(corrupted, num_byzantine=2, num_batches=8)
        err = float(jnp.linalg.norm(out["w"] - 1.0))
        assert err < 0.5, f"{name} failed: {err}"


# tier-1 covers the same claim (gmom bounded under every attack, same m/q/k)
# via tests/test_defense_matrix.py::test_robust_aggregators_stay_bounded;
# this variant exercises the RobustConfig aggregate() entry point.
@pytest.mark.slow
@pytest.mark.parametrize("attack", byzantine.available())
def test_gmom_survives_every_attack(attack):
    m = 12
    s = _stacked(m)
    cfg = RobustConfig(num_workers=m, num_byzantine=2, attack=attack,
                       aggregator="gmom", num_batches=6,
                       gmom_max_iters=20, gmom_tol=1e-6)
    out = aggregate(s, cfg, key=jax.random.PRNGKey(3), round_index=0)
    err = float(jnp.linalg.norm(out["w"] - 1.0))
    assert err < 0.5, f"gmom under {attack}: err={err}"


def test_gmom_breaks_beyond_half_batches():
    """Breakdown point: with > k/2 contaminated batches the median can be
    dragged (Lemma 1's alpha < 1/2 requirement is tight)."""
    m = 8
    s = _stacked(m)
    mask = jnp.arange(m) < 5          # 5 of 8 workers => 5 of 8 batches
    corrupted = byzantine.mean_shift_attack(s, mask, jax.random.PRNGKey(0),
                                            scale=100.0)
    out = aggregators.gmom_aggregator(corrupted, num_batches=8,
                                      trim_multiplier=None)
    assert float(jnp.linalg.norm(out["w"] - 1.0)) > 1.0


def test_trimming_defeats_huge_norm_outliers():
    m = 8
    s = _stacked(m)
    mask = jnp.arange(m) < 3
    corrupted = byzantine.random_noise_attack(s, mask, jax.random.PRNGKey(1),
                                              scale=1e6)
    out = aggregators.gmom_aggregator(corrupted, num_batches=8,
                                      trim_multiplier=3.0)
    assert float(jnp.linalg.norm(out["w"] - 1.0)) < 0.5


def test_gmom_per_leaf_close_to_global_on_honest():
    s = {"a": _stacked(8, 3, seed=1)["w"], "b": _stacked(8, 4, seed=2)["w"]}
    g1 = aggregators.gmom_aggregator(s, num_batches=4, trim_multiplier=None)
    g2 = aggregators.gmom_per_leaf_aggregator(s, num_batches=4)
    for k in s:
        assert float(jnp.linalg.norm(g1[k] - g2[k])) < 0.1


def test_krum_selects_honest_worker():
    m = 8
    s = _stacked(m)
    mask = jnp.arange(m) < 2
    corrupted = byzantine.random_noise_attack(s, mask, jax.random.PRNGKey(2),
                                              scale=100.0)
    out = aggregators.krum_aggregator(corrupted, num_byzantine=2)
    assert float(jnp.linalg.norm(out["w"] - 1.0)) < 0.5


def test_bottom_k_mask_exact_under_ties():
    """Regression: thresholding by the k-th smallest value selects MORE than
    k entries when scores tie; rank selection must pick exactly k."""
    for scores in [jnp.zeros((8,)),                       # all tied
                   jnp.array([1.0, 1.0, 1.0, 2.0, 2.0]),  # tie at threshold
                   jnp.array([3.0, 1.0, 2.0, 0.5])]:
        for k in range(1, scores.shape[0] + 1):
            sel = aggregators.bottom_k_mask(scores, k)
            assert float(jnp.sum(sel)) == k, (scores, k)
            # selected scores are all <= every unselected score
            if k < scores.shape[0]:
                assert float(jnp.max(jnp.where(sel > 0, scores, -jnp.inf))) \
                    <= float(jnp.min(jnp.where(sel > 0, jnp.inf, scores)))


def test_random_select_averages_exactly_n_sel():
    """random_select must average exactly n_sel = floor(frac·m) gradients:
    with one-hot rows the output recovers the selection mask directly."""
    m = 8
    eye = {"w": jnp.eye(m, dtype=jnp.float32)}
    for seed in range(6):
        out = aggregators.random_select_aggregator(
            eye, key=jax.random.PRNGKey(seed), subset_fraction=0.5)
        sel = np.asarray(out["w"]) * (m // 2)
        np.testing.assert_allclose(sel.sum(), m // 2, atol=1e-5)
        assert set(np.round(sel, 5)) <= {0.0, 1.0}


def test_norm_select_exact_under_colluding_ties():
    """Colluders reporting identical gradients tie in norm; norm_select must
    still keep exactly m - q gradients."""
    m = 6
    g = jnp.ones((m, 4), jnp.float32)
    g = g.at[0].set(5.0).at[1].set(5.0)   # two tied large-norm colluders
    out = aggregators.norm_select_aggregator({"w": g}, num_byzantine=2)
    np.testing.assert_allclose(np.asarray(out["w"]), np.ones(4), atol=1e-6)


def test_random_select_requires_key():
    """Regression: the old PRNGKey(0) fallback made the "random" subset
    deterministic and identical every round — a silent downgrade to a fixed
    selection rule.  A missing key must raise, not degrade."""
    with pytest.raises(ValueError, match="requires a PRNG key"):
        aggregators.random_select_aggregator(_stacked())


def test_krum_degenerate_neighbourhood_raises():
    """Krum's m - q - 2 nearest-neighbour score needs m > q + 2; below that
    the old code silently clamped to a single-neighbour score with no
    selection guarantee — it must raise loudly instead."""
    s = _stacked(m=4)
    with pytest.raises(ValueError, match="m > q \\+ 2"):
        aggregators.krum_aggregator(s, num_byzantine=2)   # m = q + 2
    # smallest valid neighbourhood (closest = 1) still works
    out = aggregators.krum_aggregator(_stacked(m=5), num_byzantine=2)
    assert out["w"].shape == (5,)


# ---------------------------------------------------------------------------
# sound combined selection rules (the §6 defense-gap fix)

def test_coord_median_matches_manual_median_of_batch_means():
    s = _stacked(m=12)
    out = aggregators.coord_median_aggregator(s, num_batches=6)
    means = aggregators.batch_means(s, 6)
    np.testing.assert_allclose(
        np.asarray(out["w"]), np.median(np.asarray(means["w"]), axis=0),
        atol=1e-6)


def test_coord_trimmed_mean_discards_extremes_per_coordinate():
    """With t = q the per-coordinate trim must remove an adversarial batch
    value regardless of sign or magnitude — the two-sidedness norm_select
    lacks."""
    m, k = 12, 6
    s = _stacked(m)
    # poison workers 0,1 (both land in batch 0 under contiguous b=2):
    # one coordinate huge, one tiny — both sides of the honest range.
    g = s["w"].at[0, 0].set(1e4).at[1, 0].set(1e4)
    g = g.at[0, 1].set(-1e4).at[1, 1].set(-1e4)
    out = aggregators.coord_trimmed_mean_aggregator(
        {"w": g}, num_batches=k, num_byzantine=1)
    assert float(jnp.max(jnp.abs(out["w"] - 1.0))) < 0.2


def test_norm_filter_gmom_drops_huge_and_tiny_outliers():
    """The envelope filter is two-sided: a huge-norm report AND a
    deliberately-tiny report are both excluded from their batch means, so
    the aggregate recovers the honest value where one-sided selection
    (norm_select keeps the tiny one) is biased."""
    m, k = 12, 6
    s = _stacked(m)
    g = s["w"].at[0].set(100.0)      # classic huge-norm outlier (batch 0)
    g = g.at[2].set(1e-4)            # adversarially-small report (batch 1)
    out = aggregators.norm_filter_gmom_aggregator(
        {"w": g}, num_batches=k, num_byzantine=2, round_backend="reference")
    # surviving members of batches 0 and 1 are honest -> near-honest output
    assert float(jnp.max(jnp.abs(out["w"] - 1.0))) < 0.1


def test_norm_filter_gmom_all_filtered_batch_falls_back():
    """A batch whose members are ALL outside the envelope falls back to its
    unfiltered mean (static shapes), and the downstream GMoM median still
    tolerates that single contaminated batch mean."""
    m, k = 12, 6
    s = _stacked(m)
    g = s["w"].at[0].set(100.0).at[1].set(100.0)   # whole batch 0 huge
    out = aggregators.norm_filter_gmom_aggregator(
        {"w": g}, num_batches=k, num_byzantine=2, round_backend="reference")
    assert bool(jnp.all(jnp.isfinite(out["w"])))
    assert float(jnp.max(jnp.abs(out["w"] - 1.0))) < 0.5


def test_coord_median_rejects_crossed_breakdown_point():
    """q >= k/2 crosses the median's breakdown point — must raise, not
    silently emit an adversary-dominated aggregate."""
    with pytest.raises(ValueError, match="2q < k"):
        aggregators.coord_median_aggregator(
            _stacked(m=8), num_batches=4, num_byzantine=2)   # 2q = k


def test_coord_trimmed_mean_rejects_uncoverable_contamination():
    """q >= k/2 is outside the Yin et al. guarantee: the old clamp silently
    returned an adversary-dominated aggregate; it must raise instead
    (mirroring krum's degenerate-neighbourhood check)."""
    s = _stacked(m=16)
    with pytest.raises(ValueError, match="2·trim_count < k"):
        aggregators.coord_trimmed_mean_aggregator(
            s, num_batches=8, num_byzantine=4)   # 2q = k
    with pytest.raises(ValueError, match="2·trim_count < k"):
        aggregators.coord_trimmed_mean_aggregator(
            s, num_batches=8, num_byzantine=1, trim_count=-1)


def test_gmom_per_leaf_honors_grouping_scheme():
    """needs_grouping threads cfg.grouping_scheme; the rule must actually
    partition with it, not silently fall back to contiguous."""
    m, k = 8, 4
    # six 0-workers then two 10-workers: contiguous pairs give batch means
    # [0, 0, 0, 10] (honest majority -> geomed near 0), strided pairs
    # (worker j with j+4) give [0, 0, 5, 5] (geomed pulled to ~2.5).
    g = jnp.asarray([0.0] * 6 + [10.0] * 2, jnp.float32)[:, None] \
        * jnp.ones((m, 3), jnp.float32)
    cont = aggregators.gmom_per_leaf_aggregator(
        {"w": g}, num_batches=k, grouping_scheme="contiguous")
    strd = aggregators.gmom_per_leaf_aggregator(
        {"w": g}, num_batches=k, grouping_scheme="strided")
    assert float(jnp.max(cont["w"])) < 1.0, np.asarray(cont["w"])
    assert float(jnp.min(strd["w"])) > 1.5, np.asarray(strd["w"])


def test_norm_filter_gmom_honest_passthrough():
    """With i.i.d. honest reports the envelope keeps (essentially) everyone
    and the rule coincides with plain gmom on the same grouping."""
    s = _stacked(m=12)
    nf = aggregators.norm_filter_gmom_aggregator(
        s, num_batches=6, round_backend="reference")
    gm = aggregators.gmom_aggregator(
        s, num_batches=6, round_backend="reference")
    np.testing.assert_allclose(np.asarray(nf["w"]), np.asarray(gm["w"]),
                               atol=1e-5)


def test_worker_batch_ids_inverts_assignment_matrix():
    from repro.core.grouping import (assignment_matrix, make_grouping,
                                     worker_batch_ids)
    for m, k, scheme in [(12, 6, "contiguous"), (12, 5, "contiguous"),
                         (8, 4, "strided"), (50, 11, "contiguous")]:
        grouping = make_grouping(m, k, scheme=scheme)
        ids = worker_batch_ids(grouping)
        s = assignment_matrix(grouping)
        for w in range(m):
            assert s[ids[w], w] == 1.0, (m, k, scheme, w)


# ---------------------------------------------------------------------------
# batch-mean dtype contract: both grouping paths accumulate in f32

def test_bf16_batch_means_match_f32_accumulation():
    """Even (k | m) and uneven (k ∤ m) batch means both accumulate in f32
    and cast once — bitwise equal to computing the means in f32 and casting
    the result.  Previously the even path meant directly in bf16 and
    diverged from the uneven path's f32 contraction."""
    rng = np.random.default_rng(3)
    g32 = jnp.asarray(rng.normal(size=(12, 7)).astype(np.float32))
    gb = {"w": g32.astype(jnp.bfloat16)}
    for k in (4, 5):                       # 12 % 4 == 0, 12 % 5 != 0
        got = aggregators.batch_means(gb, k)["w"]
        want = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16),
            aggregators.batch_means(
                {"w": gb["w"].astype(jnp.float32)}, k))["w"]
        assert got.dtype == jnp.bfloat16
        assert np.array_equal(np.asarray(got, np.float32),
                              np.asarray(want, np.float32)), k


def test_bf16_batch_means_even_uneven_consistent():
    """A worker that lands alone in a batch contributes the identical bits
    under even and uneven groupings (the shared f32-accumulate path)."""
    rng = np.random.default_rng(4)
    g = jnp.asarray(rng.normal(size=(7, 5)).astype(np.float32)).astype(
        jnp.bfloat16)
    uneven = aggregators.batch_means({"w": g}, 4)["w"]   # sizes 2,2,2,1
    # last batch is worker 6 alone: the mean of one element must be itself
    assert np.array_equal(np.asarray(uneven[3], np.float32),
                          np.asarray(g[6], np.float32))


# ---------------------------------------------------------------------------
# round-backend dispatch: target backend + partitioned gradients

def test_resolve_round_backend_targets():
    # auto on a CPU host resolves the host path...
    assert aggregators.resolve_round_backend(
        "auto", num_batches=4) == "reference"
    # ...but a TPU *target* resolves the production fused path even when
    # lowering from a CPU host (dry-run sweeps).
    assert aggregators.resolve_round_backend(
        "auto", num_batches=4, target_backend="tpu") == "fused"
    assert aggregators.resolve_round_backend(
        "auto", num_batches=4, target_backend="cpu") == "reference"


def test_resolve_round_backend_partitioned_forces_reference():
    # partitioned grads veto the fused kernel (its leaf concat = a gather),
    # even on a TPU target ...
    assert aggregators.resolve_round_backend(
        "auto", num_batches=4, target_backend="tpu",
        partitioned=True) == "reference"
    # ... silently for auto, with a warning for an explicit request
    with pytest.warns(UserWarning, match="partitioned"):
        got = aggregators.resolve_round_backend(
            "fused", num_batches=4, target_backend="tpu", partitioned=True)
    assert got == "reference"

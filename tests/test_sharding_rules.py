"""Unit tests for the sharding rules + roofline HLO parser (no devices)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.roofline import hlo_parser
from repro.roofline.analysis import collective_bytes

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SAMPLE_HLO = textwrap.dedent("""\
    HloModule test

    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8] get-tuple-element(%p), index=1
      %w = f32[8,8] constant(0)
      %d = f32[8,8] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,8] all-reduce(%d), replica_groups={}
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
    }

    %cond (p2: (s32[], f32[8,8])) -> pred[] {
      %p2 = (s32[], f32[8,8]) parameter(0)
      %i2 = s32[] get-tuple-element(%p2), index=0
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%i2, %n), direction=LT
    }

    ENTRY %main (a: f32[8,8]) -> f32[8,8] {
      %a = f32[8,8] parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,8]) tuple(%zero, %a)
      %w2 = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
      %ag = f32[16,8] all-gather(%a), dimensions={0}
      ROOT %out = f32[8,8] get-tuple-element(%w2), index=1
    }
""")


def test_parser_trip_count_correction():
    cost = hlo_parser.analyze(SAMPLE_HLO)
    # dot: 2*8*8*8 = 1024 flops, x5 iterations
    assert cost.dot_flops == 1024 * 5
    # all-reduce 8*8*4 bytes x5 + all-gather 16*8*4 once
    assert cost.collective_breakdown["all-reduce"] == 256 * 5
    assert cost.collective_breakdown["all-gather"] == 512
    assert cost.max_trip_product == 5


def test_legacy_collective_bytes():
    out = collective_bytes(SAMPLE_HLO)
    assert out["all-reduce"] == 256      # uncorrected: body counted once
    assert out["all-gather"] == 512


def test_shape_bytes():
    elems, nbytes = hlo_parser._shape_elems_bytes(
        "f32[2,3]{1,0} bf16[4] pred[]")
    assert elems == 6 + 4 + 1
    assert nbytes == 24 + 8 + 1


SHARDING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.launch import mesh as mesh_lib, sharding, steps

    mesh = mesh_lib.make_debug_mesh(data=4, model=2)
    checks = []

    # dense family: attention weights model-replicated, unembed vocab-TP,
    # MLP F-sharded (Megatron TP)
    cfg = get_config("h2o-danube-3-4b")       # full dims (divisible)
    params = steps.abstract_params(cfg)
    shard = sharding.param_shardings(params, mesh, cfg)
    lay = shard["layers"]
    assert "model" not in str(lay["attn"]["wq"].spec), lay["attn"]["wq"].spec
    assert lay["mlp"]["w_gate"].spec[2] == "model"
    assert lay["mlp"]["w_down"].spec[1] == "model"
    assert shard["unembed"].spec[-1] == "model"
    assert shard["embed"].spec[0] == "model"

    # moe: experts -> model, router replicated
    cfg = get_config("granite-moe-1b-a400m")
    params = steps.abstract_params(cfg)
    shard = sharding.param_shardings(params, mesh, cfg)
    assert shard["layers"]["moe"]["w_gate"].spec[1] == "model"
    assert all(s is None for s in shard["layers"]["moe"]["router"].spec)

    # ssm: rwkv head-TP
    cfg = get_config("rwkv6-7b")
    params = steps.abstract_params(cfg)
    shard = sharding.param_shardings(params, mesh, cfg)
    tm = shard["layers"]["time_mix"]
    assert tm["wr"].spec[2] == "model"
    assert tm["wo"].spec[1] == "model"
    cm = shard["layers"]["channel_mix"]
    assert cm["wk"].spec[2] == "model"
    assert cm["wv"].spec[1] == "model"

    # decode cache: S -> model, batch -> data
    from repro.configs.base import InputShape
    cfg = get_config("h2o-danube-3-4b")
    shape = InputShape("d", seq_len=256, global_batch=8, kind="decode")
    tok, pos, state = steps.decode_input_struct(cfg, shape)
    sshard = sharding.decode_state_shardings(state, mesh, cfg, 8)
    kspec = sshard["cache"]["self"]["k"].spec
    assert kspec[1] == ("data",) or kspec[1] == "data"
    assert kspec[2] == "model"
    print("OK")
""")


def test_sharding_rules_subprocess():
    res = subprocess.run(
        [sys.executable, "-c", SHARDING_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, (res.stdout[-500:], res.stderr[-3000:])
    assert "OK" in res.stdout

"""Unit tests: layers, optimizers, schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.models import layers
from repro.optim import schedule


def test_rmsnorm_matches_naive():
    p = layers.rmsnorm_init(8, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 8))
    out = layers.rmsnorm(p, x)
    naive = x / np.sqrt(np.mean(np.asarray(x) ** 2, -1, keepdims=True)
                        + 1e-5)
    np.testing.assert_allclose(np.asarray(out), naive, atol=1e-5)


def test_layernorm_zero_mean_unit_var():
    p = layers.layernorm_init(16, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 16)) * 5 + 3
    out = np.asarray(layers.layernorm(p, x))
    np.testing.assert_allclose(out.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(out.std(-1), 1.0, atol=1e-2)


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 6, 2, 8))
    pos = jnp.broadcast_to(jnp.arange(6)[None], (1, 6))
    out = layers.apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(out), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 8))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 8))
    def dot_at(i, j):
        qi = layers.apply_rope(q, jnp.full((1, 1), i))
        kj = layers.apply_rope(k, jnp.full((1, 1), j))
        return float(jnp.sum(qi * kj))
    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4
    assert abs(dot_at(3, 1) - dot_at(3, 2)) > 1e-6


def test_rope_position_zero_identity():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 2, 8))
    out = layers.apply_rope(x, jnp.zeros((1, 1)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), atol=1e-6)


def test_cross_entropy_matches_naive():
    V, B, T, D = 11, 2, 8, 4
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (D, V))
    h = jax.random.normal(jax.random.fold_in(key, 1), (B, T, D))
    y = jax.random.randint(jax.random.fold_in(key, 2), (B, T), 0, V)
    out = layers.cross_entropy_loss(lambda hh: hh @ W, h, y, vocab_chunk=4)
    logits = np.asarray(h @ W)
    lse = np.log(np.sum(np.exp(logits - logits.max(-1, keepdims=True)), -1)) \
        + logits.max(-1)
    picked = np.take_along_axis(logits, np.asarray(y)[..., None], -1)[..., 0]
    naive = float(np.mean(lse - picked))
    assert abs(float(out) - naive) < 1e-4


def test_cross_entropy_ignore_index():
    V, D = 7, 4
    W = jnp.eye(D, V)
    h = jnp.ones((1, 4, D))
    y = jnp.array([[1, -1, -1, 2]])
    out = layers.cross_entropy_loss(lambda hh: hh @ W, h, y, vocab_chunk=2)
    y2 = jnp.array([[1, 2, 1, 2]])
    out2 = layers.cross_entropy_loss(lambda hh: hh @ W, h, y2, vocab_chunk=2)
    assert jnp.isfinite(out)
    # uniform h => same per-token loss; masking shouldn't change the mean
    np.testing.assert_allclose(float(out), float(out2), atol=1e-5)


def test_sgd_matches_manual():
    opt = optim.sgd(0.1)
    params = {"w": jnp.array([1.0, 2.0])}
    state = opt.init(params)
    grads = {"w": jnp.array([0.5, -1.0])}
    updates, state = opt.update(grads, state, params)
    np.testing.assert_allclose(np.asarray(updates["w"]), [-0.05, 0.1],
                               atol=1e-7)
    assert int(state.step) == 1


def test_sgd_momentum_accumulates():
    opt = optim.sgd(1.0, momentum=0.9)
    params = {"w": jnp.zeros((1,))}
    state = opt.init(params)
    g = {"w": jnp.ones((1,))}
    u1, state = opt.update(g, state, params)
    u2, state = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(u1["w"]), [-1.0])
    np.testing.assert_allclose(np.asarray(u2["w"]), [-1.9])


def test_adamw_first_step_is_lr_sized():
    opt = optim.adamw(1e-2, weight_decay=0.0)
    params = {"w": jnp.array([10.0])}
    state = opt.init(params)
    updates, _ = opt.update({"w": jnp.array([3.0])}, state, params)
    np.testing.assert_allclose(np.asarray(updates["w"]), [-1e-2], rtol=1e-3)


def test_adamw_grad_clip():
    opt = optim.adamw(1.0, grad_clip_norm=1.0)
    params = {"w": jnp.zeros((2,))}
    state = opt.init(params)
    big = {"w": jnp.array([300.0, 400.0])}    # norm 500 -> scaled to 1
    _, state2 = opt.update(big, state, params)
    np.testing.assert_allclose(float(jnp.linalg.norm(state2.mu["w"])),
                               0.1, rtol=1e-4)   # (1-b1)*clipped


def test_schedules():
    s = schedule.warmup_cosine(1.0, warmup_steps=10, total_steps=100)
    assert float(s(jnp.asarray(0))) == 0.0
    assert abs(float(s(jnp.asarray(10))) - 1.0) < 1e-5
    assert float(s(jnp.asarray(100))) <= 0.11
    inv = schedule.inverse_sqrt(1.0, warmup_steps=16)
    assert float(inv(jnp.asarray(16))) == 1.0
    assert abs(float(inv(jnp.asarray(64))) - 0.5) < 1e-5

"""End-to-end behaviour tests: the paper's claim on a real LM + substrates."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint, optim
from repro.configs import get_config
from repro.core import RobustConfig, make_robust_train_step
from repro.data.tokens import TokenStream, frame_embeddings, patch_embeddings
from repro.models import model as M

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_lm(aggregator, attack, steps=8, m=8):
    cfg = get_config("minitron-4b").reduced()
    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=32,
                         global_batch=16, num_workers=m, seed=0)
    rc = RobustConfig(num_workers=m, num_byzantine=2, attack=attack,
                      aggregator=aggregator, num_batches=8)
    opt = optim.adamw(1e-3)
    loss_fn = lambda p, b: M.loss_fn(p, b, cfg)  # noqa: E731
    step = jax.jit(make_robust_train_step(loss_fn, opt, rc))
    params = M.init(jax.random.PRNGKey(0), cfg)
    opt_state = opt.init(params)
    losses = []
    for i in range(steps):
        params, opt_state, metrics = step(
            params, opt_state, stream.batch(i), jax.random.PRNGKey(7), i)
        losses.append(float(metrics["loss_median"]))
    return losses


@pytest.mark.slow
def test_lm_training_robustness_end_to_end():
    """The paper's headline behaviour on a transformer LM:
    mean+attack diverges; gmom+attack tracks the attack-free run."""
    clean = _run_lm("mean", "none")
    broken = _run_lm("mean", "sign_flip")
    robust = _run_lm("gmom", "sign_flip")
    assert clean[-1] < clean[0]                     # learning happens
    assert broken[-1] > clean[-1] + 1.0             # mean is destroyed
    assert abs(robust[-1] - clean[-1]) < 0.5        # gmom ~ attack-free


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("h2o-danube-3-4b").reduced()
    params = M.init(jax.random.PRNGKey(0), cfg)
    path = checkpoint.save(str(tmp_path), 7, params)
    assert os.path.isdir(path)
    assert checkpoint.latest_step(str(tmp_path)) == 7
    zeros = jax.tree.map(jnp.zeros_like, params)
    restored = checkpoint.restore(str(tmp_path), 7, zeros)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention(tmp_path):
    params = {"w": jnp.ones((4,))}
    for s in range(6):
        checkpoint.save(str(tmp_path), s, params, keep=3)
    assert checkpoint.all_steps(str(tmp_path)) == [3, 4, 5]


def test_token_stream_deterministic_and_shaped():
    s = TokenStream(vocab_size=100, seq_len=16, global_batch=8,
                    num_workers=4, seed=3)
    b1, b2 = s.batch(5), s.batch(5)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (4, 2, 16)
    assert int(jnp.max(b1["tokens"])) < 100
    b3 = s.batch(6)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    # labels are next tokens
    np.testing.assert_array_equal(np.asarray(b1["labels"][..., :-1]),
                                  np.asarray(b1["tokens"][..., 1:]))


def test_modality_stubs():
    f = frame_embeddings(jax.random.PRNGKey(0), num_workers=2, per_worker=3,
                         num_frames=10, d_model=16)
    assert f.shape == (2, 3, 10, 16) and f.dtype == jnp.bfloat16
    p = patch_embeddings(jax.random.PRNGKey(0), num_workers=2, per_worker=3,
                         num_patches=4, d_model=16)
    assert p.shape == (2, 3, 4, 16)


@pytest.mark.slow
def test_train_driver_resume_bit_identical(tmp_path):
    """End-to-end driver resume: interrupt at a checkpoint boundary, resume
    from the saved TrainState, and get the exact uninterrupted history —
    including the stateful stealth_then_strike adversary.  (The fast
    per-schedule equivalence tests live in test_train_state.py; this one
    exercises the real LM driver path.)"""
    import types

    from repro.launch.train import train_cpu

    def args(**kw):
        base = dict(arch="minitron-4b", steps=6, workers=4, byzantine=1,
                    num_batches=4, attack="sign_flip",
                    schedule="stealth_then_strike", scan_chunk=3,
                    aggregator="gmom", batch=8, seq_len=16, lr=1e-3,
                    seed=0, log_every=100, ckpt_dir=None, ckpt_every=4,
                    out=None)
        base.update(kw)
        return types.SimpleNamespace(**base)

    straight = train_cpu(args())
    ckpt = str(tmp_path / "ckpt")
    # steps=3 is NOT a ckpt_every multiple: the final-state save must still
    # fire, and the resume restarts from that misaligned boundary
    train_cpu(args(steps=3, ckpt_dir=ckpt))          # "crash" after step 3
    resumed = train_cpu(args(ckpt_dir=ckpt))
    assert resumed["resumed_from"] == 3
    assert resumed["history"] == straight["history"]
    assert resumed["first_loss"] == straight["first_loss"]
    assert resumed["final_loss"] == straight["final_loss"]
    # resuming an already-complete run: no IndexError, unchanged result
    done = train_cpu(args(ckpt_dir=ckpt))
    assert done["resumed_from"] == 6
    assert done["history"] == straight["history"]


@pytest.mark.slow
def test_train_driver_cli(tmp_path):
    """examples-style end-to-end: the training driver runs and learns."""
    out = tmp_path / "result.json"
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "minitron-4b",
         "--steps", "6", "--workers", "4", "--byzantine", "1",
         "--attack", "sign_flip", "--aggregator", "gmom",
         "--batch", "8", "--seq-len", "32", "--out", str(out)],
        cwd=REPO, capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, res.stderr[-2000:]
    import json
    data = json.loads(out.read_text())
    assert np.isfinite(data["final_loss"])

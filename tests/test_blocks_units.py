"""Unit tests for MoE / RWKV / Mamba block internals."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mamba, moe, rwkv


# ---------------------------------------------------------------------------
# MoE

def _moe_spec(**kw):
    base = dict(d_model=16, d_ff=32, num_experts=4, experts_per_token=2,
                capacity_factor=4.0)
    base.update(kw)
    return moe.MoESpec(**base)


def test_moe_router_topk_and_renorm():
    spec = _moe_spec()
    params = moe.init(jax.random.PRNGKey(0), spec, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 16))
    ids, w, aux, z = moe.route(params, spec, x)
    assert ids.shape == (10, 2) and w.shape == (10, 2)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, atol=1e-5)
    assert float(aux) > 0 and float(z) >= 0


@pytest.mark.slow
def test_moe_full_capacity_equals_dense_mixture():
    """With no drops, MoE output == sum_k w_k * FFN_{e_k}(x) per token."""
    spec = _moe_spec(capacity_factor=100.0)
    params = moe.init(jax.random.PRNGKey(0), spec, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 16))
    out, _ = moe._apply_dense(params, spec, x)
    ids, w, _, _ = moe.route(params, spec, x.reshape(-1, 16))

    def ffn(e, h):
        g = h @ params["w_gate"][e]
        u = h @ params["w_up"][e]
        return (jax.nn.silu(g) * u) @ params["w_down"][e]

    for t in range(6):
        expected = sum(float(w[t, j]) * ffn(int(ids[t, j]), x[0, t])
                       for j in range(2))
        np.testing.assert_allclose(np.asarray(out[0, t]),
                                   np.asarray(expected), atol=1e-4)


def test_moe_capacity_drops_tokens():
    spec = _moe_spec(capacity_factor=0.01)    # capacity = K minimum
    params = moe.init(jax.random.PRNGKey(0), spec, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16))
    out, _ = moe._apply_dense(params, spec, x)
    assert bool(jnp.all(jnp.isfinite(out)))
    # some token outputs must be exactly zero (fully dropped)
    norms = jnp.linalg.norm(out[0], axis=-1)
    assert float(jnp.min(norms)) == 0.0


def test_moe_aux_loss_uniform_router_is_one():
    """Switch aux loss == 1 under perfectly uniform routing (its minimum)."""
    spec = _moe_spec()
    params = moe.init(jax.random.PRNGKey(0), spec, dtype=jnp.float32)
    params = dict(params, router=jnp.zeros_like(params["router"]))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    _, _, aux, _ = moe.route(params, spec, x)
    # f_e = 1/E exactly (ties broken deterministically may skew; allow slack)
    assert 0.9 < float(aux) < 1.4


# ---------------------------------------------------------------------------
# RWKV6

def test_wkv_scan_manual_recurrence():
    B, T, H, hd = 1, 4, 1, 3
    key = jax.random.PRNGKey(0)
    r, k, v, w = (jax.random.uniform(jax.random.fold_in(key, i),
                                     (B, T, H, hd)) for i in range(4))
    u = jax.random.uniform(jax.random.fold_in(key, 9), (H, hd))
    state = jnp.zeros((B, H, hd, hd))
    y, final = rwkv.wkv_scan(r, k, v, w, u, state)

    S = np.zeros((hd, hd))
    for t in range(T):
        kv = np.outer(np.asarray(k[0, t, 0]), np.asarray(v[0, t, 0]))
        yt = np.asarray(r[0, t, 0]) @ (S + np.asarray(u[0])[:, None] * kv)
        np.testing.assert_allclose(np.asarray(y[0, t, 0]), yt, atol=1e-5)
        S = np.asarray(w[0, t, 0])[:, None] * S + kv
    np.testing.assert_allclose(np.asarray(final[0, 0]), S, atol=1e-5)


@pytest.mark.slow
def test_rwkv_decay_in_unit_interval():
    spec = rwkv.RWKVSpec(d_model=32, d_ff=64, head_dim=8)
    params = rwkv.init(jax.random.PRNGKey(0), spec, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 32))
    out, (prev, state) = rwkv.time_mix(params["time_mix"], spec,
                                       x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    # decode continuation equals batch processing
    out_a, st_a = rwkv.time_mix(params["time_mix"], spec, x[:, :3])
    out_b, _ = rwkv.time_mix(params["time_mix"], spec, x[:, 3:],
                             prev_token=st_a[0], wkv_state=st_a[1])
    np.testing.assert_allclose(np.asarray(out[:, 3:]), np.asarray(out_b),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# Mamba2 SSD

def _ssd_naive(x, dt, A, B_mat, C_mat):
    """Direct recurrence h_t = a_t h + dt_t B_t x_t^T; y = C_t h."""
    Bsz, T, H, P = x.shape
    N = B_mat.shape[-1]
    h = np.zeros((Bsz, H, P, N))
    ys = np.zeros((Bsz, T, H, P))
    for t in range(T):
        a = np.exp(-np.asarray(dt[:, t]) * np.asarray(A))      # (B,H)
        inject = np.einsum("bh,bhp,bn->bhpn", np.asarray(dt[:, t]),
                           np.asarray(x[:, t]), np.asarray(B_mat[:, t]))
        h = a[..., None, None] * h + inject
        ys[:, t] = np.einsum("bn,bhpn->bhp", np.asarray(C_mat[:, t]), h)
    return ys, h


@pytest.mark.parametrize("chunk", [2, 4, 8])
def test_ssd_chunked_matches_naive(chunk):
    B, T, H, P, N = 2, 8, 3, 4, 5
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B, T, H, P))
    dt = jax.random.uniform(jax.random.fold_in(key, 1), (B, T, H),
                            minval=0.1, maxval=1.0)
    A = jax.random.uniform(jax.random.fold_in(key, 2), (H,),
                           minval=0.5, maxval=2.0)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (B, T, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (B, T, N))
    spec = mamba.MambaSpec(d_model=P * H // 2, chunk=chunk)
    y, final = mamba._ssd_chunked(x, dt, A, Bm, Cm, spec)
    y_ref, h_ref = _ssd_naive(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-4)
    np.testing.assert_allclose(np.asarray(final), h_ref, atol=1e-4)


@pytest.mark.slow
def test_ssd_carried_state_continuation():
    B, T, H, P, N = 1, 8, 2, 4, 3
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (B, T, H, P))
    dt = jax.random.uniform(jax.random.fold_in(key, 1), (B, T, H),
                            minval=0.1, maxval=0.9)
    A = jnp.ones((H,))
    Bm = jax.random.normal(jax.random.fold_in(key, 2), (B, T, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 3), (B, T, N))
    spec = mamba.MambaSpec(d_model=4, chunk=4)
    y_full, s_full = mamba._ssd_chunked(x, dt, A, Bm, Cm, spec)
    y1, s1 = mamba._ssd_chunked(x[:, :4], dt[:, :4], A, Bm[:, :4],
                                Cm[:, :4], spec)
    y2, s2 = mamba._ssd_chunked(x[:, 4:], dt[:, 4:], A, Bm[:, 4:],
                                Cm[:, 4:], spec, init_state=s1)
    np.testing.assert_allclose(np.asarray(y_full[:, 4:]), np.asarray(y2),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2), atol=1e-4)


def test_causal_conv_decode_matches_batch():
    spec = mamba.MambaSpec(d_model=8)
    key = jax.random.PRNGKey(2)
    w = jax.random.normal(key, (4, 6))
    b = jnp.zeros((6,))
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 10, 6))
    y_full, _ = mamba._causal_conv(x, w, b)
    state = jnp.zeros((2, 3, 6))
    outs = []
    for t in range(10):
        y, state = mamba._causal_conv(x[:, t:t + 1], w, b, state=state)
        outs.append(y)
    y_inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_inc),
                               atol=1e-5)

"""Audit: every assigned architecture config matches the assignment table
exactly, and the shape table matches the four assigned input shapes."""

import pytest

from repro.configs import ARCHITECTURES, get_config, get_shape, \
    long_context_variant, supports_long_context

# (arch, family, L, d_model, H, kv, d_ff, vocab, extras)
ASSIGNMENT = {
    "qwen2-72b": ("dense", 80, 8192, 64, 8, 29568, 152064,
                  {"qkv_bias": True}),
    "rwkv6-7b": ("ssm", 32, 4096, 0, 0, 14336, 65536, {}),
    "qwen3-14b": ("dense", 40, 5120, 40, 8, 17408, 151936,
                  {"qk_norm": True}),
    "seamless-m4t-medium": ("audio", 12, 1024, 16, 16, 4096, 256206,
                            {"encoder_layers": 12, "frontend": "audio"}),
    "granite-moe-1b-a400m": ("moe", 24, 1024, 16, 8, 512, 49155,
                             {"num_experts": 32, "experts_per_token": 8}),
    "kimi-k2-1t-a32b": ("moe", 61, 7168, 64, 8, 2048, 163840,
                        {"num_experts": 384, "experts_per_token": 8}),
    "zamba2-2.7b": ("hybrid", 54, 2560, 32, 32, 10240, 32000,
                    {"ssm_state": 64}),
    "internvl2-26b": ("vlm", 48, 6144, 48, 8, 16384, 92553,
                      {"frontend": "vision"}),
    "minitron-4b": ("dense", 32, 3072, 24, 8, 9216, 256000, {}),
    "h2o-danube-3-4b": ("dense", 24, 3840, 32, 8, 10240, 32000,
                        {"sliding_window": 4096}),
}


def test_all_ten_assigned():
    assert set(ARCHITECTURES) == set(ASSIGNMENT)


@pytest.mark.parametrize("arch", sorted(ASSIGNMENT))
def test_config_matches_assignment(arch):
    family, L, d, H, kv, ff, vocab, extras = ASSIGNMENT[arch]
    cfg = get_config(arch)
    assert cfg.family == family
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.d_ff == ff
    assert cfg.vocab_size == vocab
    if H:
        assert cfg.num_heads == H
        assert cfg.num_kv_heads == kv
    for key, val in extras.items():
        assert getattr(cfg, key) == val, (arch, key)
    assert cfg.source   # citation present


def test_shapes_match_assignment():
    s = get_shape("train_4k")
    assert (s.seq_len, s.global_batch, s.kind) == (4096, 256, "train")
    s = get_shape("prefill_32k")
    assert (s.seq_len, s.global_batch, s.kind) == (32768, 32, "prefill")
    s = get_shape("decode_32k")
    assert (s.seq_len, s.global_batch, s.kind) == (32768, 128, "decode")
    s = get_shape("long_500k")
    assert (s.seq_len, s.global_batch, s.kind) == (524288, 1, "decode")


def test_long_context_policy():
    # native sub-quadratic: ssm/hybrid/native-SWA
    for arch in ["rwkv6-7b", "zamba2-2.7b", "h2o-danube-3-4b"]:
        assert supports_long_context(get_config(arch))
        assert long_context_variant(get_config(arch)).name == \
            get_config(arch).name
    # full-attention archs get the explicit SWA variant
    for arch in ["qwen2-72b", "qwen3-14b", "minitron-4b", "internvl2-26b",
                 "granite-moe-1b-a400m", "kimi-k2-1t-a32b",
                 "seamless-m4t-medium"]:
        cfg = get_config(arch)
        assert not supports_long_context(cfg)
        var = long_context_variant(cfg)
        assert var.sliding_window == 4096
        assert "+swa4k" in var.name


def test_param_counts_sane():
    # order-of-magnitude sanity of the analytic counts used by the roofline
    approx = {
        "qwen2-72b": 72e9, "qwen3-14b": 14e9, "minitron-4b": 4e9,
        "h2o-danube-3-4b": 4e9, "rwkv6-7b": 7e9, "zamba2-2.7b": 2.7e9,
        "granite-moe-1b-a400m": 1.3e9, "kimi-k2-1t-a32b": 1.0e12,
        "internvl2-26b": 20e9, "seamless-m4t-medium": 1.2e9,
    }
    for arch, expect in approx.items():
        n = get_config(arch).param_count()
        assert 0.4 * expect < n < 2.5 * expect, (arch, n, expect)


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    active = cfg.active_param_count()
    assert active < 0.1 * cfg.param_count()      # sparse activation
    assert 10e9 < active < 60e9                  # "A32B"-ish

"""Multi-pod scenario sweep engine (repro.sim.sweep) + the CI contract.

Tier-1-fast coverage:
* registry shape: the full attack × schedule × aggregator matrix exists on
  both production meshes, names are well-formed, lookups work;
* the sweep record schema round-trips through JSON and self-compares clean;
* the --check gate flags an injected collective-bytes regression, a missing
  scenario, and a stale record entry (library + CLI exit codes);
* one PodScenario lowers end-to-end on a small host-device mesh (subprocess:
  the virtual-device flag must precede jax init) and produces a schema-valid
  entry with nonzero collectives;
* .github/workflows/ci.yml parses and wires the two lanes the README
  documents (tier1 on push/PR; nightly slow lane running the sweep gate).
"""

import copy
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.roofline import analysis
from repro.sim import sweep

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fake_entry(name: str, *, coll=1.0e9, peak=2.0e9) -> dict:
    rec = analysis.RooflineRecord(
        arch="minitron-4b", shape="train_4k", mesh="16x16",
        step="train_step", flops_per_device=1e12, bytes_per_device=1e12,
        collective_bytes_per_device=coll,
        collective_breakdown={"all-gather": coll * 0.5,
                              "all-reduce": coll * 0.5},
        peak_memory_bytes=peak, model_flops_global=1e15, num_chips=256)
    entry = analysis.sweep_entry(rec, scenario=name)
    entry.update(aggregator="gmom", attack="sign_flip", schedule="static",
                 round_backend="auto", num_groups=4, num_byzantine=1,
                 compile_seconds=1.0)
    return entry


def _fake_payload(names, **kw) -> dict:
    return {"matrix": {"attacks": list(sweep.POD_ATTACKS),
                       "schedules": list(sweep.POD_SCHEDULES),
                       "aggregators": list(sweep.POD_AGGREGATORS),
                       "meshes": list(sweep.POD_MESHES)},
            "scenarios": {n: _fake_entry(n, **kw) for n in names}}


# ---------------------------------------------------------------------------
# registry

def test_registry_covers_full_matrix_on_both_meshes():
    names = sweep.available()
    expected = (len(sweep.POD_ATTACKS) * len(sweep.POD_SCHEDULES)
                * len(sweep.POD_AGGREGATORS) * len(sweep.POD_MESHES)
                + len(sweep.BIG_MODEL_SCENARIOS)
                + len(sweep.COMPRESSION_SCENARIOS)
                + len(sweep.STALE_SCENARIOS))
    assert len(names) == expected
    for mesh in sweep.POD_MESHES:
        for agg in sweep.POD_AGGREGATORS:
            for attack in sweep.POD_ATTACKS:
                for schedule in sweep.POD_SCHEDULES:
                    name = (f"pod/{mesh}/{sweep.DEFAULT_ARCH}/{agg}/"
                            f"{attack}/{schedule}")
                    ps = sweep.get_pod_scenario(name)
                    assert (ps.mesh, ps.aggregator, ps.attack, ps.schedule) \
                        == (mesh, agg, attack, schedule)


def test_big_model_cells_registered():
    """The qwen2-72b shard-scaling cells: sharded gmom/krum/coord_median
    plus the gathered-baseline gmom twin."""
    for name in sweep.BIG_MODEL_SCENARIOS:
        ps = sweep.get_pod_scenario(name)
        assert ps.arch == sweep.BIG_MODEL_ARCH
        assert ps.mesh == "16x16"
        expect = "gathered" if name.endswith("/gathered") else "sharded"
        assert ps.grad_mode == expect, name
    gathered = [n for n in sweep.BIG_MODEL_SCENARIOS
                if sweep.get_pod_scenario(n).grad_mode == "gathered"]
    assert len(gathered) == 1
    assert sweep.get_pod_scenario(gathered[0]).aggregator == "gmom"
    aggs = {sweep.get_pod_scenario(n).aggregator
            for n in sweep.BIG_MODEL_SCENARIOS}
    assert {"gmom", "krum", "coord_median"} <= aggs


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(KeyError, match="unknown pod scenario"):
        sweep.get_pod_scenario("pod/nope")
    existing = sweep.get_pod_scenario(sweep.available()[0])
    with pytest.raises(ValueError, match="already registered"):
        sweep.register(existing)
    with pytest.raises(ValueError, match="unknown mesh"):
        sweep.register(sweep.PodScenario(name="pod/bad-mesh", mesh="3x3"))


def test_pod_scenario_builds_rc_and_schedule():
    ps = sweep.get_pod_scenario(
        f"pod/2x16x16/{sweep.DEFAULT_ARCH}/gmom/alie/stealth_then_strike")
    rc = ps.robust_config()
    assert rc.aggregator == "gmom" and rc.attack == "alie"
    assert rc.num_workers == rc.num_batches == ps.num_groups
    sched = ps.build_schedule()
    assert sched.name == "stealth_then_strike"
    assert sched.num_workers == ps.num_groups


# ---------------------------------------------------------------------------
# record schema + gate

def test_sweep_entry_schema_roundtrips_and_self_compares_clean():
    payload = _fake_payload(sweep.available()[:3])
    rt = json.loads(json.dumps(payload))
    assert rt == payload
    problems, notes = sweep.compare_payloads(rt, payload)
    assert problems == [] and notes == []


def test_check_flags_injected_collective_regression():
    names = sweep.available()[:2]
    record = _fake_payload(names)
    fresh = copy.deepcopy(record)
    fresh["scenarios"][names[0]]["collective_bytes_per_device"] *= 1.5
    problems, _ = sweep.compare_payloads(record, fresh)
    assert len(problems) == 1
    assert names[0] in problems[0] and "collective bytes regressed" \
        in problems[0]


def test_check_flags_memory_regression_and_improvement_note():
    names = sweep.available()[:1]
    record = _fake_payload(names)
    fresh = copy.deepcopy(record)
    fresh["scenarios"][names[0]]["peak_memory_bytes"] *= 2.0
    fresh["scenarios"][names[0]]["collective_bytes_per_device"] *= 0.5
    problems, notes = sweep.compare_payloads(record, fresh)
    assert len(problems) == 1 and "peak memory regressed" in problems[0]
    assert any("improved" in n for n in notes)


def test_check_flags_missing_and_stale_scenarios():
    names = sweep.available()[:2]
    record = _fake_payload(names[:1])
    fresh = _fake_payload(names[1:])
    problems, _ = sweep.compare_payloads(record, fresh)
    assert any("not in the checked-in record" in p for p in problems)
    assert any("stale record entry" in p for p in problems)


def test_small_drift_within_tolerance_passes():
    names = sweep.available()[:1]
    record = _fake_payload(names)
    fresh = copy.deepcopy(record)
    fresh["scenarios"][names[0]]["collective_bytes_per_device"] *= 1.01
    fresh["scenarios"][names[0]]["peak_memory_bytes"] *= 1.05
    problems, _ = sweep.compare_payloads(record, fresh)
    assert problems == []


def _fake_big_model_payload(*, gmom_peak=1.0e10, gathered_peak=None,
                            krum_peak=None) -> dict:
    base = f"pod/16x16/{sweep.BIG_MODEL_ARCH}/gmom/sign_flip/static"
    krum = f"pod/16x16/{sweep.BIG_MODEL_ARCH}/krum/sign_flip/static"
    if gathered_peak is None:
        gathered_peak = gmom_peak * sweep.SHARD_MEMORY_MIN_RATIO * 2
    if krum_peak is None:
        krum_peak = gmom_peak * 1.1
    scenarios = {
        base: _fake_entry(base, peak=gmom_peak),
        base + "/gathered": _fake_entry(base + "/gathered",
                                        peak=gathered_peak),
        krum: _fake_entry(krum, peak=krum_peak),
    }
    scenarios[base + "/gathered"]["grad_mode"] = "gathered"
    return {"scenarios": scenarios}


def test_shard_scaling_gate_passes_on_clean_ratios():
    payload = _fake_big_model_payload()
    assert sweep.shard_scaling_problems(payload["scenarios"]) == []


def test_shard_scaling_gate_flags_lost_memory_ratio():
    payload = _fake_big_model_payload(
        gmom_peak=1.0e10,
        gathered_peak=1.0e10 * (sweep.SHARD_MEMORY_MIN_RATIO - 1))
    problems = sweep.shard_scaling_problems(payload["scenarios"])
    assert len(problems) == 1
    assert "O(d/shards)" in problems[0]


def test_shard_scaling_gate_flags_krum_blowup():
    payload = _fake_big_model_payload(
        gmom_peak=1.0e10,
        krum_peak=1.0e10 * (sweep.KRUM_PEAK_MAX_RATIO + 1))
    problems = sweep.shard_scaling_problems(payload["scenarios"])
    assert len(problems) == 1
    assert "krum" in problems[0]


def test_compression_cells_registered():
    """The §1.4 wire-cost cells: two full-step compressed aggregation cells
    plus the three report-wire microcells (f32 baseline / sign / int8)."""
    for name in sweep.COMPRESSION_SCENARIOS:
        ps = sweep.get_pod_scenario(name)
        assert ps.mesh == "16x16" and ps.arch == sweep.DEFAULT_ARCH
        assert ps.wire == name.endswith("/wire"), name
        assert ps.robust_config().compression == ps.compression
    wire = {sweep.get_pod_scenario(n).compression
            for n in sweep.COMPRESSION_SCENARIOS if n.endswith("/wire")}
    assert wire == {"none", "sign", "int8_stochastic"}
    full = {(sweep.get_pod_scenario(n).aggregator,
             sweep.get_pod_scenario(n).attack)
            for n in sweep.COMPRESSION_SCENARIOS if not n.endswith("/wire")}
    assert ("sign_sgd_majority", "sign_flip_targeted") in full
    assert ("int8_gmom", "sign_flip") in full


def _fake_wire_payload(*, f32=8.0e10, sign=None, int8=None) -> dict:
    if sign is None:
        sign = f32 / 32.0
    if int8 is None:
        int8 = f32 / 4.0
    return {sweep.WIRE_BASELINE_SCENARIO:
            _fake_entry(sweep.WIRE_BASELINE_SCENARIO, coll=f32),
            sweep.WIRE_SIGN_SCENARIO:
            _fake_entry(sweep.WIRE_SIGN_SCENARIO, coll=sign),
            sweep.WIRE_INT8_SCENARIO:
            _fake_entry(sweep.WIRE_INT8_SCENARIO, coll=int8)}


def test_wire_gate_passes_on_clean_ratios():
    assert sweep.compression_wire_problems(_fake_wire_payload()) == []


def test_wire_gate_flags_lost_sign_reduction():
    scenarios = _fake_wire_payload(f32=8.0e10, sign=8.0e10 / 20.0)
    problems = sweep.compression_wire_problems(scenarios)
    assert len(problems) == 1
    assert "sign" in problems[0] and "wire-cost claim" in problems[0]


def test_wire_gate_flags_lost_int8_reduction():
    scenarios = _fake_wire_payload(f32=8.0e10, int8=8.0e10 / 2.0)
    problems = sweep.compression_wire_problems(scenarios)
    assert len(problems) == 1 and "int8" in problems[0]


def test_wire_gate_tolerates_rtol_and_flags_optimized_away_wire():
    # just inside the 5% slack of the 25x floor: no problem
    ok = _fake_wire_payload(f32=8.0e10,
                            sign=8.0e10 / (25.0 * (1 - 0.04)))
    assert sweep.compression_wire_problems(ok) == []
    gone = _fake_wire_payload()
    gone[sweep.WIRE_SIGN_SCENARIO]["collective_bytes_per_device"] = 0.0
    problems = sweep.compression_wire_problems(gone)
    assert len(problems) == 1 and "optimized away" in problems[0]


def test_wire_gate_skips_absent_cells():
    assert sweep.compression_wire_problems({}) == []
    base_only = {sweep.WIRE_BASELINE_SCENARIO:
                 _fake_entry(sweep.WIRE_BASELINE_SCENARIO)}
    assert sweep.compression_wire_problems(base_only) == []


def test_shard_scaling_gate_skips_absent_cells():
    """Filtered --check runs / --fresh-from subsets without the big-model
    cells must not trip the gate."""
    names = sweep.available()[:2]
    payload = _fake_payload(names)
    assert sweep.shard_scaling_problems(payload["scenarios"]) == []
    assert sweep.shard_scaling_problems({}) == []


def test_cli_check_exit_codes(tmp_path):
    """sweep --check wiring: clean record -> 0, doctored regression -> 1,
    no record -> 2 (uses --fresh-from so no lowering happens)."""
    names = sweep.available()[:2]
    fresh = _fake_payload(names)
    fresh_path = tmp_path / "fresh.json"
    fresh_path.write_text(json.dumps(fresh))

    ok_record = tmp_path / "record_ok.json"
    ok_record.write_text(json.dumps(fresh))
    bad = copy.deepcopy(fresh)
    bad["scenarios"][names[0]]["collective_bytes_per_device"] *= 0.5
    bad_record = tmp_path / "record_bad.json"
    bad_record.write_text(json.dumps(bad))

    def run(record_path):
        return subprocess.run(
            [sys.executable, "-m", "repro.sim.sweep", "--check",
             "--fresh-from", str(fresh_path),
             "--record-path", str(record_path)],
            capture_output=True, text=True, timeout=300,
            env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))

    res = run(ok_record)
    assert res.returncode == 0, (res.stdout, res.stderr[-2000:])
    res = run(bad_record)
    assert res.returncode == 1 and "REGRESSION" in res.stdout, \
        (res.stdout, res.stderr[-2000:])
    res = run(tmp_path / "missing.json")
    assert res.returncode == 2, (res.stdout, res.stderr[-2000:])


def test_cli_filtered_check_ignores_out_of_scope_record_entries(tmp_path):
    """--check --single-pod against the full-matrix record must not call
    the unswept 2x16x16 entries stale (exit 0)."""
    single = [n for n in sweep.available()
              if sweep.get_pod_scenario(n).mesh == "16x16"][:2]
    multi = [n for n in sweep.available()
             if sweep.get_pod_scenario(n).mesh == "2x16x16"][:2]
    record_path = tmp_path / "record.json"
    record_path.write_text(json.dumps(_fake_payload(single + multi)))
    fresh_path = tmp_path / "fresh.json"
    fresh_path.write_text(json.dumps(_fake_payload(single)))
    res = subprocess.run(
        [sys.executable, "-m", "repro.sim.sweep", "--check", "--single-pod",
         "--scenario", single[0], "--scenario", single[1],
         "--fresh-from", str(fresh_path), "--record-path", str(record_path)],
        capture_output=True, text=True, timeout=300,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, (res.stdout, res.stderr[-2000:])
    assert "stale" not in res.stdout


def test_force_host_device_count_upgrades_stale_flag():
    """A pre-exported smaller device-count flag is raised in place (the old
    import-time mutation silently kept the stale value)."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = \\
            "--xla_allow_excess_precision " \\
            "--xla_force_host_platform_device_count=8"
        from repro.launch import dryrun
        dryrun.force_host_device_count(64)
        flags = os.environ["XLA_FLAGS"]
        assert "--xla_force_host_platform_device_count=64" in flags, flags
        assert "--xla_allow_excess_precision" in flags, flags
        import jax
        assert jax.device_count() == 64, jax.device_count()
        dryrun.force_host_device_count(32)   # enough devices: no-op
        print("OK")
    """)
    res = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=300,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, (res.stdout[-800:], res.stderr[-3000:])
    assert "OK" in res.stdout


def test_checked_in_record_covers_registry():
    """The committed BENCH_pod_sweeps.json covers every registered scenario
    and both meshes (check_docs enforces the same invariant in CI)."""
    assert os.path.exists(sweep.BENCH_PATH), \
        "benchmarks/BENCH_pod_sweeps.json missing — run " \
        "`python -m repro.sim.sweep --all` and commit it"
    rec = sweep.load_record()
    scenarios = rec.get("scenarios", {})
    missing = [n for n in sweep.available() if n not in scenarios]
    assert not missing, f"record missing scenarios: {missing[:5]} ..."
    recorded_meshes = {e["mesh"] for e in scenarios.values()}
    assert set(sweep.POD_MESHES) <= recorded_meshes, recorded_meshes
    for name, entry in scenarios.items():
        assert entry["collective_bytes_per_device"] > 0
        ps = sweep.get_pod_scenario(name)
        expect = ("report_wire" if ps.wire
                  else "stale_report" if ps.stale else "train_step")
        assert entry["step"] == expect, name


# ---------------------------------------------------------------------------
# one real lowering on a small host-device mesh (subprocess: the virtual
# device flag must be set before jax initializes)

_LOWER_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    from repro.configs import get_config
    from repro.configs.base import InputShape
    import repro.configs.shapes as shapes_mod
    from repro.launch import mesh as mesh_lib
    from repro.sim import sweep

    small = InputShape("train_tiny", seq_len=32, global_batch=16,
                       kind="train")
    shapes_mod.SHAPES[small.name] = small
    ps = sweep.get_pod_scenario(
        "pod/2x16x16/%s/gmom/alie/stealth_then_strike" % sweep.DEFAULT_ARCH)
    entry = sweep.lower_scenario(
        ps, mesh=mesh_lib.make_debug_mesh(data=2, model=2, pod=2),
        cfg=get_config(sweep.DEFAULT_ARCH).reduced(), shape="train_tiny")
    assert entry["scenario"] == ps.name
    assert entry["num_chips"] == 8
    assert entry["collective_bytes_per_device"] > 0
    assert set(entry["collective_breakdown"]) == {
        "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute"}
    json.dumps(entry)   # JSON-stable
    print("OK", int(entry["collective_bytes_per_device"]))
""")


def test_pod_scenario_lowers_on_small_mesh():
    res = subprocess.run(
        [sys.executable, "-c", _LOWER_SCRIPT],
        capture_output=True, text=True, timeout=600,
        env=dict(os.environ, PYTHONPATH=os.path.join(REPO, "src")))
    assert res.returncode == 0, (res.stdout[-1000:], res.stderr[-3000:])
    assert "OK" in res.stdout


# ---------------------------------------------------------------------------
# CI workflow contract

def test_ci_workflow_parses_and_wires_both_lanes():
    yaml = pytest.importorskip("yaml")
    path = os.path.join(REPO, ".github", "workflows", "ci.yml")
    assert os.path.exists(path), ".github/workflows/ci.yml missing"
    with open(path) as f:
        wf = yaml.safe_load(f)
    # pyyaml parses the bare `on:` key as boolean True
    triggers = wf.get("on", wf.get(True))
    assert "pull_request" in triggers and "push" in triggers
    assert "schedule" in triggers and "workflow_dispatch" in triggers

    jobs = wf["jobs"]
    assert set(jobs) == {"tier1", "slow"}
    tier1_text = json.dumps(jobs["tier1"])
    assert "python -m pytest -x -q" in tier1_text
    assert "scripts/check_docs.py" in tier1_text
    assert "repro.sim.goldens --check" in tier1_text
    # the matrix pins a jax floor (0.4.x shims) and a current entry
    matrix = jobs["tier1"]["strategy"]["matrix"]["include"]
    assert any(m["jax-version"].startswith("0.4.") for m in matrix)
    assert any(m["jax-version"] == "" for m in matrix)
    assert any(step.get("with", {}).get("cache") == "pip"
               for step in jobs["tier1"]["steps"] if isinstance(step, dict))

    slow_text = json.dumps(jobs["slow"])
    assert "repro.sim.sweep --check" in slow_text
    assert '-m pytest -q -m' in slow_text
    # slow lane only fires on schedule/dispatch; tier1 on push/PR
    assert "schedule" in jobs["slow"]["if"]
    assert "pull_request" in jobs["tier1"]["if"]

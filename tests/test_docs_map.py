"""Tier-1 wrapper around scripts/check_docs.py: the paper↔code map
(docs/PAPER_MAP.md), the README aggregator table, and the checked-in
BENCH_round_kernel.json must stay consistent with the live registries."""

import os
import sys

SCRIPTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "scripts")


def test_docs_registries_consistent():
    sys.path.insert(0, SCRIPTS)
    try:
        import check_docs
        problems = check_docs.collect_problems()
    finally:
        sys.path.remove(SCRIPTS)
    assert not problems, "\n".join(problems)

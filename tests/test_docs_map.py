"""Tier-1 wrapper around scripts/check_docs.py: the paper↔code map
(docs/PAPER_MAP.md), the README aggregator table, and the checked-in
BENCH_round_kernel.json must stay consistent with the live registries."""

import os
import sys

SCRIPTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "scripts")


def test_docs_registries_consistent():
    sys.path.insert(0, SCRIPTS)
    try:
        import check_docs
        problems = check_docs.collect_problems()
    finally:
        sys.path.remove(SCRIPTS)
    assert not problems, "\n".join(problems)


def test_undocumented_codec_fails_check_docs():
    """Registering a wire codec without documenting it must fail the docs
    gate, same as an undocumented aggregator or attack."""
    from repro.core import compression
    sys.path.insert(0, SCRIPTS)
    try:
        import check_docs
        compression.register(
            "_test_undocumented_codec",
            "temporary codec for the docs-gate test",
            encode=lambda tree, **kw: tree,
            decode=lambda payload, like, **kw: payload)
        problems = check_docs._codec_problems(
            check_docs._read(os.path.join("docs", "PAPER_MAP.md")))
        assert any("_test_undocumented_codec" in p and "PAPER_MAP" in p
                   for p in problems), problems
        assert any("_test_undocumented_codec" in p and "BENCHMARKS" in p
                   for p in problems), problems
    finally:
        compression._REGISTRY.pop("_test_undocumented_codec", None)
        sys.path.remove(SCRIPTS)

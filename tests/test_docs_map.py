"""Tier-1 wrapper around scripts/check_docs.py: the paper↔code map
(docs/PAPER_MAP.md), the README aggregator table, and the checked-in
BENCH_round_kernel.json must stay consistent with the live registries."""

import os
import sys

SCRIPTS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "scripts")


def test_docs_registries_consistent():
    sys.path.insert(0, SCRIPTS)
    try:
        import check_docs
        problems = check_docs.collect_problems()
    finally:
        sys.path.remove(SCRIPTS)
    assert not problems, "\n".join(problems)


def test_undocumented_codec_fails_check_docs():
    """Registering a wire codec without documenting it must fail the docs
    gate, same as an undocumented aggregator or attack."""
    from repro.core import compression
    sys.path.insert(0, SCRIPTS)
    try:
        import check_docs
        compression.register(
            "_test_undocumented_codec",
            "temporary codec for the docs-gate test",
            encode=lambda tree, **kw: tree,
            decode=lambda payload, like, **kw: payload)
        problems = check_docs._codec_problems(
            check_docs._read(os.path.join("docs", "PAPER_MAP.md")))
        assert any("_test_undocumented_codec" in p and "PAPER_MAP" in p
                   for p in problems), problems
        assert any("_test_undocumented_codec" in p and "BENCHMARKS" in p
                   for p in problems), problems
    finally:
        compression._REGISTRY.pop("_test_undocumented_codec", None)
        sys.path.remove(SCRIPTS)


def test_undocumented_arrival_fails_check_docs():
    """The docs/ASYNC.md contract: registering an arrival schedule without
    adding it to the ASYNC.md table AND the PAPER_MAP synchrony rows must
    fail the docs gate (exit != 0 via collect_problems)."""
    from repro.core import staleness
    sys.path.insert(0, SCRIPTS)
    try:
        import check_docs

        @staleness.register_arrival(
            "_test_undocumented_arrival",
            "temporary arrival schedule for the docs-gate test")
        def _builder(*, num_workers, staleness_bound, **_kw):
            return staleness.make_arrival(
                "all_sync", num_workers=num_workers,
                staleness_bound=staleness_bound)

        problems = check_docs._arrival_problems(
            check_docs._read(os.path.join("docs", "PAPER_MAP.md")))
        assert any("_test_undocumented_arrival" in p and "ASYNC" in p
                   for p in problems), problems
        assert any("_test_undocumented_arrival" in p and "PAPER_MAP" in p
                   for p in problems), problems
    finally:
        staleness._ARRIVAL_REGISTRY.pop("_test_undocumented_arrival", None)
        staleness._ARRIVAL_DESCRIPTIONS.pop("_test_undocumented_arrival",
                                            None)
        sys.path.remove(SCRIPTS)


def test_dead_doc_path_fails_check_docs():
    """A prose doc referencing a nonexistent repo file — or the build
    container's /root/related staging area — must fail the docs gate."""
    sys.path.insert(0, SCRIPTS)
    try:
        import check_docs
        fake = {"docs/FAKE.md":
                "see `src/repro/core/no_such_module.py` and the exemplar "
                "under /root/related/some_repo/thing.py"}
        problems = check_docs._dead_path_problems(doc_texts=fake)
        assert any("no_such_module.py" in p for p in problems), problems
        assert any("/root/related" in p for p in problems), problems
        # and the real docs tree is clean
        assert check_docs._dead_path_problems() == []
    finally:
        sys.path.remove(SCRIPTS)

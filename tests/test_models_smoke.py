"""Per-architecture smoke tests (assignment deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(<=2 layers, d_model<=256, <=4 experts) and run one forward pass AND one
robust train step on CPU, asserting output shapes and finiteness.
"""

import jax
import jax.numpy as jnp
import pytest

pytestmark = pytest.mark.slow

from repro import optim
from repro.configs import ARCHITECTURES, get_config
from repro.core import RobustConfig, make_robust_train_step
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, T=32, workers=None):
    """Batch for reduced config; optional leading worker axis."""
    lead = (workers,) if workers else ()
    tok = jax.random.randint(KEY, lead + (B, T), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=-1)}
    if cfg.family == "vlm":
        keep = T - cfg.num_patches
        batch["tokens"] = tok[..., :keep]
        batch["labels"] = batch["labels"][..., :keep]
        batch["patches"] = jax.random.normal(
            KEY, lead + (B, cfg.num_patches, cfg.d_model), cfg.dtype)
    if cfg.family == "audio":
        t_enc = max(T // cfg.encoder_seq_divisor, 1)
        batch["frames"] = jax.random.normal(
            KEY, lead + (B, t_enc, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_reduced_constraints(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = M.init(KEY, cfg)
    batch = make_batch(cfg)
    loss = M.loss_fn(params, batch, cfg)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    lg = M.logits(params, cfg, batch)
    assert lg.shape == batch["labels"].shape + (cfg.vocab_size,)
    assert bool(jnp.all(jnp.isfinite(lg)))


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_one_robust_train_step(arch):
    cfg = get_config(arch).reduced()
    m = 4
    rc = RobustConfig(num_workers=m, num_byzantine=1, num_batches=4,
                      attack="sign_flip", aggregator="gmom",
                      gmom_max_iters=8)
    opt = optim.adamw(1e-3)
    loss_fn = lambda p, b: M.loss_fn(p, b, cfg)  # noqa: E731
    step = jax.jit(make_robust_train_step(loss_fn, opt, rc))
    params = M.init(KEY, cfg)
    opt_state = opt.init(params)
    batch = make_batch(cfg, workers=m)
    new_params, _, metrics = step(params, opt_state, batch,
                                  jax.random.PRNGKey(1), 0)
    assert bool(jnp.isfinite(metrics["loss_median"]))
    assert bool(jnp.isfinite(metrics["agg_grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(new_params),
                        jax.tree.leaves(params)))
    assert moved
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCHITECTURES)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    params = M.init(KEY, cfg)
    B = 2
    state = M.init_decode_state(cfg, B, 64)
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab_size)
    lg, new_state = M.decode_step(params, cfg, state, tok,
                                  jnp.zeros((B,), jnp.int32))
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg)))
    assert jax.tree.structure(new_state) == jax.tree.structure(state)


@pytest.mark.parametrize("arch", ["qwen2-72b", "h2o-danube-3-4b",
                                  "rwkv6-7b", "zamba2-2.7b",
                                  "minitron-4b", "qwen3-14b"])
def test_decode_matches_forward(arch):
    """KV-cache / recurrent-state decode reproduces the full forward."""
    cfg = get_config(arch).reduced()
    if cfg.family == "hybrid":
        cfg = cfg.with_(ssm_chunk=4)
    params = M.init(jax.random.PRNGKey(1), cfg)
    B, T = 2, 16
    tok = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    full = M.logits(params, cfg, {"tokens": tok, "labels": tok})
    state = M.init_decode_state(cfg, B, T)
    outs = []
    for t in range(T):
        lg, state = M.decode_step(params, cfg, state, tok[:, t:t + 1],
                                  jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    scale = float(jnp.max(jnp.abs(full))) + 1e-6
    assert float(jnp.max(jnp.abs(full - dec))) / scale < 2e-2


def test_moe_decode_matches_forward_with_slack_capacity():
    cfg = get_config("granite-moe-1b-a400m").reduced() \
        .with_(moe_capacity_factor=100.0)
    params = M.init(jax.random.PRNGKey(1), cfg)
    B, T = 2, 8
    tok = jax.random.randint(KEY, (B, T), 0, cfg.vocab_size)
    full = M.logits(params, cfg, {"tokens": tok, "labels": tok})
    state = M.init_decode_state(cfg, B, T)
    outs = []
    for t in range(T):
        lg, state = M.decode_step(params, cfg, state, tok[:, t:t + 1],
                                  jnp.full((B,), t, jnp.int32))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.max(jnp.abs(full - dec))) < 1e-3


def test_sliding_window_variant_changes_logits():
    cfg = get_config("minitron-4b").reduced()
    from repro.configs import long_context_variant
    cfg_swa = long_context_variant(get_config("minitron-4b")).reduced()
    assert cfg_swa.sliding_window is not None
    params = M.init(KEY, cfg)
    T = 96
    tok = jax.random.randint(KEY, (1, T), 0, cfg.vocab_size)
    full = M.logits(params, cfg, {"tokens": tok, "labels": tok})
    swa = M.logits(params, cfg_swa.with_(sliding_window=8),
                   {"tokens": tok, "labels": tok})
    # early positions identical (window covers everything)...
    assert float(jnp.max(jnp.abs(full[:, :4] - swa[:, :4]))) < 1e-3
    # ...late positions differ (window truncates context)
    assert float(jnp.max(jnp.abs(full[:, -1] - swa[:, -1]))) > 1e-4

import os

# Smoke tests and benches must see the single real CPU device; only the
# dry-run (repro.launch.dryrun / subprocess tests) sets the 512-device flag.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)

"""Scenario engine: the paper's headline claim as executable tests, plus
golden-trace regression checks."""

import jax
import numpy as np
import pytest

from repro import sim
from repro.sim import goldens
from repro.sim.scenarios import available, get_scenario, golden_scenarios

SCHEDULES = ("static", "rotating", "ramp_up", "coordinated_switch",
             "stealth_then_strike")


def test_registry_sanity():
    names = available()
    assert len(names) == len(set(names))
    for s in SCHEDULES:
        assert f"linreg/gmom/sign_flip/{s}" in names
    with pytest.raises(KeyError):
        get_scenario("nope")
    sc = get_scenario("linreg/gmom/sign_flip/rotating")
    assert sc.paper_floor > 0


@pytest.mark.parametrize("schedule", SCHEDULES)
def test_gmom_converges_under_every_schedule(schedule):
    """Theorem 1 / Corollary 1: with 2(1+eps)q <= k (and q <= (m-1)/2), GMoM
    drives the estimation error to the sqrt(d(2q+1)/N) scale no matter how
    the Byzantine set and attack vary across rounds."""
    tr = sim.run_scenario(f"linreg/gmom/sign_flip/{schedule}")
    assert tr["final_est_error"] < 1.2 * tr["paper_floor"], tr
    # exponential decrease early on (Corollary 1's contraction)
    errs = tr["est_error"]
    assert errs[5] < 0.5 * errs[0]


def test_mean_diverges_under_attack_but_converges_failure_free():
    """Algorithm 1's breakdown: a single Byzantine worker per round sends
    plain BGD to infinity, while the failure-free baseline converges."""
    broken = sim.run_scenario("linreg/mean/sign_flip/rotating")
    clean = sim.run_scenario("linreg/mean/none/static")
    assert broken["final_est_error"] > 10.0
    assert clean["final_est_error"] < 1.2 * clean["paper_floor"]


def test_adaptive_attacks_stay_tolerated():
    """The two new omniscient attacks (ALIE, norm-stealth) do not break
    GMoM within the tolerance region.  Reads the checked-in goldens (their
    fidelity is enforced by test_goldens_match_checked_in) to avoid
    re-running the scenarios."""
    for name in ("linreg/gmom/alie/static",
                 "linreg/gmom/norm_stealth/rotating"):
        tr = goldens.load_golden(name)
        assert tr["final_est_error"] < 2.0 * tr["paper_floor"], name


def test_traces_byte_stable_across_runs():
    """Two consecutive runs of the same scenario serialize to identical
    bytes (determinism is what makes goldens trustworthy)."""
    name = "linreg/gmom/sign_flip/rotating"
    b1 = goldens.trace_bytes(sim.run_scenario(name, rounds=10))
    b2 = goldens.trace_bytes(sim.run_scenario(name, rounds=10))
    assert b1 == b2


def test_goldens_match_checked_in():
    """Every golden scenario reproduces its checked-in trace."""
    assert golden_scenarios(), "no golden scenarios registered"
    for sc in golden_scenarios():
        trace = sim.run_scenario(sc)
        mismatches = goldens.compare_traces(
            trace, goldens.load_golden(sc.name))
        assert not mismatches, (sc.name, mismatches[:5])


def test_golden_files_are_canonical_bytes():
    """Checked-in files are exactly the canonical serialization (no manual
    edits; `python -m repro.sim.goldens --update` is the only writer)."""
    for sc in golden_scenarios():
        with open(goldens.golden_path(sc.name), "rb") as f:
            on_disk = f.read()
        assert on_disk == goldens.trace_bytes(goldens.load_golden(sc.name))


def test_compare_traces_detects_drift():
    tr = {"a": 1.0, "b": [1.0, 2.0]}
    assert goldens.compare_traces(tr, {"a": 1.0, "b": [1.0, 2.0]}) == []
    assert goldens.compare_traces(tr, {"a": 1.01, "b": [1.0, 2.0]})
    assert goldens.compare_traces(tr, {"a": 1.0, "b": [1.0]})
    assert goldens.compare_traces(tr, {"a": 1.0})


# --------------------------------------------------------------------------
# compressed wire scenario: the sign-majority golden runs the full 1-bit
# encode -> packed-vote pipeline end to end through the engine.

def test_sign_majority_golden_is_compressed():
    sc = get_scenario("linreg/sign_majority_static")
    assert sc.golden and sc.compression == "sign"
    assert sc.aggregator == "sign_sgd_majority"
    assert sc.attack == "sign_flip"
    # the trace carries the codec name; uncompressed traces must NOT
    # (adding the key unconditionally would invalidate every pre-existing
    # golden — compare_traces flags one-sided keys)
    assert goldens.load_golden(sc.name)["compression"] == "sign"
    assert "compression" not in goldens.load_golden(
        "linreg/gmom/sign_flip/rotating")


def test_sign_majority_vote_survives_sign_flippers_end_to_end():
    """Qualitative claim behind the golden: with q sign-flipping workers
    the vote still drives estimation error down by ~10x from init.  Sign
    descent settles at an eta*sqrt(d) neighborhood, not the paper's
    statistical floor, so the envelope is deliberately looser than the
    GMoM scenarios' 1.2x floor check — the golden pins the exact level."""
    tr = goldens.load_golden("linreg/sign_majority_static")
    errs = tr["est_error"]
    assert tr["final_est_error"] < 0.1 * errs[0]
    assert tr["final_est_error"] < 2.0 * tr["paper_floor"]


def test_compressed_scenario_resume_replay_bit_exact(tmp_path):
    """Interrupted-then-resumed checkpointed replay of the compressed
    scenario is byte-identical to the single scan AND reproduces the
    checked-in golden: the codec keeps no state outside (key, round), so
    nothing about compression breaks resume."""
    name = "linreg/sign_majority_static"
    straight = goldens.trace_bytes(sim.run_scenario(name))
    d = str(tmp_path / "ckpt")
    sim.replay_scenario(name, d, rounds=19, ckpt_every=7)    # "crash" mid-run
    trace = sim.replay_scenario(name, d, ckpt_every=7)
    assert goldens.trace_bytes(trace) == straight
    assert goldens.compare_traces(trace, goldens.load_golden(name)) == []


def test_stale_golden_carries_staleness_keys_conditionally():
    sc = get_scenario("linreg/gmom/sign_flip/rotating/stale")
    assert sc.golden and sc.arrival == "straggler_rotating"
    assert sc.staleness_bound == 2
    tr = goldens.load_golden(sc.name)
    assert tr["arrival"] == "straggler_rotating"
    assert tr["staleness_bound"] == 2
    assert len(tr["stale_count"]) == sc.rounds
    assert any(c > 0 for c in tr["stale_count"])
    # synchronous traces must NOT grow the keys (adding them
    # unconditionally would invalidate every pre-existing golden)
    sync = goldens.load_golden("linreg/gmom/sign_flip/rotating")
    assert "arrival" not in sync and "stale_count" not in sync


def test_stale_scenario_resume_replay_bit_exact(tmp_path):
    """Interrupted-then-resumed checkpointed replay of the staleness
    scenario is byte-identical to the single scan AND reproduces the
    checked-in golden: the buffer rides TrainState (ages + buffered rows
    restored exactly), so a mid-decay interrupt loses nothing."""
    name = "linreg/gmom/sign_flip/rotating/stale"
    straight = goldens.trace_bytes(sim.run_scenario(name))
    d = str(tmp_path / "ckpt")
    sim.replay_scenario(name, d, rounds=19, ckpt_every=7)    # "crash" mid-run
    trace = sim.replay_scenario(name, d, ckpt_every=7)
    assert goldens.trace_bytes(trace) == straight
    assert goldens.compare_traces(trace, goldens.load_golden(name)) == []

"""Property tests for the gradient-compression codecs (core/compression.py).

Wire-format invariants the aggregation stack builds on:

* ``sign`` round-trips the exact IEEE sign pattern — including −0.0 and
  subnormals — and its packed bytes are bit-stable across input dtype
  (f32 vs bf16) and across even/uneven last-dim shapes;
* ``int8_stochastic`` is unbiased (the mean decode over many keys
  concentrates on the input at the 3σ rate) with worst-case per-coordinate
  error below one per-worker scale step, and its scales are per-worker
  (the quantization-range attack closure);
* the packed majority vote equals the raw-gradient vote bit for bit.

``hypothesis`` is optional, per the repo convention: when installed the
properties run under its strategies; otherwise the same checks run over a
parametrized set of deterministic seeds (tier-1 does not ship hypothesis).
The exhaustive variants (every uint8 word pattern, a 4096-key
concentration run) sit behind the ``slow`` marker for the nightly
``-m ""`` lane.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression
from repro.core.compression import (majority_vote_packed,
                                    majority_vote_signs, pack_signs,
                                    packed_words, unpack_signs)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

FALLBACK_SEEDS = list(range(5))


def _random_tree(seed: int):
    """A stacked-gradient pytree with even and uneven last dims plus a
    param-dim-free (m,) leaf — the three packing layouts."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(2, 9))
    d_even = 8 * int(rng.integers(1, 5))
    d_odd = int(rng.integers(1, 21))
    return {
        "w": (rng.normal(size=(m, d_even)) * 10).astype(np.float32),
        "b": {"x": (rng.normal(size=(m, 3, d_odd)) * 0.1)
              .astype(np.float32)},
        "s": rng.normal(size=(m,)).astype(np.float32),
    }


def property_test(*, needs_seed=False):
    """Run the check under hypothesis when available, else over seeds."""
    def deco(check):
        if HAVE_HYPOTHESIS:
            if needs_seed:
                return given(tree_strategy,
                             st.integers(0, 2**31 - 1))(check)
            return given(tree_strategy)(check)

        @pytest.mark.parametrize("seed", FALLBACK_SEEDS)
        def fallback(seed):
            tree = _random_tree(seed)
            if needs_seed:
                check(tree, seed + 1000)
            else:
                check(tree)
        fallback.__name__ = check.__name__
        fallback.__doc__ = check.__doc__
        return fallback
    return deco


if HAVE_HYPOTHESIS:
    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
    tree_strategy = st.builds(_random_tree, st.integers(0, 2**31 - 1))


def _sign_pattern(x):
    """The exact expected sign decode: −1 where signbit, else +1."""
    return np.where(np.signbit(x), -1.0, 1.0).astype(x.dtype)


# ---------------------------------------------------------------------------
# sign codec

@property_test()
def test_sign_roundtrip_recovers_exact_sign_pattern(tree):
    codec = compression.get_codec("sign")
    decoded = codec.decode(codec.encode(tree), tree)
    for leaf, dec in zip(jax.tree.leaves(tree), jax.tree.leaves(decoded)):
        np.testing.assert_array_equal(np.asarray(dec), _sign_pattern(leaf))
        assert dec.dtype == leaf.dtype


def test_sign_roundtrip_zero_and_subnormal_edge_cases():
    """IEEE corner cases: −0.0 and negative subnormals are negative, +0.0
    and positive subnormals positive (jnp.signbit semantics), infs keep
    their sign — bit 1 == signbit, no value-magnitude dependence."""
    x = np.array([[0.0, -0.0, 1e-45, -1e-45, np.inf, -np.inf,
                   1e38, -1e-38, 5e-324, -5e-324]], np.float32)
    codec = compression.get_codec("sign")
    dec = np.asarray(jax.tree.leaves(codec.decode(codec.encode(x), x))[0])
    np.testing.assert_array_equal(dec, _sign_pattern(x))
    # −0.0 really voted negative and +0.0 positive
    assert dec[0, 1] == -1.0 and dec[0, 0] == 1.0


@property_test()
def test_sign_packing_bit_stable_across_dtypes(tree):
    """f32 and bf16 reports with the same sign pattern pack to the SAME
    bytes — the wire format is dtype-independent."""
    f32 = jax.tree.map(lambda x: jnp.asarray(x, jnp.float32), tree)
    bf16 = jax.tree.map(lambda x: jnp.asarray(x, jnp.bfloat16), tree)
    codec = compression.get_codec("sign")
    p32 = jax.tree.leaves(codec.encode(f32))
    p16 = jax.tree.leaves(codec.encode(bf16))
    for a, b in zip(p32, p16):
        assert a.dtype == jnp.uint8 and b.dtype == jnp.uint8
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("d", [1, 7, 8, 9, 13, 16, 64])
def test_sign_packing_even_and_uneven_last_dims(d):
    """Packing pads the last dim to whole uint8 words with ZERO bits, and
    unpacking slices the pad back off — any d round-trips."""
    rng = np.random.default_rng(d)
    x = rng.normal(size=(3, d)).astype(np.float32)
    packed = pack_signs(jnp.asarray(x))
    assert packed.shape == (3, packed_words(d))
    bits = np.asarray(unpack_signs(packed, d))
    np.testing.assert_array_equal(bits, np.signbit(x).astype(np.uint8))
    if d % 8:   # padding bits really are zero
        full = np.asarray(unpack_signs(packed, packed_words(d) * 8))
        assert not full[..., d:].any()


@property_test()
def test_majority_vote_packed_equals_raw_vote(tree):
    """The server's packed-wire vote == the raw-gradient vote, leaf for
    leaf, bit for bit (ties resolve to +1 on both paths)."""
    payload = compression.get_codec("sign").encode(tree)
    raw = majority_vote_signs(tree)
    packed = majority_vote_packed(payload, tree)
    for a, b in zip(jax.tree.leaves(raw), jax.tree.leaves(packed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_majority_vote_tie_resolves_positive():
    x = np.array([[1.0], [-1.0], [2.0], [-2.0]], np.float32)   # 2 vs 2
    assert np.asarray(jax.tree.leaves(majority_vote_signs(x))[0]) == 1.0


# ---------------------------------------------------------------------------
# int8_stochastic codec

def _int8_roundtrip(tree, key):
    codec = compression.get_codec("int8_stochastic")
    payload = codec.encode(tree, key=key)
    return payload, codec.decode(payload, tree)


@property_test(needs_seed=True)
def test_int8_worst_case_error_below_one_scale_step(tree, seed):
    """|decode(encode(g)) − g| < scale, per coordinate, per worker: the
    stochastic rounding moves each coordinate by strictly less than one
    quantization step of its OWN worker's scale."""
    payload, decoded = _int8_roundtrip(tree, jax.random.PRNGKey(seed))
    flat_g = jax.tree.leaves(tree)
    flat_d = jax.tree.leaves(decoded)
    flat_s = jax.tree.leaves(payload["scale"])
    for g, dec, s in zip(flat_g, flat_d, flat_s):
        err = np.abs(np.asarray(dec, np.float64) - np.asarray(g, np.float64))
        step = np.asarray(s, np.float64).reshape((-1,) + (1,) * (g.ndim - 1))
        assert (err <= step * (1 + 1e-6)).all()


@property_test(needs_seed=True)
def test_int8_unbiased_over_many_keys(tree, seed):
    """E_key[decode(encode(g))] == g over 512 independent keys.

    Two concentration checks (σ = scale / (2·sqrt(K)) is the uniform
    stochastic-rounding bound on the key-mean of ONE coordinate):
    * the per-worker aggregate bias — the mean over keys AND coordinates —
      sits within 3σ/sqrt(n_coords) of zero (a 3σ test on the statistic
      whose σ actually shrinks with the coordinate count);
    * every single coordinate's key-mean sits within 5σ (Bonferroni slack
      for the hundreds of coordinates a tree carries — a flat 3σ bound
      would fail ~0.3% of coordinates by design).
    """
    keys = jax.random.split(jax.random.PRNGKey(seed), 512)
    codec = compression.get_codec("int8_stochastic")

    def one(key):
        return codec.decode(codec.encode(tree, key=key), tree)

    stacked = jax.vmap(one)(keys)
    payload = codec.encode(tree, key=keys[0])
    for g, dec, s in zip(jax.tree.leaves(tree), jax.tree.leaves(stacked),
                         jax.tree.leaves(payload["scale"])):
        err = (np.asarray(dec, np.float64).mean(axis=0)
               - np.asarray(g, np.float64))
        step = np.asarray(s, np.float64).reshape((-1,) + (1,) * (g.ndim - 1))
        sigma = step / (2.0 * np.sqrt(len(keys)))
        assert (np.abs(err) <= 5.0 * sigma + 1e-7).all()
        n_coords = err[0].size if err.ndim > 1 else 1
        bias = err.reshape(err.shape[0], -1).mean(axis=1)
        tol = 3.0 * sigma.reshape(-1) / np.sqrt(n_coords) + 1e-7
        assert (np.abs(bias) <= tol).all()


def test_int8_scales_are_per_worker_range_attack_closure():
    """A Byzantine worker reporting 1e6× magnitudes must not inflate the
    honest workers' quantization step — scales are per-(worker, leaf)."""
    honest = np.ones((3, 16), np.float32)
    byz = np.full((1, 16), 1e6, np.float32)
    tree = np.concatenate([honest, byz])
    payload, decoded = _int8_roundtrip(tree, jax.random.PRNGKey(0))
    scale = np.asarray(jax.tree.leaves(payload["scale"])[0])
    assert scale.shape == (4,)
    np.testing.assert_allclose(scale[:3], 1.0 / 127.0, rtol=1e-6)
    # honest rows decode with honest-sized error
    err = np.abs(np.asarray(decoded)[:3] - honest)
    assert err.max() <= 1.0 / 127.0 * (1 + 1e-6)


def test_int8_zero_leaf_uses_unit_scale():
    """An all-zero worker report must not divide by zero: scale falls back
    to 1.0 and the decode is exactly zero."""
    tree = np.zeros((2, 8), np.float32)
    payload, decoded = _int8_roundtrip(tree, jax.random.PRNGKey(1))
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(payload["scale"])[0]), 1.0)
    np.testing.assert_array_equal(np.asarray(decoded), 0.0)


def test_int8_requires_key():
    with pytest.raises(ValueError, match="PRNG key"):
        compression.get_codec("int8_stochastic").encode(
            np.ones((2, 8), np.float32))


# ---------------------------------------------------------------------------
# registry / none codec

def test_registry_has_all_codecs_with_descriptions():
    assert set(compression.available()) >= {"none", "sign",
                                            "int8_stochastic"}
    for name, desc in compression.describe():
        assert desc.strip(), f"codec {name} has no description"
    with pytest.raises(KeyError, match="unknown codec"):
        compression.get_codec("zstd")
    bits = {n: compression.get_codec(n).bits_per_coordinate
            for n in ("none", "sign", "int8_stochastic")}
    assert bits == {"none": 32.0, "sign": 1.0, "int8_stochastic": 8.0}


def test_none_codec_is_identity():
    tree = _random_tree(0)
    codec = compression.get_codec("none")
    out = codec.decode(codec.encode(tree), tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# exhaustive variants (nightly -m "" lane)

@pytest.mark.slow
def test_sign_pack_unpack_exhaustive_word_patterns():
    """Every uint8 word pattern survives unpack -> repack bit-exactly."""
    words = jnp.arange(256, dtype=jnp.uint8).reshape(1, 256)
    bits = unpack_signs(words, 256 * 8)
    # signbit of (-1)^bit reproduces the bit, so repacking closes the loop
    x = jnp.where(bits == 1, -1.0, 1.0).astype(jnp.float32)
    np.testing.assert_array_equal(np.asarray(pack_signs(x)),
                                  np.asarray(words))


@pytest.mark.slow
def test_int8_unbiased_tight_concentration_4096_keys():
    """4096-key concentration — an ~3× tighter absolute bound than the
    tier-1 512-key run, same 5σ-per-coordinate / 3σ-aggregate rates."""
    tree = _random_tree(7)
    keys = jax.random.split(jax.random.PRNGKey(7), 4096)
    codec = compression.get_codec("int8_stochastic")
    stacked = jax.vmap(
        lambda k: codec.decode(codec.encode(tree, key=k), tree))(keys)
    payload = codec.encode(tree, key=keys[0])
    for g, dec, s in zip(jax.tree.leaves(tree), jax.tree.leaves(stacked),
                         jax.tree.leaves(payload["scale"])):
        err = (np.asarray(dec, np.float64).mean(axis=0)
               - np.asarray(g, np.float64))
        step = np.asarray(s, np.float64).reshape((-1,) + (1,) * (g.ndim - 1))
        sigma = step / (2.0 * np.sqrt(len(keys)))
        assert (np.abs(err) <= 5.0 * sigma + 1e-8).all()
        n_coords = err[0].size if err.ndim > 1 else 1
        bias = err.reshape(err.shape[0], -1).mean(axis=1)
        tol = 3.0 * sigma.reshape(-1) / np.sqrt(n_coords) + 1e-8
        assert (np.abs(bias) <= tol).all()

"""Bounded-staleness aggregation (core/staleness.py) — the docs/ASYNC.md
semantics contract, pinned:

* the all-fresh invariant: ``all_sync`` is BIT-identical to the synchronous
  trainer at any τ (a fresh row's weight is exactly 1.0, so the staleness
  scaling is an exact identity), and the disabled default (τ=0 +
  ``all_sync``) carries the empty pytree — same lowering, byte for byte;
* buffer mechanics: the merge rule keeps exactly the non-fresh rows, ages
  follow the exact integer recurrence (0 on arrival, +1 otherwise), and
  rows past the bound get weight exactly 0 (the hard drop);
* the PR 2 resume contract extended: a run interrupted with a NON-EMPTY
  staleness buffer (workers mid-decay at the boundary) resumes
  bit-identically to the uninterrupted run.

``hypothesis`` is optional, per the repo convention (tier-1 does not ship
it): properties fall back to a deterministic seed sweep.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import (RobustConfig, byzantine, init_train_state,
                        make_run_rounds, restore_train_state,
                        save_train_state, staleness)
from repro.core.staleness import (apply_staleness, init_buffer,
                                  merge_reports, staleness_weights)
from repro.core.train_state import advance, history_rows
from repro.data import regression

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

FALLBACK_SEEDS = list(range(5))


def _random_case(seed: int):
    """(buffer, reported, fresh) with random shapes/ages for the
    merge/weight properties."""
    rng = np.random.default_rng(seed)
    m = int(rng.integers(2, 9))
    d = int(rng.integers(1, 13))
    bound = int(rng.integers(0, 5))
    params = {"w": np.zeros((d,), np.float32),
              "b": {"x": np.zeros((3,), np.float32)}}
    buf = init_buffer(params, m, bound)
    # age the buffer into an arbitrary reachable state
    buf = buf._replace(
        age=jnp.asarray(rng.integers(0, bound + 3, size=(m,)), jnp.int32),
        grads=jax.tree.map(
            lambda l: jnp.asarray(
                rng.normal(size=(m,) + l.shape), jnp.float32), params))
    reported = jax.tree.map(
        lambda l: jnp.asarray(rng.normal(size=l.shape), jnp.float32),
        buf.grads)
    fresh = jnp.asarray(rng.integers(0, 2, size=(m,)).astype(bool))
    return buf, reported, fresh


def property_test(check):
    """Run under hypothesis when available, else over deterministic seeds."""
    if HAVE_HYPOTHESIS:
        wrapped = given(st.integers(0, 2**31 - 1))(check)
        return settings(max_examples=25, deadline=None)(wrapped)
    return pytest.mark.parametrize("seed", FALLBACK_SEEDS)(check)


# --------------------------------------------------------------------------
# buffer mechanics: merge / age / drop


@property_test
def test_merge_selects_rows_and_ages_exactly(seed):
    buf, reported, fresh = _random_case(seed)
    merged, new_buf = merge_reports(buf, reported, fresh)
    fresh_np = np.asarray(fresh)
    for got, rep, old in zip(jax.tree.leaves(merged),
                             jax.tree.leaves(reported),
                             jax.tree.leaves(buf.grads)):
        want = np.where(
            fresh_np.reshape((-1,) + (1,) * (np.asarray(rep).ndim - 1)),
            np.asarray(rep), np.asarray(old))
        np.testing.assert_array_equal(np.asarray(got), want)
    # the exact integer recurrence: 0 on arrival, +1 otherwise
    want_age = np.where(fresh_np, 0, np.asarray(buf.age) + 1)
    np.testing.assert_array_equal(np.asarray(new_buf.age), want_age)
    assert new_buf.age.dtype == jnp.int32
    # merged rows are what the buffer now holds (the buffer IS the merge)
    for got, kept in zip(jax.tree.leaves(merged),
                         jax.tree.leaves(new_buf.grads)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(kept))


@property_test
def test_weights_discount_and_hard_drop(seed):
    buf, _, _ = _random_case(seed)
    discount = 0.7
    w = np.asarray(staleness_weights(buf.age, buf.bound, discount=discount))
    age = np.asarray(buf.age)
    bound = int(buf.bound)
    assert np.all(w[age == 0] == np.float32(1.0))        # exactly 1.0 fresh
    assert np.all(w[age > bound] == 0.0)                 # hard drop
    mid = (age > 0) & (age <= bound)
    np.testing.assert_allclose(
        w[mid], np.float32(discount) ** age[mid].astype(np.float32),
        rtol=1e-6)


@property_test
def test_all_fresh_scaling_is_a_bit_exact_identity(seed):
    buf, reported, _ = _random_case(seed)
    fresh = jnp.ones_like(buf.age, dtype=bool)
    merged, new_buf = merge_reports(buf, reported, fresh)
    scaled = apply_staleness(merged, new_buf.age, new_buf.bound,
                             discount=0.7)
    for a, b in zip(jax.tree.leaves(scaled), jax.tree.leaves(merged)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dropped_rows_contribute_zero():
    params = {"w": np.zeros((4,), np.float32)}
    buf = init_buffer(params, 3, 1)
    buf = buf._replace(age=jnp.asarray([0, 1, 2], jnp.int32),
                       grads={"w": jnp.ones((3, 4), jnp.float32)})
    scaled = apply_staleness(buf.grads, buf.age, buf.bound, discount=0.5)
    rows = np.asarray(scaled["w"])
    assert np.all(rows[2] == 0.0), "age > bound must zero the row"
    # normalization: total mass stays m x weighted mean
    w = np.array([1.0, 0.5, 0.0], np.float32)
    np.testing.assert_allclose(rows[0], 3 * w[0] / w.sum(), rtol=1e-6)
    np.testing.assert_allclose(rows[1], 3 * w[1] / w.sum(), rtol=1e-6)


def test_init_buffer_starts_beyond_the_bound():
    """Workers that have never reported must be hard-dropped, not counted
    as age-0 phantom zeros."""
    buf = init_buffer({"w": np.zeros((2,), np.float32)}, 4, 2)
    assert buf.age.dtype == jnp.int32
    assert np.all(np.asarray(buf.age) > int(buf.bound))
    w = np.asarray(staleness_weights(buf.age, buf.bound, discount=0.7))
    assert np.all(w == 0.0)


def test_arrival_registry_round_trips():
    names = staleness.available_arrivals()
    assert set(names) == {"all_sync", "straggler_fixed",
                          "straggler_rotating", "partition",
                          "byzantine_max_stale"}
    for name, description in staleness.describe():
        assert description.strip(), name
        arr = staleness.make_arrival(name, num_workers=6, staleness_bound=2)
        fresh = arr.arrive(jax.random.PRNGKey(0), 3,
                           jnp.zeros((6,), bool))
        assert fresh.shape == (6,) and fresh.dtype == jnp.bool_


# --------------------------------------------------------------------------
# the all-fresh invariant on the real trainer


def _setup(*, arrival=None, d=8, N=1280, m=8, q=2, seed=3):
    ds = regression.generate(jax.random.PRNGKey(seed), dim=d,
                             total_samples=N, num_workers=m)
    rc = RobustConfig(num_workers=m, num_byzantine=q, num_batches=4,
                      attack="sign_flip", aggregator="gmom")
    schedule = byzantine.make_schedule("rotating", num_workers=m,
                                       num_byzantine=q, attack="sign_flip")
    opt = optim.adamw(1e-2)
    run = make_run_rounds(regression.squared_loss, opt, rc,
                          schedule=schedule, arrival=arrival)
    theta0 = jnp.zeros((d,))
    state0 = init_train_state(theta0, opt.init(theta0),
                              jax.random.PRNGKey(11), schedule=schedule,
                              arrival=arrival)
    return run, state0, regression.worker_batches(ds), opt, schedule


def _rows_sans_stale(rows):
    """History rows with the staleness-only metric removed: an enabled
    arrival adds ``stale_count`` to the trace by design (conditional keys
    keep disabled goldens byte-stable), so bit-equality against the sync
    trainer is asserted on the shared metrics."""
    return [{k: v for k, v in r.items() if k != "stale_count"}
            for r in rows]


def _tree_equal(a, b, msg=""):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, f"{msg}: structure {ta} vs {tb}"
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


@pytest.mark.parametrize("schedule_name",
                         ["static", "rotating", "stealth_then_strike"])
def test_all_sync_tau0_bit_identical_to_sync_trainer(schedule_name):
    """τ=0 + all_sync — the default — must not change a single bit of the
    synchronous trainer, for stateless and stateful attack schedules."""
    m, q, d = 8, 2, 8
    ds = regression.generate(jax.random.PRNGKey(3), dim=d,
                             total_samples=1280, num_workers=m)
    rc = RobustConfig(num_workers=m, num_byzantine=q, num_batches=4,
                      attack="sign_flip", aggregator="gmom")
    schedule = byzantine.make_schedule(schedule_name, num_workers=m,
                                       num_byzantine=q, attack="sign_flip")
    opt = optim.adamw(1e-2)
    theta0 = jnp.zeros((d,))
    batches = regression.worker_batches(ds)

    arrival = staleness.make_arrival("all_sync", num_workers=m,
                                     staleness_bound=0)
    run_sync = make_run_rounds(regression.squared_loss, opt, rc,
                               schedule=schedule)
    run_stale = make_run_rounds(regression.squared_loss, opt, rc,
                                schedule=schedule, arrival=arrival)
    s_sync = init_train_state(theta0, opt.init(theta0),
                              jax.random.PRNGKey(11), schedule=schedule)
    s_stale = init_train_state(theta0, opt.init(theta0),
                               jax.random.PRNGKey(11), schedule=schedule,
                               arrival=arrival)

    out_sync, _ = advance(run_sync, s_sync, batches, num_rounds=12)
    out_stale, _ = advance(run_stale, s_stale, batches, num_rounds=12)
    _tree_equal(out_stale.params, out_sync.params, "params")
    _tree_equal(out_stale.opt_state, out_sync.opt_state, "opt_state")
    assert _rows_sans_stale(history_rows(out_stale.history)) == \
        history_rows(out_sync.history)


def test_all_sync_any_tau_bit_identical_to_sync_trainer():
    """Stronger than τ=0: with every worker fresh the weights are exactly
    1.0, so even an ACTIVE buffer (τ=3, real merge/scale in the scan body)
    reproduces the sync trajectory bit for bit."""
    arrival = staleness.make_arrival("all_sync", num_workers=8,
                                     staleness_bound=3)
    run_a, s_a, batches, _, _ = _setup(arrival=arrival)
    run_b, s_b, _, _, _ = _setup(arrival=None)
    out_a, _ = advance(run_a, s_a, batches, num_rounds=12)
    out_b, _ = advance(run_b, s_b, batches, num_rounds=12)
    _tree_equal(out_a.params, out_b.params, "params")
    _tree_equal(out_a.opt_state, out_b.opt_state, "opt_state")
    assert _rows_sans_stale(history_rows(out_a.history)) == \
        history_rows(out_b.history)
    # and the buffer really was live: ages all 0 after an all-fresh run
    assert np.all(np.asarray(out_a.stale_buffer.age) == 0)


def test_disabled_arrival_keeps_empty_carry():
    run, state0, batches, _, _ = _setup(arrival=None)
    out, _ = advance(run, state0, batches, num_rounds=3)
    assert state0.stale_buffer == ()
    assert out.stale_buffer == ()


def test_straggler_run_is_finite_and_counts_stale_workers():
    arrival = staleness.make_arrival("straggler_rotating", num_workers=8,
                                     staleness_bound=2)
    run, state0, batches, _, _ = _setup(arrival=arrival)
    out, metrics = advance(run, state0, batches, num_rounds=10)
    assert bool(jnp.all(jnp.isfinite(out.params)))
    counts = np.asarray(metrics["stale_count"])
    assert counts.shape == (10,)
    assert np.all(counts >= 0) and np.any(counts > 0)
    ages = np.asarray(out.stale_buffer.age)
    assert ages.dtype == np.int32 and np.all(ages >= 0)


# --------------------------------------------------------------------------
# resume with a non-empty buffer


def test_resume_with_nonempty_buffer_is_bit_identical(tmp_path):
    """Interrupt mid-decay — some workers stale at the checkpoint boundary,
    the buffer holding real gradients — and the resumed run must match the
    straight run bit for bit (params, opt moments, ages, buffered rows)."""
    m = 8
    arrival = staleness.make_arrival("straggler_fixed", num_workers=m,
                                     staleness_bound=2)
    run, state0, batches, opt, schedule = _setup(arrival=arrival, m=m)
    # k = 8: round index 7 at the boundary, 7 % period != 0, so the
    # straggler rows are buffered mid-decay exactly when we interrupt
    rounds, k = 14, 8

    straight, _ = advance(run, state0, batches, num_rounds=rounds)

    mid, _ = advance(run, state0, batches, num_rounds=k)
    assert np.any(np.asarray(mid.stale_buffer.age) > 0), \
        "boundary must catch workers mid-decay or the test is vacuous"
    save_train_state(str(tmp_path), mid)
    del mid                                   # the "crash"

    theta0 = jnp.zeros_like(state0.params)
    restored = restore_train_state(str(tmp_path), k, theta0,
                                   opt.init(theta0), schedule=schedule,
                                   arrival=arrival)
    assert int(restored.round_index) == k
    resumed, _ = advance(run, restored, batches, num_rounds=rounds - k)

    _tree_equal(resumed.params, straight.params, "params")
    _tree_equal(resumed.opt_state, straight.opt_state, "opt_state")
    _tree_equal(resumed.stale_buffer, straight.stale_buffer, "stale_buffer")
    assert history_rows(resumed.history) == history_rows(straight.history)
